"""Streaming elastic execution: double-buffered pipelining vs discrete.

The STRELA move at the host level: instead of upload -> sweep -> download
in strict sequence (``KernelEngine.run``'s per-block blocking
``np.asarray``), ``run_stream`` cuts a large batch into warm-bucket
chunks and pipelines them — while chunk *i* computes on device, chunk
*i+1* uploads and chunk *i-1* drains, riding jax async dispatch.  This
bench holds the PR's claims at equal total B:

  * streaming steady-state samples/s >= a floor ratio of the discrete
    ``run``'s samples/s (1.0 where the machine can actually overlap,
    degraded to a collapse detector on a 1-core container — PR-2/PR-7
    calibration precedent: the floor is derived from *measured*
    multiprocessing parallelism, recorded alongside),
  * measured transfer/compute overlap (``overlap_frac`` = fraction of
    stream wall the host was NOT blocked in ``block_until_ready``)
    >= a parallelism-calibrated floor,
  * streamed chunks are bit-exact vs the discrete path and the
    DFG-interpreter oracle (ragged tail included),
  * a warm engine streams with ZERO new traces (trace count flat across
    the whole streaming phase — the bucket ladder is the trace budget),
  * ``Service.submit_stream`` pipelines a chunked tenant request
    bit-exact while discrete tenants interleave, with stream stats
    surfaced under ``stats()["stream"]``.
"""
from __future__ import annotations

import multiprocessing as _mp
import time

import numpy as np

from repro import obs, ual
from repro.core.dfg import interpret

from benchmarks.common import ART, Timer, fmt_table, save

KERNEL = "gemm"
BANK_WORDS = 64
B_TOTAL = 192            # equal-B comparison: 6 full top-bucket chunks
CHUNK = 32               # == the ladder's top bucket (warm trace reuse)
N_REPS = 7               # steady-state medians over this many sweeps
SERVICE_STREAM_N = 96
SERVICE_DISCRETE_N = 16


def _busy(n: int) -> int:
    acc = 0
    for i in range(n):
        acc = (acc + i * i) % 1000003
    return acc


def _measured_parallelism(n_procs: int = 2, work: int = 2_000_000) -> float:
    """CPU-bound multiprocessing speedup THIS machine delivers (~1.0 on a
    1-core container) — the honest basis for the overlap/throughput
    floors; cgroup quotas and noisy neighbors show up here, unlike
    ``os.cpu_count()`` (PR-2/PR-7 precedent)."""
    _busy(work // 10)
    t0 = time.perf_counter()
    for _ in range(n_procs):
        _busy(work)
    serial = time.perf_counter() - t0
    ctx = _mp.get_context("spawn")
    with ctx.Pool(n_procs) as pool:
        t0 = time.perf_counter()
        pool.map(_busy, [work] * n_procs)
        par = time.perf_counter() - t0
    return max(1.0, serial / par) if par > 0 else 1.0


def _throughput_floor(parallelism: float) -> float:
    """Streaming must deliver >= this ratio of discrete throughput.
    Where the machine can genuinely run host and device work in parallel
    (measured parallelism >= 2) pipelining must not lose to the discrete
    path (1.0); on a 1-core container the chunked python loop serializes
    with the compute it would otherwise hide behind, so the ratio
    degrades to a collapse detector (0.7) with the measured parallelism
    recorded alongside."""
    return 1.0 if parallelism >= 2.0 else 0.7


def _overlap_floor(parallelism: float) -> float:
    """Minimum acceptable ``overlap_frac``.  The metric is the fraction
    of stream wall the host spent NOT blocked on the device — genuine
    double buffering pushes it toward 1 on multi-core; on 1 core only
    the host's own pad/drain work registers (measured ~0.025-0.03 here),
    so the floor degrades to 1.5% — still a collapse detector for a
    fully-blocking regression, where every chunk waits out its whole
    compute and overlap falls toward 0."""
    return min(0.25, max(0.015, 0.5 * (parallelism - 1.0)))


def run(seed: int = 0, verbose: bool = True) -> dict:
    # jax first touched here (not at module import): fork-based benches
    # in the same harness run must spawn workers before jax threads
    from repro.ual.engine import CompiledKernelCache

    parallelism = _measured_parallelism()
    sps_floor = _throughput_floor(parallelism)
    ov_floor = _overlap_floor(parallelism)

    target = ual.Target.from_name("hycube", rows=4, cols=4, seed=seed,
                                  backend="pallas")
    program = ual.Program.from_kernel(KERNEL,
                                      n_banks=target.fabric.n_mem_ports,
                                      bank_words=BANK_WORDS)
    exe = ual.compile(program, target)
    if not exe.success:
        payload = {"mapped": False, "claims": {"mapped": False}}
        save("stream", payload)
        return payload
    n_iters = program.n_iters
    rng = np.random.default_rng(seed)
    mems = [program.random_inputs(rng) for _ in range(B_TOTAL)]
    flats = program.flatten_batch(mems)
    oracle = np.stack([program.flatten(interpret(program.dfg, m, n_iters))
                       for m in mems])

    engine = CompiledKernelCache()
    eng = engine.engine_for(exe.lowered)
    eng.warmup(program.layout.total_words)
    traces_after_warmup = eng.stats()["traces"]

    # -- discrete baseline: the existing blocking path, same total B
    discrete_walls = []
    for _ in range(N_REPS):
        t0 = time.perf_counter()
        disc_out, _info = engine.run(exe.lowered, flats, n_iters)
        discrete_walls.append(time.perf_counter() - t0)
    discrete_s = float(np.median(discrete_walls))
    discrete_sps = B_TOTAL / discrete_s

    # -- streaming: same flats, same engine, chunks drained as they land
    stream_walls, summaries = [], []
    stream_out = None
    for _ in range(N_REPS):
        rows_out = np.empty_like(disc_out)
        pos = 0
        t0 = time.perf_counter()
        gen = eng.run_stream(flats, n_iters, chunk=CHUNK)
        while True:
            try:
                out, _cinfo = next(gen)
            except StopIteration as stop:
                summaries.append(dict(stop.value or {}))
                break
            rows_out[pos:pos + len(out)] = out
            pos += len(out)
        stream_walls.append(time.perf_counter() - t0)
        stream_out = rows_out
    stream_s = float(np.median(stream_walls))
    stream_sps = B_TOTAL / stream_s
    overlap = float(np.median([s["overlap_frac"] for s in summaries]))
    traces_after_stream = eng.stats()["traces"]

    # -- ragged tail: B that straddles the ladder must stay bit-exact
    ragged_gen = eng.run_stream(flats[:CHUNK + 5], n_iters, chunk=CHUNK)
    ragged_rows = []
    while True:
        try:
            out, _cinfo = next(ragged_gen)
        except StopIteration:
            break
        ragged_rows.append(out)
    ragged = np.concatenate(ragged_rows)

    bitexact = (np.array_equal(stream_out, disc_out)
                and np.array_equal(stream_out, oracle)
                and np.array_equal(ragged, oracle[:CHUNK + 5]))

    # -- serving path: one chunked tenant pipelined through submit_stream
    # while a discrete tenant's singles coalesce in between
    prev_engine = ual.set_default_engine(engine)
    try:
        with ual.Service(max_batch=CHUNK, max_wait_ms=2.0,
                         max_queue=4 * SERVICE_STREAM_N) as svc:
            d_resps = [svc.submit(program, target, m, tenant="discrete")
                       for m in mems[:SERVICE_DISCRETE_N]]
            sr = svc.submit_stream(program, target,
                                   mems[:SERVICE_STREAM_N], tenant="bulk",
                                   chunk=CHUNK, span=2)
            got = sr.results(timeout=600)
            d_outs = [r.result(timeout=600) for r in d_resps]
            svc_stats = svc.stats()["stream"]
        svc_parity = all(
            np.array_equal(program.flatten(o), oracle[i])
            for i, o in enumerate(got)) and all(
            np.array_equal(program.flatten(o), oracle[i])
            for i, o in enumerate(d_outs))
        stream_info = sr.info
    finally:
        ual.set_default_engine(prev_engine)

    # -- trace artifact: one streaming sweep with the flight recorder on,
    # exported next to the claims JSON so the upload/compute/drain
    # pipeline is inspectable at https://ui.perfetto.dev
    tracer = obs.Tracer(enabled=True)
    prev = obs.set_tracer(tracer)
    try:
        with Timer("stream_traced"):
            gen = eng.run_stream(flats, n_iters, chunk=CHUNK)
            while True:
                try:
                    next(gen)
                except StopIteration:
                    break
        trace_path = tracer.export_chrome(ART / "stream_trace.json")
        chunk_spans = sum(1 for s in tracer.spans()
                          if s.name.startswith("stream:"))
    finally:
        obs.set_tracer(prev)

    data = {
        "mapped": True, "ii": exe.II, "B": B_TOTAL, "chunk": CHUNK,
        "reps": N_REPS,
        "parallelism_measured": round(parallelism, 2),
        "throughput_floor_ratio": sps_floor,
        "overlap_floor": round(ov_floor, 3),
        "discrete_sps": round(discrete_sps, 1),
        "stream_sps": round(stream_sps, 1),
        "stream_vs_discrete": round(stream_sps / discrete_sps, 3),
        "overlap_frac": round(overlap, 4),
        "traces_after_warmup": traces_after_warmup,
        "traces_after_stream": traces_after_stream,
        "bitexact": bitexact,
        "service": {"stream_requests": SERVICE_STREAM_N,
                    "discrete_requests": SERVICE_DISCRETE_N,
                    "parity": svc_parity, "stats": svc_stats,
                    "stream_info": stream_info},
        "trace": {"file": str(trace_path), "chunk_spans": chunk_spans},
    }
    claims = {
        "mapped": True,
        "stream_bitexact_vs_oracle_and_discrete": bitexact,
        "stream_sps_ge_floor_x_discrete":
            stream_sps >= sps_floor * discrete_sps,
        "overlap_ge_calibrated_floor": overlap >= ov_floor,
        "no_new_traces_while_streaming":
            traces_after_stream == traces_after_warmup,
        "service_stream_parity_with_interleaved_discrete": svc_parity,
        "service_stream_stats_surfaced":
            svc_stats["spans"] > 0 and svc_stats["samples"]
            == SERVICE_STREAM_N,
    }
    payload = {"data": data, "claims": claims, "kernel": KERNEL}
    save("stream", payload)
    if verbose:
        print("== streaming vs discrete at equal total B "
              f"(B={B_TOTAL}, chunk={CHUNK}, medians of {N_REPS}) ==")
        print(fmt_table(
            ["path", "samples/s", "overlap", "traces", "bitexact"],
            [["discrete run", data["discrete_sps"], "-",
              traces_after_warmup, "ok"],
             ["run_stream", data["stream_sps"], data["overlap_frac"],
              traces_after_stream, "ok" if bitexact else "MISMATCH"]]))
        print(f"measured parallelism {data['parallelism_measured']} -> "
              f"floors: sps ratio {sps_floor}, overlap {ov_floor:.3f}; "
              f"achieved ratio {data['stream_vs_discrete']}")
        print(f"service stream: {svc_stats} "
              f"(parity={'ok' if svc_parity else 'FAIL'})")
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

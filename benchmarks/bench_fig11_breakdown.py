"""Paper Fig. 11: SoC/CGRA area and CGRA power breakdowns + gating study.

(a) SoC area:   RISC-V 42%, SRAM 24%, CGRA 34%  (7.6 mm^2 total)
(b) CGRA area:  PE logic 42%, dmem 29%, CM 21%, routing 8%
(c) CGRA power: CM 52%, PE ctrl 23%, router 14%, ALU 8%, dmem 3%
    — CM dominates power despite modest area because it is read every
    cycle; we additionally price mapped kernels with and without PACE's
    dynamic clock gating (paper: ~10% additional savings) using real
    mapped configurations from the kernel library.
"""
from __future__ import annotations

from repro import ual
from repro.core.energy import (AREA_SPLIT_CGRA, AREA_SPLIT_SOC, POWER_SPLIT,
                               kernel_energy)

from benchmarks.common import fmt_table, save


def run(seed: int = 0, verbose: bool = True) -> dict:
    target = ual.Target.from_name("pace", seed=seed)
    gating = {}
    for name in ("gemm", "dct", "nw"):
        program = ual.Program.from_kernel(name)
        exe = ual.compile(program, target)
        if not exe.success:
            continue
        n_iters = program.n_iters
        e_on = kernel_energy(exe.map_result.config, n_iters,
                             dynamic_gating=True)
        e_off = kernel_energy(exe.map_result.config, n_iters,
                              dynamic_gating=False)
        gating[name] = {
            "ii": exe.II,
            "energy_gated_pj": e_on["total"],
            "energy_ungated_pj": e_off["total"],
            "savings_pct": (1 - e_on["total"] / e_off["total"]) * 100,
            # the configs being priced are correct: one batched sweep each
            "validated": exe.validate(seed=seed, n_vectors=2).passed,
        }
    claims = {
        "cm_dominates_power": POWER_SPLIT["cm"] == max(POWER_SPLIT.values()),
        "cm_area_modest": AREA_SPLIT_CGRA["cm"] < AREA_SPLIT_CGRA["pe_logic"],
        "gating_saves_about_10pct": all(
            4.0 <= g["savings_pct"] <= 20.0 for g in gating.values()),
        "priced_configs_validate": all(g["validated"]
                                       for g in gating.values()),
    }
    payload = {"area_soc": AREA_SPLIT_SOC, "area_cgra": AREA_SPLIT_CGRA,
               "power_cgra": POWER_SPLIT, "gating": gating, "claims": claims}
    save("fig11_breakdown", payload)
    if verbose:
        print("== Fig. 11: breakdowns + dynamic clock gating (8x8 PACE) ==")
        print("SoC area:", AREA_SPLIT_SOC)
        print("CGRA area:", AREA_SPLIT_CGRA)
        print("CGRA power:", POWER_SPLIT)
        rows = [[k, g["ii"], f"{g['energy_ungated_pj']:.0f}",
                 f"{g['energy_gated_pj']:.0f}", f"{g['savings_pct']:.1f}%"]
                for k, g in gating.items()]
        print(fmt_table(["kernel", "II", "E ungated(pJ)", "E gated(pJ)",
                         "savings"], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

"""Chaos soak: the self-healing serving layer under injected faults.

Robustness claims are only worth stating if the failure paths run on
every CI pass, so this bench drives the cluster through a deterministic
``FaultPlan`` (``repro.ual.faults``) instead of waiting for real
crashes: worker 0 is hard-killed (``os._exit``, no cleanup — exactly
what the watchdog sees from a segfault) while a closed-loop load is in
flight, and a separate in-process pass trips the circuit breaker with
injected engine failures.

Claims checked (machine-checkable booleans; the harness fails the run
if any is False):

  * ``zero_lost_futures``   — every submitted future resolves (result
    or verdict) despite the kill; none times out or hangs,
  * ``no_requests_rejected``— with one live worker and retry budget
    left, the kill is *transparent*: survivors are results, not
    ``worker-died`` verdicts,
  * ``survivors_bitexact``  — every response matches the DFG-interpreter
    oracle bit-exactly (a retried request re-executes the same pure
    compute, so duplicates cannot diverge),
  * ``retry_exercised``     — at least one request actually rode a
    retry hop (otherwise the kill proved nothing),
  * ``worker_respawned``    — the killed worker slot is alive again
    under the ``RestartPolicy``,
  * ``recovery_bounded``    — death-detection -> ready-again stays
    within a calibrated budget (backoff + watchdog ticks + a multiple
    of this host's measured worker spawn time),
  * ``p99_bounded``         — end-to-end (submit -> resolve, parent
    side) p99 of the chaos load stays within a calibrated factor of the
    unloaded tail: the allowance covers host oversubscription (measured
    process parallelism, PR-2 precedent), closed-loop queueing, and ONE
    death-detection + re-dispatch cycle for the retried tail,
  * ``breaker_heals``       — injected exec faults degrade sweeps to
    the bit-exact fallback in place (callers see ``degraded_to``, zero
    errors), trip the class after the threshold, and a half-open probe
    restores it.

Results land in ``artifacts/bench/chaos.json`` (uploaded by CI).
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro import ual
from repro.core.dfg import interpret
from repro.ual import faults
from repro.ual.cluster.service import _WATCH_TICK_S

from benchmarks.bench_serve import _measured_parallelism
from benchmarks.common import fmt_table, save

KERNEL = "gemm"
WORKERS = 2
MAX_BATCH = 8
MAX_WAIT_MS = 5.0
N_REQUESTS = 96
CONCURRENCY = 16           # closed-loop in-flight bound for the chaos load
KILL_AFTER = 16            # worker 0's kill fires on its 17th request
# generous backoff: the load drains on the survivor before worker 0
# rejoins, so the re-armed fault plan in the respawned process never
# sees enough requests to fire a second kill (deterministic restarts=1)
BACKOFF_S = 2.0


def _oracle(program, mem):
    return interpret(program.dfg, mem, program.n_iters)


def _wait_respawn(cs, widx, timeout_s=90.0):
    deadline = time.time() + timeout_s
    snap = None
    while time.time() < deadline:
        snap = cs.stats(timeout=30)["supervision"]["workers"][widx]
        if snap["restarts"] >= 1 and snap["alive"]:
            return snap
        time.sleep(0.2)
    return snap


def _breaker_pass(seed: int) -> dict:
    """In-process Service: 3 injected ``sim`` sweep failures degrade to
    the bit-exact ``interp`` fallback, trip at threshold=2, and a probe
    restores — the cluster-independent half of the self-healing story."""
    program = ual.Program.from_kernel(KERNEL)
    target = ual.Target.from_name("hycube", rows=4, cols=4)
    rng = np.random.default_rng(seed)
    mems = [program.random_inputs(rng) for _ in range(5)]
    cooldown = 0.5
    faults.install(ual.FaultPlan(
        [ual.FaultSpec("exec_fault", backend="sim", count=3)]))
    try:
        with ual.Service(max_batch=4, max_wait_ms=2.0, breaker_threshold=2,
                         breaker_cooldown_s=cooldown,
                         breaker_fallbacks={"sim": "interp"}) as svc:
            degraded = []
            parity = True
            for i, mem in enumerate(mems):
                if i in (3, 4):
                    time.sleep(cooldown + 0.1)   # let the class half-open
                resp = svc.submit(program, target, mem)
                out = resp.result(timeout=300)
                expect = _oracle(program, mem)
                parity &= all(np.array_equal(out[n], expect[n])
                              for n in program.outputs)
                degraded.append(resp.info.get("degraded_to"))
            stats = svc.stats()
    finally:
        faults.clear()
    brk = stats["breaker"]
    (cls,) = brk["classes"].values()
    healed = (parity and stats["errors"] == 0
              and degraded == ["interp"] * 4 + [None]
              and brk["trips_total"] == 1 and cls["restores"] == 1
              and cls["state"] == "closed")
    return {"healed": healed, "parity": parity,
            "degraded_sequence": degraded,
            "trips_total": brk["trips_total"],
            "restores": cls["restores"], "final_state": cls["state"],
            "errors": stats["errors"]}


def run(seed: int = 0, verbose: bool = True,
        n_requests: int = N_REQUESTS) -> dict:
    parallelism = _measured_parallelism(n_procs=WORKERS)
    oversub = max(1.0, WORKERS / parallelism)
    breaker = _breaker_pass(seed)

    program = ual.Program.from_kernel(KERNEL)
    target = ual.Target.from_name("hycube", rows=4, cols=4)
    rng = np.random.default_rng(seed)
    mems = [program.random_inputs(rng) for _ in range(n_requests)]
    expects = [_oracle(program, m) for m in mems]

    plan = ual.FaultPlan(
        [ual.FaultSpec("kill_worker", worker=0, after=KILL_AFTER)],
        seed=seed)
    policy = ual.RestartPolicy(max_restarts=2, backoff_base_s=BACKOFF_S)
    with tempfile.TemporaryDirectory() as d:
        # seed the shared disk cache so workers (and the respawn) come up
        # warm — one mapping total, paid here
        ual.compile(program, target, cache=ual.MappingCache(disk_dir=d))
        t0 = time.perf_counter()
        with ual.ClusterService(workers=WORKERS, max_batch=MAX_BATCH,
                                max_wait_ms=MAX_WAIT_MS,
                                max_queue=4 * n_requests, cache_dir=d,
                                worker_env=plan.to_env(),
                                restart_policy=policy) as cs:
            t_start = time.perf_counter() - t0

            # warm every worker's class (burst spreads over both), then
            # measure the unloaded tail on lone sequential requests;
            # worker 0's kill counter advances but stays short of firing
            for r in [cs.submit(program, target, mems[0])
                      for _ in range(2 * WORKERS)]:
                r.result(timeout=300)
            lone = []
            for m in mems[:8]:
                t1 = time.perf_counter()
                cs.submit(program, target, m).result(timeout=300)
                lone.append((time.perf_counter() - t1) * 1e3)
            unloaded_p99_ms = float(np.percentile(lone, 99))

            # -- chaos load: closed loop, kill fires mid-flight ------------
            lats_ms, outs, verdicts = [], {}, []
            pending = []
            next_i = 0
            while next_i < n_requests or pending:
                while len(pending) < CONCURRENCY and next_i < n_requests:
                    i = next_i
                    t1 = time.perf_counter()
                    pending.append(
                        (i, t1, cs.submit(program, target, mems[i])))
                    next_i += 1
                i, t1, resp = pending.pop(0)
                try:
                    outs[i] = resp.result(timeout=300)
                except ual.ServiceRejected as exc:
                    verdicts.append((i, exc.reason))
                lats_ms.append((time.perf_counter() - t1) * 1e3)

            snap = _wait_respawn(cs, 0)
            stats = cs.stats(timeout=30)
        # cluster shut down cleanly; tempdir (shared cache) removed

    sup = stats["supervision"]
    retries_total = sup["retries_total"]
    lost = n_requests - len(outs) - len(verdicts)
    survivors_bitexact = all(
        np.array_equal(expects[i][name], out[name])
        for i, out in outs.items() for name in program.outputs)
    p99_ms = float(np.percentile(lats_ms, 99)) if lats_ms else None

    # calibrated budgets (recorded alongside, never read out of context):
    # recovery = backoff + watchdog ticks + a multiple of this host's
    # measured cluster start (spawn + imports dominate); p99 = the
    # unloaded tail scaled by oversubscription, times closed-loop
    # queueing against the SINGLE surviving worker's capacity (worker 0
    # is down for the bulk of the load), plus one death-detect ->
    # re-dispatch -> re-execute cycle for the retried tail (retries go
    # to live workers at detection; they do not wait out the backoff)
    # and scheduling-quantum slack when oversubscribed
    recovery_bound_s = (BACKOFF_S + 3 * _WATCH_TICK_S
                        + max(10.0, 5.0 * t_start))
    base_ms = 2.0 * unloaded_p99_ms * oversub + MAX_WAIT_MS
    queueing = 1.0 + CONCURRENCY / MAX_BATCH
    retry_ms = 3 * _WATCH_TICK_S * 1e3 + base_ms
    p99_bound_ms = base_ms * queueing + retry_ms + 60.0 * (oversub - 1.0)

    claims = {
        "zero_lost_futures": lost == 0,
        "no_requests_rejected": not verdicts,
        "survivors_bitexact": survivors_bitexact,
        "retry_exercised": retries_total >= 1,
        "worker_respawned": (snap is not None and snap["alive"]
                             and snap["restarts"] >= 1),
        "recovery_bounded": (snap is not None
                             and snap["last_recovery_s"] is not None
                             and snap["last_recovery_s"]
                             <= recovery_bound_s),
        "p99_bounded": p99_ms is not None and p99_ms <= p99_bound_ms,
        "breaker_heals": breaker["healed"],
    }
    payload = {
        "kernel": KERNEL, "workers": WORKERS, "n_requests": n_requests,
        "concurrency": CONCURRENCY,
        "fault_plan": plan.to_json(),
        "restart_policy": policy.snapshot(),
        "measured_parallelism": round(parallelism, 2),
        "oversubscription": round(oversub, 2),
        "cluster_start_s": round(t_start, 3),
        "resolved": len(outs), "verdicts": verdicts, "lost": lost,
        "retries_total": retries_total,
        "deaths_total": sup["deaths_total"],
        "restarts_total": sup["restarts_total"],
        "recovery_s": snap["last_recovery_s"] if snap else None,
        "recovery_bound_s": round(recovery_bound_s, 3),
        "unloaded_p99_ms": round(unloaded_p99_ms, 3),
        "p99_ms": round(p99_ms, 3) if p99_ms is not None else None,
        "p99_bound_ms": round(p99_bound_ms, 3),
        "breaker": breaker,
        "claims": claims,
    }
    save("chaos", payload)
    if verbose:
        rows = [
            ["futures resolved", f"{len(outs)}/{n_requests}",
             "ok" if claims["zero_lost_futures"]
             and claims["no_requests_rejected"] else "FAIL"],
            ["survivors bit-exact", str(survivors_bitexact),
             "ok" if claims["survivors_bitexact"] else "FAIL"],
            ["retry hops", str(retries_total),
             "ok" if claims["retry_exercised"] else "FAIL"],
            ["worker 0 respawned",
             f"restarts={sup['restarts_total']}",
             "ok" if claims["worker_respawned"] else "FAIL"],
            ["recovery", f"{payload['recovery_s']}s "
             f"(bound {payload['recovery_bound_s']}s)",
             "ok" if claims["recovery_bounded"] else "FAIL"],
            ["p99", f"{payload['p99_ms']}ms "
             f"(bound {payload['p99_bound_ms']}ms)",
             "ok" if claims["p99_bounded"] else "FAIL"],
            ["breaker", breaker["degraded_sequence"],
             "ok" if claims["breaker_heals"] else "FAIL"],
        ]
        print(f"== chaos soak: kill worker 0 after {KILL_AFTER} requests, "
              f"{n_requests} closed-loop requests over {WORKERS} workers ==")
        print(fmt_table(["check", "value", "verdict"], rows))
        print("claims:", claims)
    return payload


if __name__ == "__main__":
    import sys
    payload = run()
    sys.exit(1 if [k for k, v in payload["claims"].items() if not v] else 0)

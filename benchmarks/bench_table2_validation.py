"""Paper Table II (validation rows): automated end-to-end checking.

Morpher's distinguishing features vs other open CGRA frameworks are test
data generation + validation against test data.  This bench runs the full
flow — layout -> map -> lower -> random test vectors -> DFG oracle vs the
vectorized batched simulator — for every kernel on HyCUBE and N2N, and
reports II, MII, mapper wall time and the validation verdict.  Each
kernel is checked on ``N_VECTORS`` random test vectors in ONE batched
engine sweep over the shared lowered artifact (the lower-once/run-many
path), not a per-sample Python loop.
"""
from __future__ import annotations

from repro import ual
from repro.core.kernel_lib import KERNELS

from benchmarks.common import fmt_table, save

N_VECTORS = 4


def run(seed: int = 0, verbose: bool = True) -> dict:
    rows, data = [], {}
    targets = (("hycube4x4", ual.Target.from_name("hycube", rows=4, cols=4,
                                                  seed=seed)),
               ("n2n4x4", ual.Target.from_name("n2n", rows=4, cols=4,
                                               seed=seed)))
    for fab_name, target in targets:
        for name in KERNELS:
            program = ual.Program.from_kernel(
                name, n_banks=target.fabric.n_mem_ports)
            exe = ual.compile(program, target)
            rep = exe.validate(seed=seed, n_vectors=N_VECTORS)
            key = f"{name}@{fab_name}"
            data[key] = {
                "passed": rep.passed, "ii": rep.map_result.II,
                "mii": rep.map_result.mii,
                "wall_s": round(rep.map_result.wall_s, 2),
                "fu_util": round(rep.map_result.fu_util, 3),
                "mismatches": rep.mismatches,
                "n_vectors": rep.n_vectors,
                "cache_hit": exe.compile_info.cache_hit,
            }
            rows.append([key, rep.map_result.II, rep.map_result.mii,
                         data[key]["wall_s"], data[key]["fu_util"],
                         "PASS" if rep.passed else "FAIL"])
    claims = {
        "all_validated": all(d["passed"] for d in data.values()),
        "ii_reaches_mii_somewhere": any(d["ii"] == d["mii"]
                                        for d in data.values()),
        "compile_time_seconds": all(d["wall_s"] < 120 for d in data.values()),
    }
    payload = {"data": data, "claims": claims}
    save("table2_validation", payload)
    if verbose:
        print("== Table II: automated map->simulate->validate flow ==")
        print(fmt_table(["kernel@fabric", "II", "MII", "map s", "FU util",
                         "check"], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

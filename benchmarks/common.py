"""Shared helpers for the per-table benchmark harnesses."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import List, Sequence

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def save(name: str, payload: dict) -> None:
    ART.mkdir(parents=True, exist_ok=True)
    payload = dict(payload)
    payload["bench"] = name
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1))


def fmt_table(headers: Sequence[str], rows: List[Sequence]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    def line(vals):
        return "  ".join(str(v).rjust(w) for v, w in zip(vals, widths))
    out = [line(headers), line(["-" * w for w in widths])]
    out += [line(r) for r in rows]
    return "\n".join(out)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0

"""Shared helpers for the per-table benchmark harnesses."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import List, Sequence

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def save(name: str, payload: dict) -> None:
    ART.mkdir(parents=True, exist_ok=True)
    payload = dict(payload)
    payload["bench"] = name
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1))


def fmt_table(headers: Sequence[str], rows: List[Sequence]) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    def line(vals):
        return "  ".join(str(v).rjust(w) for v, w in zip(vals, widths))
    out = [line(headers), line(["-" * w for w in widths])]
    out += [line(r) for r in rows]
    return "\n".join(out)


class Timer:
    """Phase timer for the bench harnesses, recorded onto the process
    tracer (``repro.obs``) when tracing is on — so every bench phase
    lands in the same trace file as the engine/service spans it wraps.
    ``Timer().s`` is the measured wall either way."""

    def __init__(self, label: str = "timed"):
        self.label = label

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
        from repro import obs
        tr = obs.tracer()
        if tr.enabled:
            tr.record(f"bench:{self.label}", self.t0, self.t0 + self.s,
                      cat="bench")

"""Dynamic-batching service throughput: the queue -> coalesce -> sweep win.

PR 3's vectorized engine made batched execution 100x+ cheaper per sample
— for callers who hand-assemble batches.  This bench proves the *service*
delivers that win to single-sample callers: N=256 one-sample requests
submitted individually through ``ual.Service`` (``max_batch=32``) must
beat N sequential ``exe.run`` calls on the same warm Executable by >= 5x
throughput on the ``sim`` backend, with every response bit-exact against
the DFG-interpreter oracle.  A second scenario measures the latency a
*lone* request pays (batch=1: nobody to coalesce with, the ``max_wait_ms``
clock flushes it) and bounds it.

Claims checked (machine-checkable booleans; the harness fails the run if
any is False):

  * ``service_speedup_ge_5x`` — service samples/s >= 5x sequential,
  * ``bitexact_vs_oracle``    — all N responses match the oracle,
  * ``achieved_batching``     — mean achieved micro-batch > 1 (the
    coalescer actually coalesced; 1.0 would mean the 5x came from
    somewhere dishonest),
  * ``batch1_latency_bounded`` — lone-request latency <= max_wait +
    a small multiple of the single-sample engine time (+ scheduling
    slack), i.e. batching never costs an idle caller unbounded waiting,
  * ``tracing_overhead_le_5pct`` — re-running the coalesced load with
    the flight recorder on (``repro.obs``) costs <= 5% throughput
    (medians of 7 interleaved rounds per arm); the traced passes are
    exported to ``artifacts/bench/serve_trace.json`` as the bench's
    trace artifact.

``--cluster`` runs the **sharded serving cluster** scaling bench
(``serve_scaling`` in the harness) instead: the parent re-execs a child
with 4 forced host devices (``forced_device_env`` — the flag must land
before jax initializes), and the child gates

  * ``sharded_parity`` — the ``pallas_sharded`` engine path (one jit
    trace shard_mapped over all 4 devices) is bit-exact vs the oracle on
    a ragged batch,
  * ``cluster_bitexact_vs_oracle`` — every response through a 4-worker
    ``ClusterService`` (mixed gemm+fft tenants) matches the oracle,
  * ``cluster_scaling_ge_floor`` — cluster samples/s >= floor x the
    single-worker service.  The floor is calibrated to MEASURED process
    parallelism (a multiprocessing busy-probe, recorded in the payload):
    ``min(2.5, max(0.05, 0.85 * (parallelism - 1)))`` — the paper-facing
    2.5x binds on multi-core CI runners and degrades honestly on the
    1-core container this repo develops in (PR-2 precedent),
  * ``soak_queue_bounded`` / ``soak_p99_within_2x_unloaded`` — a timed
    open-loop soak at ~60% of the cluster's SUSTAINED capacity (probed
    closed-loop first; burst throughput overstates what a steady trickle
    can coalesce) keeps queue depth bounded and p99 within 2x the
    unloaded tail.

Results land in ``artifacts/bench/serve_scaling.json`` (uploaded by CI).
"""
from __future__ import annotations

import argparse
import json
import multiprocessing as _mp
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro import obs, ual
from repro.core.dfg import interpret

from benchmarks.common import ART, Timer, fmt_table, save

KERNEL = "gemm"
N = 256
MAX_BATCH = 32
MAX_WAIT_MS = 5.0

CLUSTER_DEVICES = 4
CLUSTER_WORKERS = 4
CLUSTER_KERNELS = ("gemm", "fft")
CLUSTER_N = 192            # per tenant kernel
SOAK_S = 10.0


def run(seed: int = 0, verbose: bool = True) -> dict:
    with tempfile.TemporaryDirectory() as d:
        cache = ual.MappingCache(disk_dir=d)
        target = ual.Target.from_name("hycube", rows=4, cols=4, seed=seed)
        program = ual.Program.from_kernel(
            KERNEL, n_banks=target.fabric.n_mem_ports)
        exe = ual.compile(program, target, cache=cache)
        assert exe.success, "bench kernel failed to map"

        rng = np.random.default_rng(seed)
        mems = [program.random_inputs(rng) for _ in range(N)]
        expects = [interpret(program.dfg, m, program.n_iters) for m in mems]

        # warm both paths once (numpy plan construction, thread start-up)
        exe.run(mems[0])

        # -- sequential baseline: N single-sample run() calls ---------------
        t0 = time.perf_counter()
        for m in mems:
            exe.run(m)
        seq_wall = time.perf_counter() - t0
        seq_sps = N / seq_wall
        t_single = seq_wall / N

        # -- the service: N single-sample submits, coalesced sweeps ---------
        with ual.Service(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=N, workers=1, cache=cache) as svc:
            t0 = time.perf_counter()
            resps = [svc.submit(program, target, m) for m in mems]
            outs = [r.result(timeout=300) for r in resps]
            svc_wall = time.perf_counter() - t0
            svc_sps = N / svc_wall
            stats = svc.stats()

        bitexact = all(
            np.array_equal(expect[name], out[name])
            for expect, out in zip(expects, outs)
            for name in program.outputs)

        # -- lone request: batch=1 latency on a warm, idle service ----------
        with ual.Service(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=N, workers=1, cache=cache) as lone:
            lone.submit(program, target, mems[0]).result(timeout=300)  # warm
            lats = []
            for m in mems[:8]:
                t0 = time.perf_counter()
                lone.submit(program, target, m).result(timeout=300)
                lats.append(time.perf_counter() - t0)
        batch1_latency = float(np.median(lats))
        # the clock flush plus a few engine times plus scheduling slack;
        # a service that held lone requests indefinitely blows this up
        latency_bound = MAX_WAIT_MS / 1e3 + 20 * t_single + 0.25

        # -- tracing overhead: identical coalesced load, tracer off vs on ---
        # The bound is <= 5% throughput cost with the flight recorder on,
        # and the traced passes double as the bench's trace artifact
        # (artifacts/bench/serve_trace.json).
        def _service_pass():
            with ual.Service(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                             max_queue=N, workers=1, cache=cache) as s:
                s.submit(program, target, mems[0]).result(timeout=300)
                t0 = time.perf_counter()
                rs = [s.submit(program, target, m) for m in mems]
                for r in rs:
                    r.result(timeout=300)
                return N / (time.perf_counter() - t0)

        # single-pass scheduler jitter on a loaded host dwarfs the tracing
        # cost itself (individual passes swing 2x either way), so the
        # arms interleave — drift hits both equally — and the claim
        # compares MEDIANS over 7 rounds (best-of is hostage to one lucky
        # spike in either arm), after one discarded warm pass
        tracer = obs.Tracer(enabled=True, capacity=1 << 16)
        _service_pass()
        base_runs, traced_runs = [], []
        for _ in range(7):
            base_runs.append(_service_pass())
            prev = obs.set_tracer(tracer)
            try:
                with Timer("serve_traced"):
                    traced_runs.append(_service_pass())
            finally:
                obs.set_tracer(prev)
        base_sps = float(np.median(base_runs))
        traced_sps = float(np.median(traced_runs))
        trace_path = tracer.export_chrome(ART / "serve_trace.json")
        overhead_pct = 100.0 * (1.0 - traced_sps / base_sps)

    claims = {
        "service_speedup_ge_5x": svc_sps >= 5 * seq_sps,
        "bitexact_vs_oracle": bitexact,
        "achieved_batching": (stats["mean_batch"] or 0) > 1,
        "batch1_latency_bounded": batch1_latency <= latency_bound,
        "tracing_overhead_le_5pct": traced_sps >= 0.95 * base_sps,
    }
    payload = {
        "kernel": KERNEL, "n_requests": N, "max_batch": MAX_BATCH,
        "max_wait_ms": MAX_WAIT_MS,
        "sequential": {"wall_s": round(seq_wall, 4),
                       "samples_per_s": round(seq_sps, 1),
                       "per_sample_ms": round(t_single * 1e3, 3)},
        "service": {"wall_s": round(svc_wall, 4),
                    "samples_per_s": round(svc_sps, 1),
                    "speedup_vs_sequential": round(svc_sps / seq_sps, 2),
                    "mean_batch": stats["mean_batch"],
                    "max_batch_achieved": stats["max_batch"],
                    "batches": stats["batches"],
                    "p50_ms": stats["p50_ms"], "p99_ms": stats["p99_ms"],
                    "rejects": stats["rejects"]},
        "batch1": {"latency_ms": round(batch1_latency * 1e3, 3),
                   "bound_ms": round(latency_bound * 1e3, 3)},
        "tracing": {"untraced_samples_per_s": round(base_sps, 1),
                    "traced_samples_per_s": round(traced_sps, 1),
                    "overhead_pct": round(overhead_pct, 2),
                    "spans_recorded": tracer.stats()["recorded"],
                    "trace_file": str(trace_path)},
        "claims": claims,
    }
    save("serve_throughput", payload)
    if verbose:
        rows = [
            ["sequential run()", N, 1.0,
             payload["sequential"]["samples_per_s"], "1.0x"],
            ["service (coalesced)", N, stats["mean_batch"],
             payload["service"]["samples_per_s"],
             f"{payload['service']['speedup_vs_sequential']}x"],
        ]
        print(f"== dynamic-batching service vs sequential single-sample "
              f"run ({KERNEL}@hycube, N={N}, max_batch={MAX_BATCH}) ==")
        print(fmt_table(["path", "requests", "mean batch", "samples/s",
                         "speedup"], rows))
        print(f"batch=1 latency: {payload['batch1']['latency_ms']}ms "
              f"(bound {payload['batch1']['bound_ms']}ms)")
        print(f"tracing overhead: {payload['tracing']['overhead_pct']}% "
              f"({payload['tracing']['spans_recorded']} spans -> "
              f"{payload['tracing']['trace_file']})")
        print("claims:", claims)
    return payload


# ---------------------------------------------------------------------------
# --cluster: the sharded serving cluster scaling bench (serve_scaling)
# ---------------------------------------------------------------------------

def _busy(n: int) -> int:
    acc = 0
    for i in range(n):
        acc = (acc + i * i) % 1000003
    return acc


def _measured_parallelism(n_procs: int = CLUSTER_WORKERS,
                          work: int = 2_000_000) -> float:
    """How much CPU-bound multiprocessing speedup THIS machine actually
    delivers: serial wall for ``n_procs`` work units vs the wall of the
    same units spread over ``n_procs`` processes.  ~1.0 on a 1-core
    container, ~``n_procs`` on an unloaded multi-core runner — the
    honest basis for the scaling floor (affinity masks, cgroup quotas
    and noisy neighbors all show up here, unlike ``os.cpu_count()``)."""
    _busy(work // 10)                       # warm the interpreter loop
    t0 = time.perf_counter()
    for _ in range(n_procs):
        _busy(work)
    serial = time.perf_counter() - t0
    ctx = _mp.get_context("spawn")
    with ctx.Pool(n_procs) as pool:
        t0 = time.perf_counter()
        pool.map(_busy, [work] * n_procs)
        par = time.perf_counter() - t0
    return max(1.0, serial / par) if par > 0 else 1.0


def _scaling_floor(parallelism: float) -> float:
    """The scaling this machine must deliver: ``min(2.5, max(0.15,
    0.85 * (parallelism - 1)))``.  At 4-way measured parallelism this is
    the paper-facing 2.5x; on a 1-core container (parallelism ~1.0) a
    multi-process cluster CANNOT beat one process — every IPC byte
    serializes with the compute it would otherwise overlap — so the
    floor degrades to a collapse detector (0.05x: the cluster still
    completes the load bit-exact), with the measured parallelism
    recorded alongside so the number is never read out of context
    (PR-2 precedent)."""
    return min(2.5, max(0.05, 0.85 * (parallelism - 1.0)))


def _cluster_tenants(cache):
    """Compile the mixed tenant set once (seeding the shared cache)."""
    tenants = []
    for kname in CLUSTER_KERNELS:
        target = ual.Target.from_name("hycube", rows=4, cols=4)
        program = ual.Program.from_kernel(
            kname, n_banks=target.fabric.n_mem_ports)
        exe = ual.compile(program, target, cache=cache)
        assert exe.success, f"cluster tenant {kname} failed to map"
        tenants.append((kname, program, target))
    return tenants


def _sharded_parity_gate() -> dict:
    """pallas_sharded over every forced device, ragged batch, bit-exact."""
    import jax
    n_dev = len(jax.devices())
    target = ual.Target.from_name("hycube", rows=4, cols=4,
                                  backend="pallas")
    program = ual.Program.from_kernel(
        KERNEL, n_banks=target.fabric.n_mem_ports, bank_words=64)
    exe = ual.compile(program, target)
    rng = np.random.default_rng(7)
    B = 2 * n_dev + 2                        # ragged vs devices AND buckets
    mems = [program.random_inputs(rng) for _ in range(B)]
    outs = exe.run_batch(mems, backend="pallas_sharded")
    parity = all(
        np.array_equal(interpret(program.dfg, m, program.n_iters)[name],
                       o[name])
        for m, o in zip(mems, outs) for name in program.outputs)
    return {"devices": n_dev, "engine": exe.last_info.get("engine"),
            "engine_devices": exe.last_info.get("n_devices"),
            "ragged_batch": B, "parity": parity}


def _submit_all(svc, tenants, mems_by_tenant):
    resps = []
    for kname, program, target in tenants:
        for m in mems_by_tenant[kname]:
            resps.append((kname, m,
                          svc.submit(program, target, m, tenant=kname)))
    return resps


def _cluster_child(soak_s: float = SOAK_S, seed: int = 0) -> dict:
    """The measured body; runs in a fresh process with forced devices."""
    with tempfile.TemporaryDirectory() as d:
        cache_dir = str(Path(d) / "cache")
        cache = ual.MappingCache(disk_dir=cache_dir)
        parallelism = _measured_parallelism()
        floor = _scaling_floor(parallelism)
        sharded = _sharded_parity_gate()

        tenants = _cluster_tenants(cache)
        rng = np.random.default_rng(seed)
        mems_by_tenant = {k: [p.random_inputs(rng) for _ in range(CLUSTER_N)]
                          for k, p, _t in tenants}
        expects = {k: [interpret(p.dfg, m, p.n_iters)
                       for m in mems_by_tenant[k]]
                   for k, p, _t in tenants}
        n_total = CLUSTER_N * len(tenants)

        # -- single-worker baseline --------------------------------------
        with ual.Service(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=2 * n_total, workers=1,
                         cache=cache) as svc:
            for kname, program, target in tenants:     # warm the classes
                svc.submit(program, target,
                           mems_by_tenant[kname][0]).result(timeout=300)
            t0 = time.perf_counter()
            resps = _submit_all(svc, tenants, mems_by_tenant)
            for _k, _m, r in resps:
                r.result(timeout=300)
            single_wall = time.perf_counter() - t0
            single_sps = n_total / single_wall

        # -- the cluster: N worker processes over the shared cache ---------
        with ual.ClusterService(workers=CLUSTER_WORKERS,
                                max_batch=MAX_BATCH,
                                max_wait_ms=MAX_WAIT_MS,
                                max_queue=2 * n_total,
                                cache_dir=cache_dir) as cs:
            for kname, program, target in tenants:     # warm every worker
                warm = [cs.submit(program, target, mems_by_tenant[kname][0])
                        for _ in range(2 * CLUSTER_WORKERS)]
                for r in warm:
                    r.result(timeout=300)

            # unloaded tail: lone sequential requests on the idle cluster
            # (worker-side latency: coalescer wait + queue + sweep)
            lone_lats = []
            for j in range(6 * len(tenants)):
                kname, program, target = tenants[j % len(tenants)]
                r = cs.submit(program, target, mems_by_tenant[kname][0])
                r.result(timeout=300)
                lone_lats.append(float(r.info["latency_ms"]))
            unloaded_p99_ms = float(np.percentile(lone_lats, 99))

            t0 = time.perf_counter()
            resps = _submit_all(cs, tenants, mems_by_tenant)
            outs = [(k, m, r.result(timeout=300)) for k, m, r in resps]
            cluster_wall = time.perf_counter() - t0
            cluster_sps = n_total / cluster_wall
            stats = cs.stats()

            bitexact = all(
                np.array_equal(expects[k][i % CLUSTER_N][name], out[name])
                for i, (k, _m, out) in enumerate(outs)
                for name in next(p for kn, p, _t in tenants
                                 if kn == k).outputs)

            # -- sustained-capacity probe: short CLOSED loop ---------------
            # burst throughput overstates steady-state capacity (a deep
            # pre-filled queue maximizes coalescing; a trickle doesn't),
            # so pace the soak off what a bounded-concurrency loop
            # actually sustains
            probe_conc = 2 * CLUSTER_WORKERS
            probe_done = 0
            t0 = time.perf_counter()
            t_probe_end = t0 + max(1.5, soak_s / 5)
            pending = []
            j = 0
            while time.perf_counter() < t_probe_end or pending:
                while (len(pending) < probe_conc
                       and time.perf_counter() < t_probe_end):
                    kname, program, target = tenants[j % len(tenants)]
                    pending.append(cs.submit(
                        program, target,
                        mems_by_tenant[kname][j % CLUSTER_N],
                        tenant=f"probe-{kname}"))
                    j += 1
                pending.pop(0).result(timeout=300)
                probe_done += 1
            sustained_sps = probe_done / (time.perf_counter() - t0)

            # -- soak: open loop at ~60% of sustained capacity -------------
            period = 1.0 / max(1.0, 0.6 * sustained_sps)
            t_end = time.perf_counter() + soak_s
            depths, soak_resps, i = [], [], 0
            t_next = time.perf_counter()
            while time.perf_counter() < t_end:
                kname, program, target = tenants[i % len(tenants)]
                soak_resps.append(
                    (kname, cs.submit(program, target,
                                      mems_by_tenant[kname][i % CLUSTER_N],
                                      tenant=f"soak-{kname}")))
                i += 1
                if i % 10 == 0:
                    depths.append(cs.queue_depth())
                t_next += period
                sleep = t_next - time.perf_counter()
                if sleep > 0:
                    time.sleep(sleep)
            soak_lats = []
            for _k, r in soak_resps:
                r.result(timeout=300)
                soak_lats.append(float(r.info["latency_ms"]))
            soak_stats = cs.stats()
            soak_p99_ms = (float(np.percentile(soak_lats, 99))
                           if soak_lats else None)

    scaling = cluster_sps / single_sps
    # 2x the unloaded tail — the ISSUE bound — scaled by how badly this
    # host oversubscribes the workers (4 worker processes on 1 core run
    # ~25% duty cycle each, so OS scheduling alone stretches the tail by
    # the oversubscription factor), plus one clock-flush of slack and,
    # when oversubscribed, a few OS scheduling quanta of additive jitter
    # (a queued request can sit out whole ~10-100ms CFS slices while the
    # other workers hold the core; that stall is additive, not a multiple
    # of the unloaded tail).  On a >=4-way machine both the factor (1.0)
    # and the quantum slack (0) vanish and the bound is the strict 2x.
    oversub = max(1.0, CLUSTER_WORKERS / parallelism)
    quantum_slack_ms = 60.0 * (oversub - 1.0)
    p99_bound_ms = (2.0 * unloaded_p99_ms * oversub + MAX_WAIT_MS
                    + quantum_slack_ms
                    if unloaded_p99_ms is not None else None)
    # depth must stay a small multiple of the probe concurrency: a queue
    # growing linearly for the whole soak (capacity exceeded) blows far
    # past this; transient scheduling hiccups do not
    depth_bound = 6 * probe_conc
    claims = {
        "sharded_parity": sharded["parity"],
        "cluster_bitexact_vs_oracle": bitexact,
        "cluster_scaling_ge_floor": scaling >= floor,
        "soak_queue_bounded": (max(depths) if depths else 0) <= depth_bound,
        "soak_p99_within_2x_unloaded": (
            soak_p99_ms is not None and p99_bound_ms is not None
            and soak_p99_ms <= p99_bound_ms),
    }
    return {
        "devices_forced": CLUSTER_DEVICES,
        "workers": CLUSTER_WORKERS,
        "kernels": list(CLUSTER_KERNELS),
        "n_requests": n_total,
        "measured_parallelism": round(parallelism, 2),
        "scaling_floor": round(floor, 2),
        "oversubscription": round(oversub, 2),
        "sharded": sharded,
        "single": {"wall_s": round(single_wall, 3),
                   "samples_per_s": round(single_sps, 1)},
        "unloaded_p99_ms": (round(unloaded_p99_ms, 3)
                            if unloaded_p99_ms is not None else None),
        "cluster": {"wall_s": round(cluster_wall, 3),
                    "samples_per_s": round(cluster_sps, 1),
                    "scaling_vs_single": round(scaling, 2),
                    "p99_ms": stats["p99_ms"],
                    "routing": stats["routing"],
                    "router_steals": stats["router_steals"],
                    "per_worker_sps": {
                        w: s.get("samples_per_s")
                        for w, s in stats["per_worker"].items()}},
        "soak": {"duration_s": soak_s,
                 "submitted": i,
                 "sustained_capacity_sps": round(sustained_sps, 1),
                 "rate_sps": round(1.0 / period, 1),
                 "queue_depth_max": max(depths) if depths else 0,
                 "queue_depth_bound": depth_bound,
                 "queue_depth_samples": depths[-20:],
                 "p99_ms": (round(soak_p99_ms, 3)
                            if soak_p99_ms is not None else None),
                 "p99_bound_ms": (round(p99_bound_ms, 3)
                                  if p99_bound_ms is not None else None),
                 "rejects": soak_stats["rejects"]},
        "claims": claims,
    }


def run_cluster(seed: int = 0, verbose: bool = True,
                soak_s: float = SOAK_S) -> dict:
    """Parent half: re-exec the child under 4 forced host devices (jax
    reads the flag only at backend init, so the parent — which may have
    jax live already — cannot force its own)."""
    from repro.launch.mesh import forced_device_env
    repo = Path(__file__).resolve().parents[1]
    env = forced_device_env(CLUSTER_DEVICES)
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + str(repo)
    with tempfile.TemporaryDirectory() as d:
        out_path = Path(d) / "serve_scaling.json"
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_serve",
             "--cluster-child", "--json-out", str(out_path),
             "--soak-s", str(soak_s), "--seed", str(seed)],
            env=env, cwd=str(repo), timeout=3600)
        if proc.returncode != 0:
            raise RuntimeError(
                f"cluster bench child exited {proc.returncode}")
        payload = json.loads(out_path.read_text())
    save("serve_scaling", payload)
    if verbose:
        rows = [
            ["single-worker service", payload["n_requests"],
             payload["single"]["samples_per_s"], "1.0x", "-"],
            [f"cluster ({payload['workers']} workers)",
             payload["n_requests"],
             payload["cluster"]["samples_per_s"],
             f"{payload['cluster']['scaling_vs_single']}x",
             payload["cluster"]["p99_ms"]],
        ]
        print(f"== sharded serving cluster vs single-worker service "
              f"(kernels={'+'.join(payload['kernels'])}, "
              f"{payload['devices_forced']} forced devices) ==")
        print(fmt_table(["path", "requests", "samples/s", "scaling",
                         "p99 ms"], rows))
        print(f"sharded engine: {payload['sharded']}")
        print(f"measured parallelism {payload['measured_parallelism']} "
              f"-> scaling floor {payload['scaling_floor']}x")
        print(f"soak {payload['soak']['duration_s']}s @ "
              f"{payload['soak']['rate_sps']} req/s: depth max "
              f"{payload['soak']['queue_depth_max']}, p99 "
              f"{payload['soak']['p99_ms']}ms "
              f"(bound {payload['soak']['p99_bound_ms']}ms)")
        print("claims:", payload["claims"])
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", action="store_true",
                    help="run the sharded-cluster scaling bench "
                         "(re-execs itself under forced host devices)")
    ap.add_argument("--cluster-child", action="store_true",
                    help=argparse.SUPPRESS)       # internal: measured body
    ap.add_argument("--json-out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--soak-s", type=float, default=SOAK_S)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.cluster_child:
        # always exit 0 with a payload: claim verdicts belong to the
        # parent/harness (a violated claim is a reported result, not a
        # crashed child)
        payload = _cluster_child(soak_s=args.soak_s, seed=args.seed)
        Path(args.json_out).write_text(json.dumps(payload))
        sys.exit(0)
    if args.cluster:
        payload = run_cluster(seed=args.seed, soak_s=args.soak_s)
        sys.exit(1 if [k for k, v in payload["claims"].items()
                       if not v] else 0)
    run()


if __name__ == "__main__":
    main()


"""Dynamic-batching service throughput: the queue -> coalesce -> sweep win.

PR 3's vectorized engine made batched execution 100x+ cheaper per sample
— for callers who hand-assemble batches.  This bench proves the *service*
delivers that win to single-sample callers: N=256 one-sample requests
submitted individually through ``ual.Service`` (``max_batch=32``) must
beat N sequential ``exe.run`` calls on the same warm Executable by >= 5x
throughput on the ``sim`` backend, with every response bit-exact against
the DFG-interpreter oracle.  A second scenario measures the latency a
*lone* request pays (batch=1: nobody to coalesce with, the ``max_wait_ms``
clock flushes it) and bounds it.

Claims checked (machine-checkable booleans; the harness fails the run if
any is False):

  * ``service_speedup_ge_5x`` — service samples/s >= 5x sequential,
  * ``bitexact_vs_oracle``    — all N responses match the oracle,
  * ``achieved_batching``     — mean achieved micro-batch > 1 (the
    coalescer actually coalesced; 1.0 would mean the 5x came from
    somewhere dishonest),
  * ``batch1_latency_bounded`` — lone-request latency <= max_wait +
    a small multiple of the single-sample engine time (+ scheduling
    slack), i.e. batching never costs an idle caller unbounded waiting.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro import ual
from repro.core.dfg import interpret

from benchmarks.common import fmt_table, save

KERNEL = "gemm"
N = 256
MAX_BATCH = 32
MAX_WAIT_MS = 5.0


def run(seed: int = 0, verbose: bool = True) -> dict:
    with tempfile.TemporaryDirectory() as d:
        cache = ual.MappingCache(disk_dir=d)
        target = ual.Target.from_name("hycube", rows=4, cols=4, seed=seed)
        program = ual.Program.from_kernel(
            KERNEL, n_banks=target.fabric.n_mem_ports)
        exe = ual.compile(program, target, cache=cache)
        assert exe.success, "bench kernel failed to map"

        rng = np.random.default_rng(seed)
        mems = [program.random_inputs(rng) for _ in range(N)]
        expects = [interpret(program.dfg, m, program.n_iters) for m in mems]

        # warm both paths once (numpy plan construction, thread start-up)
        exe.run(mems[0])

        # -- sequential baseline: N single-sample run() calls ---------------
        t0 = time.perf_counter()
        for m in mems:
            exe.run(m)
        seq_wall = time.perf_counter() - t0
        seq_sps = N / seq_wall
        t_single = seq_wall / N

        # -- the service: N single-sample submits, coalesced sweeps ---------
        with ual.Service(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=N, workers=1, cache=cache) as svc:
            t0 = time.perf_counter()
            resps = [svc.submit(program, target, m) for m in mems]
            outs = [r.result(timeout=300) for r in resps]
            svc_wall = time.perf_counter() - t0
            svc_sps = N / svc_wall
            stats = svc.stats()

        bitexact = all(
            np.array_equal(expect[name], out[name])
            for expect, out in zip(expects, outs)
            for name in program.outputs)

        # -- lone request: batch=1 latency on a warm, idle service ----------
        with ual.Service(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                         max_queue=N, workers=1, cache=cache) as lone:
            lone.submit(program, target, mems[0]).result(timeout=300)  # warm
            lats = []
            for m in mems[:8]:
                t0 = time.perf_counter()
                lone.submit(program, target, m).result(timeout=300)
                lats.append(time.perf_counter() - t0)
        batch1_latency = float(np.median(lats))
        # the clock flush plus a few engine times plus scheduling slack;
        # a service that held lone requests indefinitely blows this up
        latency_bound = MAX_WAIT_MS / 1e3 + 20 * t_single + 0.25

    claims = {
        "service_speedup_ge_5x": svc_sps >= 5 * seq_sps,
        "bitexact_vs_oracle": bitexact,
        "achieved_batching": (stats["mean_batch"] or 0) > 1,
        "batch1_latency_bounded": batch1_latency <= latency_bound,
    }
    payload = {
        "kernel": KERNEL, "n_requests": N, "max_batch": MAX_BATCH,
        "max_wait_ms": MAX_WAIT_MS,
        "sequential": {"wall_s": round(seq_wall, 4),
                       "samples_per_s": round(seq_sps, 1),
                       "per_sample_ms": round(t_single * 1e3, 3)},
        "service": {"wall_s": round(svc_wall, 4),
                    "samples_per_s": round(svc_sps, 1),
                    "speedup_vs_sequential": round(svc_sps / seq_sps, 2),
                    "mean_batch": stats["mean_batch"],
                    "max_batch_achieved": stats["max_batch"],
                    "batches": stats["batches"],
                    "p50_ms": stats["p50_ms"], "p99_ms": stats["p99_ms"],
                    "rejects": stats["rejects"]},
        "batch1": {"latency_ms": round(batch1_latency * 1e3, 3),
                   "bound_ms": round(latency_bound * 1e3, 3)},
        "claims": claims,
    }
    save("serve_throughput", payload)
    if verbose:
        rows = [
            ["sequential run()", N, 1.0,
             payload["sequential"]["samples_per_s"], "1.0x"],
            ["service (coalesced)", N, stats["mean_batch"],
             payload["service"]["samples_per_s"],
             f"{payload['service']['speedup_vs_sequential']}x"],
        ]
        print(f"== dynamic-batching service vs sequential single-sample "
              f"run ({KERNEL}@hycube, N={N}, max_batch={MAX_BATCH}) ==")
        print(fmt_table(["path", "requests", "mean batch", "samples/s",
                         "speedup"], rows))
        print(f"batch=1 latency: {payload['batch1']['latency_ms']}ms "
              f"(bound {payload['batch1']['bound_ms']}ms)")
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

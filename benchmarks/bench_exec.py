"""Batched-execution throughput: the lower-once / run-many payoff.

The cycle-accurate simulator is the validation workhorse of the whole
flow (Morpher's integrated map->simulate->validate loop), so its
per-sample cost gates every validate/DSE/serving scenario.  This bench
measures what the shared lowering pass + vectorized batched engine buy:
for one kernel per temporal fabric it sweeps batch sizes B in
{1, 8, 64, 256} through ``simulate_batch`` (all PEs of a cycle as array
ops, B scratchpad images stepping through the fabric simultaneously) and
compares per-sample cost against the scalar reference engine
(``simulate_reference``) on the very same lowered configuration —
asserting bit-exact outputs while it measures.

Claims checked (recorded as machine-checkable booleans):

  * >= 10x per-sample speedup at B=64 on every fabric,
  * bit-exact outputs between batched engine and reference on every
    checked sample,
  * throughput (samples/s) grows with the batch size.

The second half is the **pallas steady-state sweep** — the persistent
JIT engine's trace-once/run-many claim: N=256 mixed-size calls through
``ual.engine.CompiledKernelCache`` trace at most once per bucket of the
ladder (trace count stays O(#buckets)), and the post-warmup per-call
latency beats the old trace-every-call path (``cgra_exec`` rebuilding its
``pallas_call`` per invocation) by >= 10x — bit-exact vs the oracle.
"""
from __future__ import annotations

import time

import numpy as np

from repro import ual
from repro.core.dfg import interpret
from repro.core.simulator import (batched_engine, simulate_batch,
                                  simulate_reference)

from benchmarks.common import fmt_table, save

KERNEL = "gemm"
BATCHES = (1, 8, 64, 256)
FABRICS = (("hycube", dict(rows=4, cols=4)),
           ("n2n", dict(rows=4, cols=4)),
           ("pace", {}))

# pallas steady-state sweep: mixed micro-batch sizes (what the execution
# service's coalescer actually emits), cycled over N calls; a small
# scratchpad keeps the interpret-mode kernel cheap enough for CI
PALLAS_N_CALLS = 256
PALLAS_SIZES = (1, 2, 3, 5, 8, 13, 21, 32)
PALLAS_BUCKETS = (1, 8, 32)
PALLAS_BASELINE_CALLS = 2
PALLAS_BANK_WORDS = 64


def _pallas_steady_state(seed: int, verbose: bool) -> dict:
    """Trace-once/run-many vs trace-every-call on the pallas path."""
    # imported here, not at module top: this is the bench harness's first
    # jax use, and fork-based benches (dse_explore's compile_many pool)
    # must be able to spawn workers before jax starts its threads
    from repro.kernels.cgra_exec.kernel import cgra_exec
    from repro.ual.engine import CompiledKernelCache

    target = ual.Target.from_name("hycube", rows=4, cols=4, seed=seed,
                                  backend="pallas")
    program = ual.Program.from_kernel(KERNEL,
                                      n_banks=target.fabric.n_mem_ports,
                                      bank_words=PALLAS_BANK_WORDS)
    exe = ual.compile(program, target)
    if not exe.success:
        return {"mapped": False}
    n_iters = program.n_iters
    rng = np.random.default_rng(seed)
    pool = [program.random_inputs(rng) for _ in range(max(PALLAS_SIZES))]
    flats = program.flatten_batch(pool)
    oracle = [program.flatten(interpret(program.dfg, m, n_iters))
              for m in pool]

    # baseline: the old per-call path — cgra_exec rebuilds (re-traces,
    # re-lowers, re-uploads) its pallas_call on EVERY invocation
    base_wall = []
    for _ in range(PALLAS_BASELINE_CALLS):
        t0 = time.perf_counter()
        out = np.asarray(cgra_exec(exe.lowered, flats[:8], n_iters,
                                   interpret=True))
        base_wall.append(time.perf_counter() - t0)
    baseline_s = sum(base_wall) / len(base_wall)
    bitexact = all(np.array_equal(out[b], oracle[b]) for b in range(8))

    # steady state: a fresh engine (isolated counters), ladder warmed,
    # then N mixed-size calls — the service's traffic shape
    engine = CompiledKernelCache(buckets=PALLAS_BUCKETS)
    eng = engine.engine_for(exe.lowered)
    eng.warmup(program.layout.total_words)
    walls, by_size = [], {}
    for i in range(PALLAS_N_CALLS):
        B = PALLAS_SIZES[i % len(PALLAS_SIZES)]
        t0 = time.perf_counter()
        out, info = engine.run(exe.lowered, flats[:B], n_iters)
        wall = time.perf_counter() - t0
        walls.append(wall)
        by_size.setdefault(B, []).append(wall)
        if i % 37 == 0:                       # rolling parity spot-check
            bitexact &= all(np.array_equal(out[b], oracle[b])
                            for b in range(B))
    steady_b8_s = float(np.median(by_size[8]))
    stats = eng.stats()
    data = {
        "mapped": True, "ii": exe.II, "n_calls": PALLAS_N_CALLS,
        "sizes": list(PALLAS_SIZES), "buckets": list(eng.buckets),
        "traces": stats["traces"], "hit_ratio": stats["hit_ratio"],
        "padded_samples": stats["padded_samples"],
        "baseline_retrace_per_call_s": round(baseline_s, 4),
        "steady_state_b8_per_call_s": round(steady_b8_s, 5),
        "steady_state_mean_per_call_s": round(float(np.mean(walls)), 5),
        "speedup_vs_retrace": round(baseline_s / steady_b8_s, 1),
        "bitexact": bitexact,
    }
    if verbose:
        print("\n== pallas steady state: persistent JIT engine vs "
              "trace-every-call ==")
        print(fmt_table(
            ["calls", "traces", "buckets", "retrace ms", "steady ms (B=8)",
             "speedup", "bitexact"],
            [[PALLAS_N_CALLS, stats["traces"], str(list(eng.buckets)),
              round(baseline_s * 1e3, 1), round(steady_b8_s * 1e3, 2),
              f"{data['speedup_vs_retrace']}x",
              "ok" if bitexact else "MISMATCH"]]))
    return data


def run(seed: int = 0, verbose: bool = True) -> dict:
    rows, data = [], {}
    for fab_name, kwargs in FABRICS:
        target = ual.Target.from_name(fab_name, seed=seed, **kwargs)
        program = ual.Program.from_kernel(
            KERNEL, n_banks=target.fabric.n_mem_ports)
        exe = ual.compile(program, target)
        if not exe.success:
            data[fab_name] = {"mapped": False}
            continue
        n_iters = program.n_iters
        rng = np.random.default_rng(seed)
        B_max = max(BATCHES)
        flats = np.stack([program.flatten(program.random_inputs(rng))
                          for _ in range(B_max)])

        # scalar reference: time + outputs on a bounded sample count
        # (large fabrics pay ~P per cycle in pure Python; 8 samples give a
        # stable per-sample figure there, small fabrics check all 64)
        n_ref = 64 if target.fabric.n_pes <= 16 else 8
        t0 = time.perf_counter()
        ref_outs = [simulate_reference(exe.map_result.config, flats[b],
                                       n_iters)[0] for b in range(n_ref)]
        ref_wall = time.perf_counter() - t0
        ref_per_sample = ref_wall / n_ref

        # batched engine: every batch size, parity on the reference prefix.
        # Build the per-slot plans once, untimed, so the B=1 figure measures
        # steady-state execution, not one-time plan construction
        batched_engine(exe.lowered)
        per_b = {}
        bitexact = True
        for B in BATCHES:
            t0 = time.perf_counter()
            outs, stats = simulate_batch(exe.lowered, flats[:B], n_iters)
            wall = time.perf_counter() - t0
            for b in range(min(B, n_ref)):
                if not np.array_equal(outs[b], ref_outs[b]):
                    bitexact = False
            per_b[B] = {
                "wall_s": round(wall, 4),
                "per_sample_ms": round(wall / B * 1e3, 3),
                "throughput_sps": round(B / wall, 1),
                "speedup_vs_ref": round(ref_per_sample / (wall / B), 1),
            }
        data[fab_name] = {
            "mapped": True, "ii": exe.II, "n_pes": target.fabric.n_pes,
            "n_iters": n_iters, "ref_per_sample_ms":
                round(ref_per_sample * 1e3, 3),
            "ref_samples_checked": n_ref, "bitexact": bitexact,
            "batches": per_b,
            "lowered_cm_bytes": exe.lowered.cm_bytes(),
        }
        for B in BATCHES:
            d = per_b[B]
            rows.append([f"{KERNEL}@{target.fabric.name}", B,
                         d["per_sample_ms"], d["throughput_sps"],
                         f"{d['speedup_vs_ref']}x",
                         "ok" if bitexact else "MISMATCH"])

    pallas = _pallas_steady_state(seed, verbose)

    mapped = {k: v for k, v in data.items() if v.get("mapped")}
    claims = {
        "all_mapped": len(mapped) == len(FABRICS),
        "speedup_ge_10x_at_b64": all(
            d["batches"][64]["speedup_vs_ref"] >= 10 for d in mapped.values()),
        "bitexact_vs_reference": all(d["bitexact"] for d in mapped.values()),
        "throughput_scales_with_batch": all(
            d["batches"][256]["throughput_sps"]
            > d["batches"][1]["throughput_sps"] for d in mapped.values()),
        "pallas_mapped": bool(pallas.get("mapped")),
        "pallas_traces_bounded_by_buckets": bool(
            pallas.get("mapped")
            and pallas["traces"] <= len(pallas["buckets"])),
        "pallas_steady_state_ge_10x_vs_retrace": bool(
            pallas.get("mapped") and pallas["speedup_vs_retrace"] >= 10),
        "pallas_bitexact_vs_oracle": bool(pallas.get("mapped")
                                          and pallas["bitexact"]),
    }
    payload = {"data": data, "pallas_steady_state": pallas, "claims": claims,
               "kernel": KERNEL, "batches": list(BATCHES)}
    save("exec_throughput", payload)
    if verbose:
        print("== batched execution: vectorized sim vs scalar reference ==")
        print(fmt_table(["kernel@fabric", "B", "ms/sample", "samples/s",
                         "speedup", "bitexact"], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

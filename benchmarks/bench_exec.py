"""Batched-execution throughput: the lower-once / run-many payoff.

The cycle-accurate simulator is the validation workhorse of the whole
flow (Morpher's integrated map->simulate->validate loop), so its
per-sample cost gates every validate/DSE/serving scenario.  This bench
measures what the shared lowering pass + vectorized batched engine buy:
for one kernel per temporal fabric it sweeps batch sizes B in
{1, 8, 64, 256} through ``simulate_batch`` (all PEs of a cycle as array
ops, B scratchpad images stepping through the fabric simultaneously) and
compares per-sample cost against the scalar reference engine
(``simulate_reference``) on the very same lowered configuration —
asserting bit-exact outputs while it measures.

Claims checked (recorded as machine-checkable booleans):

  * >= 10x per-sample speedup at B=64 on every fabric,
  * bit-exact outputs between batched engine and reference on every
    checked sample,
  * throughput (samples/s) grows with the batch size.
"""
from __future__ import annotations

import time

import numpy as np

from repro import ual
from repro.core.simulator import (batched_engine, simulate_batch,
                                  simulate_reference)

from benchmarks.common import fmt_table, save

KERNEL = "gemm"
BATCHES = (1, 8, 64, 256)
FABRICS = (("hycube", dict(rows=4, cols=4)),
           ("n2n", dict(rows=4, cols=4)),
           ("pace", {}))


def run(seed: int = 0, verbose: bool = True) -> dict:
    rows, data = [], {}
    for fab_name, kwargs in FABRICS:
        target = ual.Target.from_name(fab_name, seed=seed, **kwargs)
        program = ual.Program.from_kernel(
            KERNEL, n_banks=target.fabric.n_mem_ports)
        exe = ual.compile(program, target)
        if not exe.success:
            data[fab_name] = {"mapped": False}
            continue
        n_iters = program.n_iters
        rng = np.random.default_rng(seed)
        B_max = max(BATCHES)
        flats = np.stack([program.flatten(program.random_inputs(rng))
                          for _ in range(B_max)])

        # scalar reference: time + outputs on a bounded sample count
        # (large fabrics pay ~P per cycle in pure Python; 8 samples give a
        # stable per-sample figure there, small fabrics check all 64)
        n_ref = 64 if target.fabric.n_pes <= 16 else 8
        t0 = time.perf_counter()
        ref_outs = [simulate_reference(exe.map_result.config, flats[b],
                                       n_iters)[0] for b in range(n_ref)]
        ref_wall = time.perf_counter() - t0
        ref_per_sample = ref_wall / n_ref

        # batched engine: every batch size, parity on the reference prefix.
        # Build the per-slot plans once, untimed, so the B=1 figure measures
        # steady-state execution, not one-time plan construction
        batched_engine(exe.lowered)
        per_b = {}
        bitexact = True
        for B in BATCHES:
            t0 = time.perf_counter()
            outs, stats = simulate_batch(exe.lowered, flats[:B], n_iters)
            wall = time.perf_counter() - t0
            for b in range(min(B, n_ref)):
                if not np.array_equal(outs[b], ref_outs[b]):
                    bitexact = False
            per_b[B] = {
                "wall_s": round(wall, 4),
                "per_sample_ms": round(wall / B * 1e3, 3),
                "throughput_sps": round(B / wall, 1),
                "speedup_vs_ref": round(ref_per_sample / (wall / B), 1),
            }
        data[fab_name] = {
            "mapped": True, "ii": exe.II, "n_pes": target.fabric.n_pes,
            "n_iters": n_iters, "ref_per_sample_ms":
                round(ref_per_sample * 1e3, 3),
            "ref_samples_checked": n_ref, "bitexact": bitexact,
            "batches": per_b,
            "lowered_cm_bytes": exe.lowered.cm_bytes(),
        }
        for B in BATCHES:
            d = per_b[B]
            rows.append([f"{KERNEL}@{target.fabric.name}", B,
                         d["per_sample_ms"], d["throughput_sps"],
                         f"{d['speedup_vs_ref']}x",
                         "ok" if bitexact else "MISMATCH"])

    mapped = {k: v for k, v in data.items() if v.get("mapped")}
    claims = {
        "all_mapped": len(mapped) == len(FABRICS),
        "speedup_ge_10x_at_b64": all(
            d["batches"][64]["speedup_vs_ref"] >= 10 for d in mapped.values()),
        "bitexact_vs_reference": all(d["bitexact"] for d in mapped.values()),
        "throughput_scales_with_batch": all(
            d["batches"][256]["throughput_sps"]
            > d["batches"][1]["throughput_sps"] for d in mapped.values()),
    }
    payload = {"data": data, "claims": claims,
               "kernel": KERNEL, "batches": list(BATCHES)}
    save("exec_throughput", payload)
    if verbose:
        print("== batched execution: vectorized sim vs scalar reference ==")
        print(fmt_table(["kernel@fabric", "B", "ms/sample", "samples/s",
                         "speedup", "bitexact"], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

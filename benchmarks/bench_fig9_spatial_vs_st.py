"""Paper Fig. 9: spatial vs spatio-temporal CGRA mapping quality.

The spatial architecture (Snafu-like: each op statically owns a PE, no
time multiplexing; DFGs larger than the array are split into subgraphs
executed to completion one after another) is compared against the
spatio-temporal HyCUBE on the same kernels.  Paper claim: the spatial
architecture exhibits an EQUAL OR HIGHER II than the spatio-temporal
counterpart across all benchmarks (it trades performance for the power
saved by eliminating configuration memory).
"""
from __future__ import annotations

from repro.core.adl import hycube, spatial
from repro.core.dfg import apply_layout, plan_layout
from repro.core.kernel_lib import KERNELS
from repro.core.mapper import map_dfg, spatial_ii

from benchmarks.common import fmt_table, save

PAPER_KERNELS = ("fft", "adpcm", "aes", "disparity", "dct", "nw", "gemm")
KERNEL_ORDER = PAPER_KERNELS + ("jax_poly",)


def run(seed: int = 0, verbose: bool = True) -> dict:
    fab_st = hycube(4, 4)
    fab_sp = spatial(4, 4)
    rows, data = [], {}
    for name in KERNEL_ORDER:
        dfg, _, _ = KERNELS[name]()
        layout = plan_layout(dfg)
        laid = apply_layout(dfg, layout)
        res = map_dfg(laid, fab_st, seed=seed, max_restarts=12)
        ii_st = res.II if res.success else -1
        ii_sp, n_parts = spatial_ii(laid, fab_sp)
        data[name] = {"st_ii": ii_st, "spatial_ii": ii_sp,
                      "spatial_subgraphs": n_parts,
                      "nodes": len(dfg.nodes)}
        rows.append([name, len(dfg.nodes), ii_st, ii_sp, n_parts])
    # the paper's claim is over ITS benchmark set — all too large to fit
    # the array spatially; jax_poly (14 nodes, fits, recurrence-free) is
    # our addition and legitimately wins on a spatial fabric (reported,
    # excluded from the claim)
    claims = {
        "spatial_ii_ge_spatiotemporal": all(
            data[n]["spatial_ii"] >= data[n]["st_ii"]
            for n in PAPER_KERNELS if data[n]["st_ii"] > 0),
    }
    payload = {"data": data, "claims": claims}
    save("fig9_spatial_vs_st", payload)
    if verbose:
        print("== Fig. 9: spatial (Snafu-like) vs spatio-temporal (HyCUBE) ==")
        print(fmt_table(["kernel", "nodes", "ST II", "spatial II",
                         "subgraphs"], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

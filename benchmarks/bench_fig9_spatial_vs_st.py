"""Paper Fig. 9: spatial vs spatio-temporal CGRA mapping quality.

The spatial architecture (Snafu-like: each op statically owns a PE, no
time multiplexing; DFGs larger than the array are split into subgraphs
executed to completion one after another) is compared against the
spatio-temporal HyCUBE on the same kernels.  Paper claim: the spatial
architecture exhibits an EQUAL OR HIGHER II than the spatio-temporal
counterpart across all benchmarks (it trades performance for the power
saved by eliminating configuration memory).
"""
from __future__ import annotations

from repro import ual

from benchmarks.common import fmt_table, save

PAPER_KERNELS = ("fft", "adpcm", "aes", "disparity", "dct", "nw", "gemm")
KERNEL_ORDER = PAPER_KERNELS + ("jax_poly",)


def run(seed: int = 0, verbose: bool = True) -> dict:
    tgt_st = ual.Target.from_name("hycube", rows=4, cols=4, seed=seed,
                                  max_restarts=12)
    tgt_sp = ual.Target.from_name("spatial", rows=4, cols=4, seed=seed,
                                  backend="interp")
    rows, data = [], {}
    for name in KERNEL_ORDER:
        program = ual.Program.from_kernel(name)
        st = ual.compile(program, tgt_st)
        sp = ual.compile(program, tgt_sp)
        ii_st = st.II if st.success else -1
        # one batched engine sweep validates the ST config we report
        # (spatial targets are mapping-free interp: nothing to validate)
        checked = (st.validate(seed=seed, n_vectors=2).passed
                   if st.success else None)
        data[name] = {"st_ii": ii_st, "spatial_ii": sp.II,
                      "spatial_subgraphs": sp.spatial_subgraphs,
                      "nodes": len(program.dfg.nodes),
                      "st_validated": checked}
        rows.append([name, len(program.dfg.nodes), ii_st, sp.II,
                     sp.spatial_subgraphs])
    # the paper's claim is over ITS benchmark set — all too large to fit
    # the array spatially; jax_poly (14 nodes, fits, recurrence-free) is
    # our addition and legitimately wins on a spatial fabric (reported,
    # excluded from the claim)
    claims = {
        "spatial_ii_ge_spatiotemporal": all(
            data[n]["spatial_ii"] >= data[n]["st_ii"]
            for n in PAPER_KERNELS if data[n]["st_ii"] > 0),
    }
    payload = {"data": data, "claims": claims}
    save("fig9_spatial_vs_st", payload)
    if verbose:
        print("== Fig. 9: spatial (Snafu-like) vs spatio-temporal (HyCUBE) ==")
        print(fmt_table(["kernel", "nodes", "ST II", "spatial II",
                         "subgraphs"], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

"""Paper Table III: impact of multi-hop interconnects on CGRA performance.

Maps each benchmark kernel onto a 4x4 HyCUBE with max_hops in {1,2,3,4}
and reports the achieved II.  The paper's claims, checked here:

  * 2 hops already improves II across benchmarks vs 1 hop,
  * at 4 hops the improvement frequently exceeds 50%,
  * II is monotonically non-increasing in the hop budget (modulo mapper
    noise, which we bound with restarts).

Absolute IIs differ from the paper (our DFG loop bodies are sized for a
mapper that runs in seconds on CPU; the paper's kernels are larger), so
the reproduction target is the TREND + improvement ratios.
"""
from __future__ import annotations

from repro import ual

from benchmarks.common import fmt_table, save

HOPS = (1, 2, 3, 4)
KERNEL_ORDER = ("fft", "adpcm", "aes", "disparity", "dct", "nw", "gemm")

# paper Table III (4x4, II per hop count) — for side-by-side reporting
PAPER = {
    "fft": (11, 5, 5, 5), "adpcm": (17, 9, 9, 8), "aes": (24, 15, 13, 13),
    "disparity": (26, 12, 10, 11), "dct": (23, 14, 13, 13),
    "nw": (19, 15, 15, 15), "gemm": (14, 9, 8, 7),
}


def run(seed: int = 0, verbose: bool = True) -> dict:
    rows, data = [], {}
    for name in KERNEL_ORDER:
        program = ual.Program.from_kernel(name)
        iis, walls, hits = [], [], []
        checked = None
        for h in HOPS:
            # quality profile: this is the paper's headline table, so
            # spend more restarts than the default bounded profile
            target = ual.Target.from_name("hycube", rows=4, cols=4,
                                          max_hops=h, seed=seed,
                                          max_restarts=12,
                                          time_budget_s=240.0)
            exe = ual.compile(program, target)
            iis.append(exe.II if exe.success else -1)
            # true mapper cost from the MapResult (survives cache hits)
            walls.append(round(exe.map_result.wall_s, 2))
            hits.append(exe.compile_info.cache_hit)
            if h == HOPS[-1] and exe.success:
                # the batched engine makes validating the headline (4-hop)
                # configs essentially free: one vectorized sweep each
                checked = exe.validate(seed=seed, n_vectors=2).passed
        imp = (1 - iis[-1] / iis[0]) * 100 if iis[0] > 0 else 0.0
        pimp = (1 - PAPER[name][3] / PAPER[name][0]) * 100
        data[name] = {"ii": iis, "wall_s": walls, "cache_hits": hits,
                      "improvement_pct": imp, "validated": checked}
        rows.append([name, *iis, f"{imp:.0f}%", f"{pimp:.0f}% (paper)"])
    table = fmt_table(["kernel", "1-hop", "2-hop", "3-hop", "4-hop",
                       "gain", "paper gain"], rows)
    # paper claims as machine-checkable booleans
    claims = {
        "two_hops_helps_all": all(d["ii"][1] <= d["ii"][0]
                                  for d in data.values()),
        "monotone_within_1": all(
            d["ii"][i + 1] <= d["ii"][i] + 1
            for d in data.values() for i in range(3)),
        "some_kernel_gains_ge_50pct": any(d["improvement_pct"] >= 50
                                          for d in data.values()),
        "four_hop_configs_validate": all(d["validated"]
                                         for d in data.values()
                                         if d["validated"] is not None),
    }
    payload = {"data": data, "claims": claims, "paper": PAPER}
    save("table3_multihop", payload)
    if verbose:
        print("== Table III: II vs interconnect hop budget (4x4 HyCUBE) ==")
        print(table)
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

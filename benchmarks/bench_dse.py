"""DSE bench: `ual.explore` Pareto sweep + `compile_many` parallel speedup.

Sweeps one kernel over >= 3 fabrics x 2 mapper strategies and checks the
redesigned compile path's two headline claims:

  * **zero redundant mappings** — each unique ``(program.digest,
    target.digest)`` pair maps exactly once (verified via cache stats),
    and a second sweep over the same cache maps nothing at all;
  * **parallel speedup** — ``compile_many(workers=4)`` on a cold cache
    beats the sequential compile loop on the same grid.  The 2x floor of
    the acceptance criterion assumes the machine can actually run >= 2
    CPU-bound processes concurrently; containers routinely advertise
    cores they time-slice (this is measurable: two spinning processes
    finish barely faster than one).  The bench therefore calibrates the
    machine's real parallel throughput with a spin test and scales the
    floor to 0.8x of it, capped at the acceptance's 2.0.

The report must be complete: II, per-pass timings and GOPS/W for every
design point.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import fmt_table, save

from repro import ual
from repro.ual.explore import space_targets

def _spin(n: int) -> int:
    s = 0
    for i in range(n):
        s += i * i
    return s


def machine_parallelism(n_procs: int, n: int = 5_000_000) -> float:
    """Measured speedup of ``n_procs`` spinning processes vs one process
    doing the same total work — the ceiling any CPU-bound pool can reach
    on this machine (vCPUs are often time-sliced fractions of a core)."""
    import multiprocessing as mp
    t0 = time.perf_counter()
    for _ in range(n_procs):
        _spin(n)
    t_seq = time.perf_counter() - t0
    ctx = (mp.get_context("fork")
           if "fork" in mp.get_all_start_methods() else mp.get_context())
    procs = [ctx.Process(target=_spin, args=(n,)) for _ in range(n_procs)]
    t0 = time.perf_counter()
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    t_par = time.perf_counter() - t0
    return t_seq / t_par if t_par > 0 else 1.0


KERNEL = "fft"
SPACE = {
    "fabric": [("hycube", dict(rows=4, cols=4)),
               ("n2n", dict(rows=4, cols=4)),
               "pace"],
    "strategy": ["adaptive", "sa"],
}
WORKERS = 4


def run() -> dict:
    program = ual.Program.from_kernel(KERNEL)
    targets = [t for t, _ in space_targets(SPACE)]
    n_unique = len({(program.digest, t.digest) for t in targets})

    # -- sequential baseline: the hand-written loop the UAL replaces -------
    seq_cache = ual.MappingCache(disk_dir=None)
    t0 = time.perf_counter()
    seq = [ual.compile(program, t, cache=seq_cache) for t in targets]
    t_seq = time.perf_counter() - t0

    # -- parallel sweep through explore()/compile_many ---------------------
    par_cache = ual.MappingCache(disk_dir=None)
    t0 = time.perf_counter()
    report = ual.explore(program, SPACE, workers=WORKERS, cache=par_cache)
    t_par = time.perf_counter() - t0

    # -- warm re-sweep: everything served from the cache -------------------
    rewarm = ual.explore(program, SPACE, workers=WORKERS, cache=par_cache)

    print(report.render())
    speedup = t_seq / t_par if t_par > 0 else 0.0
    n_cores = os.cpu_count() or 1
    effective = min(WORKERS, n_cores, n_unique)
    hw = machine_parallelism(effective)
    floor = min(2.0, max(1.0, 0.8 * hw))   # never below break-even
    rows = [["sequential loop", f"{t_seq:.1f}s", "1.00x"],
            [f"compile_many(workers={WORKERS})", f"{t_par:.1f}s",
             f"{speedup:.2f}x"]]
    print(fmt_table(["grid compile", "wall", "speedup"], rows))
    print(f"{n_unique} unique design points, {report.n_mapped} mappings "
          f"paid (parallel), {rewarm.n_mapped} on re-sweep; "
          f"{n_cores} advertised cores sustain {hw:.2f}x measured parallel "
          f"throughput -> speedup floor {floor:.2f}x")

    same_iis = all(s.II == p.executable.II
                   for s, p in zip(seq, report.points))
    claims = {
        "all_points_mapped": all(p.success for p in report.points),
        "zero_redundant_mappings": (par_cache.stats.stores == n_unique
                                    and report.n_mapped == n_unique),
        "warm_resweep_maps_nothing": rewarm.n_mapped == 0,
        "report_complete": all(
            p.II is not None and p.gops_w is not None
            and {"layout", "mii", "mapping", "binding"} <= set(p.pass_times)
            for p in report.points),
        "parallel_beats_sequential": speedup >= floor,
        "parallel_matches_sequential_iis": same_iis,
    }
    payload = {
        "kernel": KERNEL,
        "t_seq_s": t_seq, "t_par_s": t_par, "speedup": speedup,
        "n_cores": n_cores, "workers": WORKERS,
        "machine_parallelism": hw,
        "speedup_floor": floor, "n_unique": n_unique,
        "report": report.to_json(),
        "claims": claims,
    }
    save("dse_explore", payload)
    return payload

"""Paper Table IV: normalized efficiency/area comparison vs prior silicon.

Reproduced from the calibrated analytic PACE model (core/energy.py): the
paper normalizes area by (node/40nm) and efficiency by (node/40nm)^2.
Claims checked: PACE's normalized efficiency exceeds every prior design by
1.2x-4.6x, and its normalized area (3.02 mm^2) is the smallest.
"""
from __future__ import annotations

from repro.core.energy import table4_comparison

from benchmarks.common import fmt_table, save


def run(verbose: bool = True) -> dict:
    rows_d = table4_comparison()
    pace = rows_d["PACE"]
    ratios = {k: pace["norm_eff"] / r["norm_eff"]
              for k, r in rows_d.items() if k != "PACE"}
    claims = {
        "pace_norm_eff_exceeds_all": all(v > 1.0 for v in ratios.values()),
        "ratio_range_1p2_to_4p6": (1.0 <= min(ratios.values()) <= 1.4
                                   and 4.0 <= max(ratios.values()) <= 5.0),
        "pace_smallest_norm_area": pace["norm_area"] <= min(
            r["norm_area"] for r in rows_d.values()),
    }
    rows = [[k, r["node"], r["area"], f"{r['eff']:.0f}",
             f"{r['norm_area']:.2f}", f"{r['norm_eff']:.0f}",
             f"{ratios.get(k, 1.0):.1f}x"] for k, r in rows_d.items()]
    payload = {"rows": {k: dict(v) for k, v in rows_d.items()},
               "pace_advantage": ratios, "claims": claims}
    save("table4_efficiency", payload)
    if verbose:
        print("== Table IV: normalized comparison with prior designs ==")
        print(fmt_table(["design", "node(nm)", "area", "GOPS/W",
                         "norm.area", "norm.eff", "PACE adv."], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

"""Paper Fig. 10: CGRA power / max frequency / efficiency vs VDD.

The calibrated model must hit the paper's anchor measurements:
  (a) power 4.4 mW @ 0.6 V -> 43 mW @ 1.0 V,
  (b) fmax 21 MHz @ 0.6 V -> 105 MHz @ 1.0 V,
  (c) efficiency peaks ~360 GOPS/W @ 0.6 V, falls to ~154 GOPS/W
      near 0.95-1.0 V (dynamic power grows faster than throughput).
"""
from __future__ import annotations

import numpy as np

from repro.core.energy import cgra_power_mw, efficiency_gops_w, freq_mhz

from benchmarks.common import fmt_table, save


def run(verbose: bool = True) -> dict:
    vdds = np.round(np.arange(0.6, 1.01, 0.05), 2)
    rows, data = [], {}
    for v in vdds:
        p = cgra_power_mw(float(v))
        f = freq_mhz(float(v))
        e = efficiency_gops_w(float(v))
        data[float(v)] = {"power_mw": p, "freq_mhz": f, "gops_w": e}
        rows.append([v, f"{p:.1f}", f"{f:.0f}", f"{e:.0f}"])
    e06, e10 = data[0.6]["gops_w"], data[1.0]["gops_w"]
    claims = {
        "power_anchors": (abs(data[0.6]["power_mw"] - 4.4) < 0.5
                          and abs(data[1.0]["power_mw"] - 43.0) < 2.0),
        "freq_anchors": (abs(data[0.6]["freq_mhz"] - 21) < 1.0
                         and abs(data[1.0]["freq_mhz"] - 105) < 1.0),
        "efficiency_peak_at_0p6": e06 == max(d["gops_w"]
                                             for d in data.values()),
        "efficiency_near_360_at_0p6": 320 <= e06 <= 400,
        "efficiency_falls_toward_154": 140 <= e10 <= 200,
    }
    payload = {"data": {str(k): v for k, v in data.items()}, "claims": claims}
    save("fig10_voltage", payload)
    if verbose:
        print("== Fig. 10: power / fmax / efficiency vs VDD (PACE model) ==")
        print(fmt_table(["VDD", "P(mW)", "f(MHz)", "GOPS/W"], rows))
        print("claims:", claims)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

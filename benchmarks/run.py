"""Benchmark harness entry: one bench per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Each bench prints its table, records artifacts/bench/<name>.json, and
returns machine-checkable claim booleans; the run fails (exit 1) if any
paper claim is violated.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_fig9_spatial_vs_st, bench_fig10_voltage,
                        bench_fig11_breakdown, bench_roofline,
                        bench_table2_validation, bench_table3_multihop,
                        bench_table4_efficiency)

BENCHES = {
    "table2_validation": bench_table2_validation.run,
    "table3_multihop": bench_table3_multihop.run,
    "fig9_spatial_vs_st": bench_fig9_spatial_vs_st.run,
    "table4_efficiency": bench_table4_efficiency.run,
    "fig10_voltage": bench_fig10_voltage.run,
    "fig11_breakdown": bench_fig11_breakdown.run,
    "roofline": bench_roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    failed = []
    for name in names:
        t0 = time.time()
        print(f"\n########## {name} ##########")
        payload = BENCHES[name]()
        claims = payload.get("claims", {})
        bad = [k for k, v in claims.items() if not v]
        if bad:
            failed.append((name, bad))
        print(f"[{name}] done in {time.time() - t0:.1f}s"
              + (f"  VIOLATED: {bad}" if bad else "  all claims hold"))
    print("\n================ SUMMARY ================")
    if failed:
        for name, bad in failed:
            print(f"FAIL {name}: {bad}")
        sys.exit(1)
    print(f"all {len(names)} benches passed their paper-claim checks")


if __name__ == "__main__":
    main()

"""Benchmark harness entry: one bench per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]

Each bench prints its table, records artifacts/bench/<name>.json, and
returns machine-checkable claim booleans; the run fails (exit 1) if any
paper claim is violated.

``--smoke`` skips the full benches and instead compiles one kernel per
registered temporal fabric through the UAL, cache-cold then cache-warm,
runs a B=16 batched-sim throughput check off the shared lowered artifact
(oracle parity + nonzero samples/s), a pallas JIT-engine gate (mixed-size
batches through the persistent engine: oracle parity spot-check, trace
count == bucket count, plus a chunked streaming run on the warm engine —
parity, populated overlap metrics, zero new traces, recorded in
``smoke.json["stream"]``), a 2-fabric x 2-strategy mini-sweep through
``compile_many(workers=2)``, a dynamic-batching service gate
(32 requests through a ``max_batch=8`` ``ual.Service``, oracle parity
spot-checked, nonzero samples/s), and a 2-process mini cluster gate
(32 requests through ``ual.ClusterService(workers=2)`` sharing one disk
cache, parity spot-checked, recorded in ``smoke.json["cluster"]``), a
chaos gate (16 requests through a 2-process cluster while a
deterministic ``FaultPlan`` hard-kills worker 0 mid-load: every future
must resolve, survivors bit-exact, the worker must respawn under its
``RestartPolicy`` — recorded in ``smoke.json["chaos"]``), and
a telemetry gate (one traced request through the service on a fresh
flight recorder: complete span tree, per-stage breakdown within 10% of
the reported latency, schema-valid Chrome-trace export to
``artifacts/bench/smoke_trace.json``, recorded in
``smoke.json["telemetry"]``) — a fast regression gate for the
toolchain, mapping cache, execution engines, DSE front-end, serving
layer and telemetry (used by CI, which uploads
``artifacts/bench/smoke.json``).

``--trace OUT.json`` runs anything above with the flight recorder on
for the whole run and exports one Chrome-trace JSON at the end.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

from benchmarks import (bench_chaos, bench_dse, bench_exec,
                        bench_fig9_spatial_vs_st,
                        bench_fig10_voltage, bench_fig11_breakdown,
                        bench_roofline, bench_serve, bench_stream,
                        bench_table2_validation, bench_table3_multihop,
                        bench_table4_efficiency)
from benchmarks.common import ART, fmt_table, save

BENCHES = {
    "table2_validation": bench_table2_validation.run,
    "table3_multihop": bench_table3_multihop.run,
    "fig9_spatial_vs_st": bench_fig9_spatial_vs_st.run,
    "table4_efficiency": bench_table4_efficiency.run,
    "fig10_voltage": bench_fig10_voltage.run,
    "fig11_breakdown": bench_fig11_breakdown.run,
    "roofline": bench_roofline.run,
    "dse_explore": bench_dse.run,
    "exec_throughput": bench_exec.run,
    "serve_throughput": bench_serve.run,
    "serve_scaling": bench_serve.run_cluster,
    "stream_throughput": bench_stream.run,
    "chaos": bench_chaos.run,
}

SMOKE_TARGETS = (
    ("hycube", dict(rows=4, cols=4)),
    ("n2n", dict(rows=4, cols=4)),
    ("pace", {}),
    ("spatial", dict(rows=4, cols=4)),
)
SMOKE_KERNEL = "gemm"


def smoke() -> int:
    """Compile one kernel per fabric (cold + warm), validate on sim, run a
    B=16 batched-sim throughput check, push mixed-size batches through
    the pallas persistent JIT engine, mini-sweep 2 fabrics x
    2 strategies through ``compile_many(workers=2)``, push 32
    single-sample requests through a ``max_batch=8`` ``ual.Service``,
    then 32 more through a 2-process ``ual.ClusterService`` sharing one
    disk cache, then 16 through the same cluster shape while a
    ``FaultPlan`` kills worker 0 mid-load (self-healing gate).

    Exit non-zero if any compile fails, any compiled config carries
    verifier findings (``exe.check_report`` must be clean — recorded
    per fabric under ``smoke.json["verifier"]``), any validation
    mismatches, the
    warm compile misses the cache, the batched engine loses oracle parity
    or reports zero throughput, the JIT engine loses parity or retraces
    on a warm bucket, the sweep pays redundant mappings, either
    serving gate (service / mini cluster) loses parity or reports zero
    samples/s, or the chaos gate loses a future / a survivor's parity /
    the killed worker.
    Writes ``artifacts/bench/smoke.json`` (uploaded by CI).
    """
    import numpy as np

    from repro import ual
    failures = []
    rows = []
    verifier_json = []
    with tempfile.TemporaryDirectory() as d:
        cache = ual.MappingCache(disk_dir=d)
        for fab_name, kwargs in SMOKE_TARGETS:
            spatial = fab_name == "spatial"
            target = ual.Target.from_name(
                fab_name, backend="interp" if spatial else "sim", **kwargs)
            program = ual.Program.from_kernel(
                SMOKE_KERNEL, n_banks=target.fabric.n_mem_ports)
            t0 = time.perf_counter()
            exe = ual.compile(program, target, cache=cache)
            t_cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            warm = ual.compile(program, target, cache=cache)
            t_warm = time.perf_counter() - t0
            fail = None if exe.success else "compile failed"
            # every config the smoke compiles must verify CLEAN — a
            # warning here is a mapper/lowering regression, not noise
            # (spatial/mapping-free targets carry no report: recorded
            # as skipped, not failed)
            rep = exe.check_report
            if rep is not None:
                verifier_json.append(rep.to_json())
                if fail is None and rep.diagnostics:
                    fail = f"verifier findings: {rep.summary()}"
            else:
                verifier_json.append(
                    {"name": f"{SMOKE_KERNEL} @ {target.fabric.name}",
                     "skipped": "no machine configuration"})
            if fail is None and spatial:
                # spatial: no config to validate, but the analytic model and
                # the interp execution path must still behave
                out = exe.run(program.random_inputs(
                    np.random.default_rng(0)))
                if not (exe.II >= 1 and exe.spatial_subgraphs >= 1
                        and set(out) == set(program.arrays)):
                    fail = "spatial model/interp regression"
            elif fail is None:
                if not exe.validate(seed=0).passed:
                    fail = "validation mismatch"
                elif not warm.compile_info.cache_hit:
                    fail = "warm compile missed cache"
            ok = fail is None
            if fail:
                failures.append(f"{fab_name}: {fail}")
            rows.append([f"{SMOKE_KERNEL}@{target.fabric.name}",
                         exe.II if exe.success else -1,
                         f"{t_cold:.2f}s", f"{t_warm * 1e3:.1f}ms",
                         "clean" if rep is not None and not rep.diagnostics
                         else ("-" if rep is None else rep.summary()),
                         "ok" if ok else "FAIL"])
    print("== smoke: one kernel per fabric, cache-cold then cache-warm ==")
    print(fmt_table(["kernel@fabric", "II", "cold", "warm", "verify",
                     "check"], rows))
    print(f"cache: {cache.stats}")
    # the aggregate view (MappingCache.stats()): ratios + disk entries.
    # Rendered after the tempdir closes, so disk_entries reads 0 here —
    # the ratios are the point; disk counts are live in the service gate
    agg = cache.stats()
    print("cache aggregate: " + " | ".join(
        f"{layer}: hit_ratio={v['hit_ratio']} "
        f"({v['hits']}/{v['lookups']}), stores={v['stores']}, "
        f"disk_entries={v['disk_entries']}"
        for layer, v in agg.items() if isinstance(v, dict))
        + f" | quarantined={agg['quarantined']}")

    # -- batched-sim throughput gate: one kernel, B=16, vectorized engine
    # off the shared lowered artifact; parity with the oracle + nonzero
    # samples/s, so the lower-once/run-many path can't silently regress
    batched_json = None
    with tempfile.TemporaryDirectory() as d:
        bcache = ual.MappingCache(disk_dir=d)
        target = ual.Target.from_name("hycube", rows=4, cols=4)
        program = ual.Program.from_kernel(
            SMOKE_KERNEL, n_banks=target.fabric.n_mem_ports)
        exe = ual.compile(program, target, cache=bcache)
        B = 16
        ok = exe.success and exe.lowered is not None
        if not ok:
            failures.append("batched sim: compile/lowering failed")
        else:
            rep = exe.validate(seed=0, backends=("sim",), n_vectors=B)
            rng = np.random.default_rng(1)
            exe.run_batch([program.random_inputs(rng) for _ in range(B)])
            sps = exe.last_info.get("throughput_sps", 0.0)
            if not rep.passed:
                failures.append("batched sim: oracle parity mismatch")
            if not sps > 0:
                failures.append("batched sim: zero throughput reported")
            if bcache.stats.lowered_stores != 1:
                failures.append("batched sim: expected exactly one lowering")
            batched_json = {"B": B, "parity": rep.passed,
                            "throughput_sps": round(float(sps), 1),
                            "relowered": bcache.stats.lowered_stores != 1}
            print(f"\n== smoke: batched sim B={B} on the lowered artifact: "
                  f"{batched_json['throughput_sps']} samples/s, "
                  f"parity={'ok' if rep.passed else 'FAIL'} ==")

    # -- mini-DSE: 2 fabrics x 2 strategies through compile_many(workers=2)
    sweep_json = None
    with tempfile.TemporaryDirectory() as d:
        sweep_cache = ual.MappingCache(disk_dir=d)
        program = ual.Program.from_kernel(SMOKE_KERNEL)
        space = {"fabric": [("hycube", dict(rows=4, cols=4)),
                            ("n2n", dict(rows=4, cols=4))],
                 "strategy": ["adaptive", "sa"]}
        t0 = time.perf_counter()
        report = ual.explore(program, space, workers=2, cache=sweep_cache)
        t_sweep = time.perf_counter() - t0
        rewarm = ual.explore(program, space, workers=2, cache=sweep_cache)
        print(f"\n== smoke: 2x2 DSE mini-sweep via compile_many(workers=2), "
              f"{t_sweep:.1f}s ==")
        print(report.render())
        if not all(p.success for p in report.points):
            failures.append("dse sweep: point failed to map")
        if sweep_cache.stats.stores != len(report.points):
            failures.append(f"dse sweep: {sweep_cache.stats.stores} mappings "
                            f"stored for {len(report.points)} unique points")
        if rewarm.n_mapped != 0 or rewarm.n_warm != len(report.points):
            failures.append("dse sweep: warm re-sweep paid mappings")
        sweep_json = report.to_json()
        sweep_json["rewarm_all_cached"] = rewarm.n_mapped == 0

    # -- service gate: >=32 single-sample requests through a max_batch=8
    # dynamic-batching service; oracle-parity spot-check on 4 responses,
    # nonzero samples/s — so the queue->coalesce->sweep path can't rot
    service_json = None
    with tempfile.TemporaryDirectory() as d:
        from repro.core.dfg import interpret
        scache = ual.MappingCache(disk_dir=d)
        target = ual.Target.from_name("hycube", rows=4, cols=4)
        program = ual.Program.from_kernel(
            SMOKE_KERNEL, n_banks=target.fabric.n_mem_ports)
        n_req = 32
        rng = np.random.default_rng(2)
        mems = [program.random_inputs(rng) for _ in range(n_req)]
        with ual.Service(max_batch=8, max_wait_ms=5.0,
                         max_queue=2 * n_req, cache=scache) as svc:
            resps = [svc.submit(program, target, m, tenant="smoke")
                     for m in mems]
            outs = [r.result(timeout=300) for r in resps]
            stats = svc.stats()
        spot = [0, 9, 17, n_req - 1]
        parity = all(
            np.array_equal(interpret(program.dfg, mems[i],
                                     program.n_iters)[name], outs[i][name])
            for i in spot for name in program.outputs)
        sps = stats["samples_per_s"]
        if not parity:
            failures.append("service: oracle parity mismatch")
        if not sps > 0:
            failures.append("service: zero samples/s")
        if stats["completed"] != n_req:
            failures.append(f"service: {stats['completed']}/{n_req} "
                            f"requests completed")
        service_json = {"requests": n_req, "max_batch": 8,
                        "parity_spot_checked": len(spot), "parity": parity,
                        "samples_per_s": sps,
                        "mean_batch": stats["mean_batch"],
                        "p50_ms": stats["p50_ms"],
                        "p99_ms": stats["p99_ms"],
                        "rejects": stats["rejects"]}
        print(f"\n== smoke: service {n_req} requests @ max_batch=8: "
              f"{sps} samples/s, mean batch {stats['mean_batch']}, "
              f"parity={'ok' if parity else 'FAIL'} ==")

    # -- telemetry gate: one traced request end to end on a fresh flight
    # recorder — the span tree must be complete (request/queue/coalesce/
    # exec/resolve), the per-stage breakdown must account for the
    # reported latency within 10%, and the Chrome-trace export must be
    # schema-valid (written to artifacts/bench/smoke_trace.json, uploaded
    # by CI)
    telemetry_json = None
    with tempfile.TemporaryDirectory() as d:
        from repro import obs
        from repro.obs.trace import validate_chrome
        tcache = ual.MappingCache(disk_dir=d)
        target = ual.Target.from_name("hycube", rows=4, cols=4)
        program = ual.Program.from_kernel(
            SMOKE_KERNEL, n_banks=target.fabric.n_mem_ports)
        rng = np.random.default_rng(5)
        tracer = obs.Tracer(enabled=True)
        prev_tracer = obs.set_tracer(tracer)
        try:
            with ual.Service(max_batch=8, max_wait_ms=5.0,
                             cache=tcache) as svc:
                svc.submit(program, target,
                           program.random_inputs(rng)).result(timeout=300)
                fut = svc.submit(program, target, program.random_inputs(rng),
                                 tenant="traced")
                fut.result(timeout=300)
            trace = fut.info.get("trace") or {}
            latency_ms = float(fut.info["latency_ms"])
            span_names = {s.name for s in tracer.spans(trace.get("trace_id"))}
            want = {"request", "queue", "coalesce", "exec", "resolve"}
            missing = sorted(want - span_names)
            parts = sum(trace.get(k) or 0.0
                        for k in ("queue_ms", "coalesce_ms", "exec_ms"))
            parity = (latency_ms > 0
                      and abs(parts - latency_ms) <= 0.10 * latency_ms)
            problems = validate_chrome(tracer.to_chrome())
            trace_path = tracer.export_chrome(ART / "smoke_trace.json")
        finally:
            obs.set_tracer(prev_tracer)
        if missing:
            failures.append(f"telemetry: span tree incomplete, "
                            f"missing {missing}")
        if not parity:
            failures.append(f"telemetry: breakdown {parts:.3f}ms vs "
                            f"latency {latency_ms:.3f}ms (>10% apart)")
        if problems:
            failures.append(f"telemetry: invalid Chrome trace: "
                            f"{problems[:3]}")
        telemetry_json = {"trace_id": trace.get("trace_id"),
                          "breakdown": trace, "latency_ms": latency_ms,
                          "span_tree_complete": not missing,
                          "breakdown_parity_10pct": parity,
                          "chrome_valid": not problems,
                          "spans_recorded": tracer.stats()["recorded"],
                          "trace_file": str(trace_path)}
        print(f"\n== smoke: telemetry — traced request breakdown "
              f"{ {k: round(v, 3) for k, v in trace.items() if isinstance(v, float)} } "
              f"vs latency {latency_ms:.3f}ms, "
              f"tree={'ok' if not missing else 'INCOMPLETE'}, "
              f"chrome={'ok' if not problems else 'INVALID'} ==")

    # -- mini cluster gate: 32 requests through a 2-process
    # ClusterService (spawn — safe at any point, unlike fork); parity
    # spot-check + nonzero samples/s + merged-stats sanity, so the
    # multi-process front-end can't rot between full serve_scaling runs
    cluster_json = None
    with tempfile.TemporaryDirectory() as d:
        from repro.core.dfg import interpret
        target = ual.Target.from_name("hycube", rows=4, cols=4)
        program = ual.Program.from_kernel(
            SMOKE_KERNEL, n_banks=target.fabric.n_mem_ports)
        n_req = 32
        rng = np.random.default_rng(4)
        mems = [program.random_inputs(rng) for _ in range(n_req)]
        with ual.ClusterService(workers=2, max_batch=8, max_wait_ms=5.0,
                                max_queue=2 * n_req,
                                warmup_buckets=(1, 8),
                                cache_dir=d) as cs:
            resps = [cs.submit(program, target, m, tenant="smoke")
                     for m in mems]
            outs = [r.result(timeout=300) for r in resps]
            cstats = cs.stats()
        spot = [0, 9, 17, n_req - 1]
        parity = all(
            np.array_equal(interpret(program.dfg, mems[i],
                                     program.n_iters)[name], outs[i][name])
            for i in spot for name in program.outputs)
        sps = cstats["samples_per_s"]
        if not parity:
            failures.append("cluster: oracle parity mismatch")
        if not sps > 0:
            failures.append("cluster: zero samples/s")
        if cstats["completed"] != n_req:
            failures.append(f"cluster: {cstats['completed']}/{n_req} "
                            f"requests completed")
        if cstats["workers"] != 2:
            failures.append(f"cluster: {cstats['workers']}/2 workers live")
        cluster_json = {"requests": n_req, "workers": cstats["workers"],
                        "parity_spot_checked": len(spot), "parity": parity,
                        "samples_per_s": sps,
                        "p99_ms": cstats["p99_ms"],
                        "routing": cstats["routing"],
                        "rejects": cstats["rejects"]}
        print(f"\n== smoke: 2-process cluster, {n_req} requests: "
              f"{sps} samples/s, "
              f"routing {cstats['routing']['decisions']}, "
              f"parity={'ok' if parity else 'FAIL'} ==")

    # -- chaos gate: same mini cluster, but a deterministic FaultPlan
    # hard-kills worker 0 after its 3rd request, mid-load.  The
    # self-healing contract is binary: every future resolves (retried
    # transparently, zero rejects), survivors are bit-exact, and the
    # watchdog respawns the slot within its RestartPolicy — so the
    # failure paths run on every CI pass, not just in full bench runs
    chaos_json = None
    with tempfile.TemporaryDirectory() as d:
        from repro.core.dfg import interpret
        target = ual.Target.from_name("hycube", rows=4, cols=4)
        program = ual.Program.from_kernel(
            SMOKE_KERNEL, n_banks=target.fabric.n_mem_ports)
        n_req = 16
        rng = np.random.default_rng(6)
        mems = [program.random_inputs(rng) for _ in range(n_req)]
        plan = ual.FaultPlan(
            [ual.FaultSpec("kill_worker", worker=0, after=2)], seed=0)
        policy = ual.RestartPolicy(max_restarts=2, backoff_base_s=0.25)
        with ual.ClusterService(workers=2, max_batch=8, max_wait_ms=5.0,
                                max_queue=2 * n_req, cache_dir=d,
                                worker_env=plan.to_env(),
                                restart_policy=policy) as cs:
            resps = [cs.submit(program, target, m, tenant="chaos")
                     for m in mems]
            outs, rejected = [], 0
            for r in resps:
                try:
                    outs.append(r.result(timeout=300))
                except ual.ServiceRejected:
                    outs.append(None)
                    rejected += 1
            deadline = time.time() + 60.0
            wsnap = None
            while time.time() < deadline:
                wsnap = cs.stats(timeout=30)["supervision"]["workers"][0]
                if wsnap["restarts"] >= 1 and wsnap["alive"]:
                    break
                time.sleep(0.2)
            cstats = cs.stats(timeout=30)
        sup = cstats["supervision"]
        parity = all(
            np.array_equal(interpret(program.dfg, mems[i],
                                     program.n_iters)[name], outs[i][name])
            for i, out in enumerate(outs) if out is not None
            for name in program.outputs)
        if rejected:
            failures.append(f"chaos: {rejected} requests rejected (retry "
                            f"not transparent)")
        if not parity:
            failures.append("chaos: survivor parity mismatch after retry")
        if sup["deaths_total"] < 1:
            failures.append("chaos: fault plan never killed the worker")
        if not (wsnap and wsnap["alive"] and wsnap["restarts"] >= 1):
            failures.append(f"chaos: worker 0 not respawned ({wsnap})")
        chaos_json = {"requests": n_req, "rejected": rejected,
                      "parity": parity,
                      "fault_plan": plan.to_json(),
                      "deaths_total": sup["deaths_total"],
                      "restarts_total": sup["restarts_total"],
                      "retries_total": sup["retries_total"],
                      "recovery_s": wsnap["last_recovery_s"] if wsnap
                      else None}
        print(f"\n== smoke: chaos — kill worker 0 mid-load, {n_req} "
              f"requests: {sup['retries_total']} retried, "
              f"{rejected} rejected, recovery "
              f"{chaos_json['recovery_s']}s, "
              f"parity={'ok' if parity else 'FAIL'} ==")

    # -- pallas engine gate: mixed-size batches through the persistent
    # JIT engine; parity spot-check vs the oracle, trace count must equal
    # the number of distinct buckets touched (trace-once/run-many).
    # Runs LAST: this is the smoke's first jax use, and the fork-based
    # mini-sweep above must spawn its workers before jax starts threads
    engine_json = None
    stream_json = None
    with tempfile.TemporaryDirectory() as d:
        from repro.core.dfg import interpret
        ecache = ual.MappingCache(disk_dir=d)
        target = ual.Target.from_name("hycube", rows=4, cols=4,
                                      backend="pallas")
        program = ual.Program.from_kernel(
            SMOKE_KERNEL, n_banks=target.fabric.n_mem_ports, bank_words=64)
        exe = ual.compile(program, target, cache=ecache)
        engine = ual.CompiledKernelCache()
        prev_engine = ual.set_default_engine(engine)
        try:
            if not exe.success:
                failures.append("pallas engine: compile failed")
            else:
                rng = np.random.default_rng(3)
                mems = [program.random_inputs(rng) for _ in range(12)]
                out_a = exe.run_batch(mems[:3])    # bucket 8
                out_b = exe.run_batch(mems)        # bucket 32
                exe.run_batch(mems[3:8])           # bucket 8, warm
                stats = engine.stats()
                parity = all(
                    np.array_equal(interpret(program.dfg, m,
                                             program.n_iters)[n], o[n])
                    for m, o in ((mems[0], out_a[0]), (mems[11], out_b[11]))
                    for n in program.outputs)
                eng = engine.engine_for(exe.lowered)
                n_buckets = len(eng.bucket_calls)
                if not parity:
                    failures.append("pallas engine: oracle parity mismatch")
                if stats["traces"] != n_buckets:
                    failures.append(
                        f"pallas engine: {stats['traces']} traces for "
                        f"{n_buckets} buckets (retrace on the warm path)")
                engine_json = {"batches": [3, 12, 5], "parity": parity,
                               "traces": stats["traces"],
                               "buckets_used": sorted(eng.bucket_calls),
                               "hit_ratio": stats["hit_ratio"]}
                print(f"\n== smoke: pallas JIT engine, 3 mixed-size "
                      f"batches: {stats['traces']} traces / "
                      f"{n_buckets} buckets, "
                      f"parity={'ok' if parity else 'FAIL'} ==")

                # -- streaming gate: a small chunked run through the
                # double-buffered path on the SAME warm engine — parity
                # spot-check, overlap metrics populated, zero new traces
                traces_before = engine.stats()["traces"]
                s_outs = exe.run_batch(mems, stream=True, chunk=8)
                s_info = exe.last_info
                s_parity = all(
                    np.array_equal(interpret(program.dfg, m,
                                             program.n_iters)[n], o[n])
                    for m, o in ((mems[0], s_outs[0]),
                                 (mems[11], s_outs[11]))
                    for n in program.outputs)
                s_traces = engine.stats()["traces"] - traces_before
                if not s_parity:
                    failures.append("stream: oracle parity mismatch")
                if not (s_info.get("stream_chunks", 0) > 0
                        and s_info.get("throughput_sps", 0) > 0):
                    failures.append("stream: overlap metrics missing "
                                    f"({s_info})")
                if s_info.get("overlap_frac") is None:
                    failures.append("stream: no overlap_frac reported")
                if s_traces != 0:
                    failures.append(f"stream: {s_traces} new traces on a "
                                    f"warm engine")
                stream_json = {"B": len(mems), "chunk": 8,
                               "parity": s_parity,
                               "stream_chunks":
                                   s_info.get("stream_chunks"),
                               "overlap_frac": s_info.get("overlap_frac"),
                               "throughput_sps":
                                   round(float(s_info.get(
                                       "throughput_sps", 0.0)), 1),
                               "new_traces": s_traces}
                print(f"== smoke: streaming B={len(mems)} chunk=8: "
                      f"{stream_json['stream_chunks']} chunks, overlap "
                      f"{stream_json['overlap_frac']}, {s_traces} new "
                      f"traces, parity={'ok' if s_parity else 'FAIL'} ==")
        finally:
            ual.set_default_engine(prev_engine)

    save("smoke", {"fabrics": rows, "verifier": verifier_json,
                   "sweep": sweep_json,
                   "batched_sim": batched_json, "pallas_engine": engine_json,
                   "service": service_json, "cluster": cluster_json,
                   "chaos": chaos_json,
                   "stream": stream_json, "telemetry": telemetry_json,
                   "failures": failures})
    for f in failures:
        print(f"FAIL {f}")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    ap.add_argument("--smoke", action="store_true",
                    help="fast regression gate: compile one kernel per "
                         "fabric, cold + warm, instead of the full benches")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="run with the flight recorder on and export the "
                         "whole run as Chrome-trace JSON to OUT (open at "
                         "https://ui.perfetto.dev)")
    args = ap.parse_args()
    tracer = prev_tracer = None
    if args.trace:
        from repro import obs
        tracer = obs.Tracer(enabled=True, capacity=1 << 17)
        prev_tracer = obs.set_tracer(tracer)
    try:
        if args.smoke:
            sys.exit(smoke())
        names = [args.only] if args.only else list(BENCHES)
        failed = []
        for name in names:
            t0 = time.perf_counter()
            print(f"\n########## {name} ##########")
            payload = BENCHES[name]()
            claims = payload.get("claims", {})
            bad = [k for k, v in claims.items() if not v]
            if bad:
                failed.append((name, bad))
            print(f"[{name}] done in {time.perf_counter() - t0:.1f}s"
                  + (f"  VIOLATED: {bad}" if bad else "  all claims hold"))
        print("\n================ SUMMARY ================")
        if failed:
            for name, bad in failed:
                print(f"FAIL {name}: {bad}")
            sys.exit(1)
        print(f"all {len(names)} benches passed their paper-claim checks")
    finally:
        if tracer is not None:
            from repro import obs
            out = tracer.export_chrome(args.trace)
            print(f"trace: {len(tracer.spans())} spans -> {out}")
            obs.set_tracer(prev_tracer)


if __name__ == "__main__":
    main()

"""Roofline summary over the multi-pod dry-run artifacts (ours, §Roofline).

Reads artifacts/dryrun/*.json (produced by `python -m repro.launch.dryrun`)
and prints the per-cell roofline table: the three terms in seconds, the
dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import fmt_table, save

DRYRUN = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_cells(mesh: str = "pod16x16", tag: str = "") -> dict:
    cells = {}
    for f in sorted(DRYRUN.glob(f"*__{mesh}{tag}.json")):
        rec = json.loads(f.read_text())
        if tag == "" and rec.get("overrides"):
            continue
        cells[rec["cell"]] = rec
    return cells


def run(mesh: str = "pod16x16", verbose: bool = True) -> dict:
    cells = load_cells(mesh)
    rows, data = [], {}
    for cell, rec in cells.items():
        if rec["status"] != "ok":
            rows.append([cell.replace(f"__{mesh}", ""), rec["status"],
                         "", "", "", "", ""])
            continue
        r = rec["roofline"]
        data[cell] = r
        rows.append([
            cell.replace(f"__{mesh}", ""), r["bottleneck"],
            f"{r['t_compute_s']:.2e}", f"{r['t_memory_s']:.2e}",
            f"{r['t_collective_s']:.2e}",
            f"{r.get('useful_flops_ratio', 0):.2f}",
            f"{r.get('roofline_fraction', 0):.2f}",
        ])
    ok = [r for r in cells.values() if r["status"] == "ok"]
    bn = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        bn[b] = bn.get(b, 0) + 1
    payload = {"mesh": mesh, "n_ok": len(ok), "n_total": len(cells),
               "bottleneck_histogram": bn}
    save(f"roofline_{mesh}", payload)
    if verbose:
        print(f"== Roofline per cell ({mesh}; terms in seconds) ==")
        print(fmt_table(["cell", "bound", "t_comp", "t_mem", "t_coll",
                         "useful", "frac"], rows))
        print("bottleneck histogram:", bn)
    return payload


def main():
    run()


if __name__ == "__main__":
    main()

"""Deterministic, host-shardable synthetic data pipeline.

Every (step, host, data-shard) produces the same tokens regardless of how
many hosts participate — restart/elastic-resharding safe by construction:
the RNG key is a pure function of (seed, step, global example index).
A background prefetch thread keeps ``PREFETCH`` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.models.common import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    global_batch: int = 32
    seq_len: int = 256
    mask_rate: float = 0.3       # hubert masked-prediction rate


def _example(seed: int, step: int, index: int, cfg: ModelConfig,
             dc: DataConfig) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.PCG64((seed, step, index)))
    if cfg.family == "hubert":
        feats = rng.normal(size=(dc.seq_len, cfg.d_model)).astype(np.float32)
        mask = rng.random(dc.seq_len) < dc.mask_rate
        targets = rng.integers(0, cfg.vocab, dc.seq_len).astype(np.int32)
        return {"features": feats, "mask": mask, "targets": targets}
    out = {"tokens": rng.integers(0, cfg.vocab, dc.seq_len + 1)
           .astype(np.int32)}
    if cfg.family == "paligemma":
        out["img_embeds"] = rng.normal(
            size=(cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
    return out


def host_batch(cfg: ModelConfig, dc: DataConfig, step: int,
               host_id: int = 0, n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """This host's shard of the global batch at ``step`` (stacked arrays)."""
    per_host = dc.global_batch // n_hosts
    lo = host_id * per_host
    examples = [_example(dc.seed, step, lo + i, cfg, dc)
                for i in range(per_host)]
    return {k: np.stack([e[k] for e in examples]) for k in examples[0]}


class Prefetcher:
    """Background-thread prefetch over ``host_batch``."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig, start_step: int = 0,
                 host_id: int = 0, n_hosts: int = 1, depth: int = 2):
        self.cfg, self.dc = cfg, dc
        self.host_id, self.n_hosts = host_id, n_hosts
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            b = host_batch(self.cfg, self.dc, step, self.host_id,
                           self.n_hosts)
            try:
                self._q.put((step, b), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

"""Sharded optimizers: AdamW and a factored-second-moment variant.

Optimizer state mirrors the parameter PartitionSpecs, so FSDP-sharded
params give fully sharded (ZeRO-3 style) optimizer state for free.  The
factored variant (Adafactor-style row/col second moments) cuts optimizer
memory from 8 to ~4 bytes/param and is the default for the 480B config.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    factored: bool = False           # Adafactor-style second moment
    state_dtype: Any = jnp.float32


def lr_schedule(opt: OptConfig, step):
    warm = jnp.minimum(step / max(1, opt.warmup_steps), 1.0)
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(1, opt.total_steps - opt.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return opt.lr * warm * (0.1 + 0.9 * cos)


def _factored_shape(shape):
    """Row/col shapes for factored second moment (last two dims)."""
    if len(shape) < 2:
        return None
    return shape[:-1], shape[:-2] + shape[-1:]


def init_opt_state(params, opt: OptConfig):
    def init_leaf(p):
        st = {"m": jnp.zeros_like(p, opt.state_dtype)}
        fs = _factored_shape(p.shape) if opt.factored else None
        if fs is not None:
            st["v_row"] = jnp.zeros(fs[0], opt.state_dtype)
            st["v_col"] = jnp.zeros(fs[1], opt.state_dtype)
        else:
            st["v"] = jnp.zeros_like(p, opt.state_dtype)
        return st
    return {"step": jnp.zeros((), jnp.int32),
            "state": jax.tree.map(init_leaf, params)}


def opt_state_specs(param_specs, opt: OptConfig, abstract_params=None):
    """PartitionSpecs for the optimizer state, mirroring the params.

    ``abstract_params`` (same pytree of ShapeDtypeStructs/arrays) decides
    *per leaf* whether the second moment is factored — it must match
    ``init_opt_state``'s shape-based decision exactly (1-D params such as
    norms keep a dense ``v`` even under a factored optimizer).
    """
    from jax.sharding import PartitionSpec

    def leaf(spec, p):
        st = {"m": spec}
        factored = (opt.factored and p is not None
                    and _factored_shape(p.shape) is not None)
        if factored:
            # pad the spec to full rank, then drop the reduced dim:
            # v_row reduces the last dim, v_col the second-to-last
            e = list(spec) + [None] * (len(p.shape) - len(spec))
            st["v_row"] = PartitionSpec(*e[:-1])
            st["v_col"] = PartitionSpec(*(e[:-2] + e[-1:]))
        else:
            st["v"] = spec
        return st

    if abstract_params is None:
        abstract_params = jax.tree.map(
            lambda s: None, param_specs,
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        if opt.factored:
            raise ValueError("factored opt_state_specs needs abstract_params")
    specs = jax.tree.map(leaf, param_specs, abstract_params,
                         is_leaf=lambda x: isinstance(x, PartitionSpec))
    return {"step": PartitionSpec(), "state": specs}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, opt: OptConfig):
    """One AdamW (or factored) update.  Returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(opt, step)
    b1, b2 = opt.betas

    def upd(p, g, st):
        g = g.astype(jnp.float32) * scale
        m = b1 * st["m"].astype(jnp.float32) + (1 - b1) * g
        if "v" in st:
            v = b2 * st["v"].astype(jnp.float32) + (1 - b2) * jnp.square(g)
            v_hat = v / (1 - b2 ** step)
            denom = jnp.sqrt(v_hat) + opt.eps
            new_v = {"v": v.astype(opt.state_dtype)}
        else:
            g2 = jnp.square(g)
            v_row = b2 * st["v_row"].astype(jnp.float32) \
                + (1 - b2) * g2.mean(-1)
            v_col = b2 * st["v_col"].astype(jnp.float32) \
                + (1 - b2) * g2.mean(-2)
            r = v_row / (1 - b2 ** step)
            c = v_col / (1 - b2 ** step)
            v_hat = (r[..., None] * c[..., None, :]
                     / jnp.maximum(r.mean(-1)[..., None, None], 1e-30))
            denom = jnp.sqrt(v_hat) + opt.eps
            new_v = {"v_row": v_row.astype(opt.state_dtype),
                     "v_col": v_col.astype(opt.state_dtype)}
        m_hat = m / (1 - b1 ** step)
        delta = m_hat / denom + opt.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, {"m": m.astype(opt.state_dtype), **new_v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(opt_state["state"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = tdef.unflatten([o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "state": new_state}, metrics

"""Jitted, sharded train step with microbatch gradient accumulation.

`make_train_step` builds a pjit-ed function with explicit in/out shardings
derived from `repro.sharding.specs`.  Gradient accumulation is a lax.scan
over microbatches — the backward all-reduce of microbatch i overlaps with
the forward of microbatch i+1 in XLA's schedule, which is the standard
compute/communication overlap trick at scale.  Optional error-feedback
int8 gradient compression sits on the DP all-reduce path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models.common import ModelConfig, init_params
from repro.models.lm import lm_loss
from repro.sharding.ctx import activation_sharding, make_rules
from repro.sharding.specs import (batch_specs, dp_axes, param_specs,
                                  sanitize_specs, to_shardings)
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   opt_state_specs)


def compress_grads_int8(grads, err_state):
    """Error-feedback int8 quantization (applied before the DP all-reduce)."""
    def q(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.abs(g).max(), 1e-8) / 127.0
        qg = jnp.clip(jnp.round(g / scale), -127, 127)
        deq = qg * scale
        return deq.astype(g.dtype), (g - deq)
    out = jax.tree.map(q, grads, err_state)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def make_loss_and_grad(cfg: ModelConfig, n_microbatches: int = 1):
    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)

    if n_microbatches <= 1:
        def total_grad(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        return total_grad

    def total_grad(params, batch):
        def reshape_mb(x):
            return x.reshape(n_microbatches, x.shape[0] // n_microbatches,
                             *x.shape[1:])
        mb = jax.tree.map(reshape_mb, batch)

        def step(carry, mbatch):
            acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(step, (zeros, 0.0), mb)
        grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / n_microbatches, metrics, grads
    return total_grad


def train_step_fn(cfg: ModelConfig, opt: OptConfig, n_microbatches: int = 1,
                  compress: bool = False, grad_shardings=None):
    total_grad = make_loss_and_grad(cfg, n_microbatches)

    def step(params, opt_state, batch):
        loss, metrics, grads = total_grad(params, batch)
        if grad_shardings is not None:
            # Pin gradients to the parameter shardings *before* the optimizer
            # consumes them: under FSDP this makes XLA emit reduce-scatter
            # (each device only materializes its shard) instead of the
            # all-reduce + slice it otherwise falls back to — half the wire
            # bytes and 1/N the gradient memory.
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                 grad_shardings)
        if compress:
            grads, err = compress_grads_int8(grads, opt_state["err"])
        params, inner, opt_metrics = adamw_update(
            params, grads, opt_state["opt"], opt)
        new_state = {"opt": inner}
        if compress:
            new_state["err"] = err
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return params, new_state, metrics
    return step


def make_train_state(cfg: ModelConfig, opt: OptConfig, params,
                     compress: bool = False):
    state = {"opt": init_opt_state(params, opt)}
    if compress:
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def make_sharded_train_step(cfg: ModelConfig, opt: OptConfig, mesh: Mesh,
                            global_batch: int, n_microbatches: int = 1,
                            compress: bool = False):
    """pjit-ed train step with explicit in/out shardings (dry-run entry)."""
    abstract = jax.eval_shape(lambda k: init_params(k, cfg),
                              jax.random.PRNGKey(0))
    p_specs = sanitize_specs(param_specs(cfg, mesh), abstract, mesh)
    o_specs = {"opt": opt_state_specs(p_specs, opt, abstract)}
    if compress:
        o_specs["err"] = p_specs
    b_specs = batch_specs(cfg, mesh, global_batch, "train")
    dp_size = 1
    for a in (dp_axes(mesh, cfg.shard_strategy) or ()):
        dp_size *= mesh.shape[a]
    kv_tp_ok = ("model" not in mesh.axis_names
                or cfg.kv_heads % mesh.shape["model"] == 0)
    rules = make_rules(mesh, batch_sharded=(global_batch % dp_size == 0
                                            and global_batch >= dp_size),
                       strategy=cfg.shard_strategy, kv_tp_ok=kv_tp_ok)
    inner_step = train_step_fn(
        cfg, opt, n_microbatches, compress,
        grad_shardings=(to_shardings(p_specs, mesh)
                        if cfg.grad_reduce == "pinned" else None))

    def step(params, opt_state, batch):
        with activation_sharding(rules):
            return inner_step(params, opt_state, batch)
    in_shardings = (to_shardings(p_specs, mesh), to_shardings(o_specs, mesh),
                    to_shardings(b_specs, mesh))
    out_shardings = (to_shardings(p_specs, mesh), to_shardings(o_specs, mesh),
                     None)
    return jax.jit(step, in_shardings=in_shardings,
                   out_shardings=out_shardings), (p_specs, o_specs, b_specs)

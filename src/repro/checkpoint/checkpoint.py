"""Sharded checkpointing: npz payloads + JSON manifest, async save,
elastic restore (re-shard onto a different mesh).

Layout:  <dir>/step_<n>/manifest.json + arrays.npz
The manifest records step, data-pipeline cursor, mesh shape and per-leaf
paths/shapes/dtypes, so a restart can validate compatibility and an
elastic resize can re-shard (arrays are saved unsharded here; on a real
multi-host fleet each host would save its shard and restore does a
re-shard-on-load — the interface is the same).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# npz cannot round-trip ml_dtypes custom dtypes; store them bit-exactly as
# a same-width integer view and record the true dtype in the manifest.
_VIEW_ENCODE = {
    np.dtype(ml_dtypes.bfloat16): ("bfloat16", np.uint16),
    np.dtype(ml_dtypes.float8_e4m3fn): ("float8_e4m3fn", np.uint8),
    np.dtype(ml_dtypes.float8_e5m2): ("float8_e5m2", np.uint8),
}
_VIEW_DECODE = {name: dt for dt, (name, _) in _VIEW_ENCODE.items()}


def _encode(arr: np.ndarray):
    enc = _VIEW_ENCODE.get(arr.dtype)
    if enc is None:
        return arr, str(arr.dtype)
    name, view = enc
    return arr.view(view), name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DECODE:
        return arr.view(_VIEW_DECODE[dtype_name])
    return arr


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    out = Path(ckpt_dir) / f"step_{step:08d}"
    tmp = Path(ckpt_dir) / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    enc = {k: _encode(v) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **{k: a for k, (a, _) in enc.items()})
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": enc[k][1]}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if out.exists():
        shutil.rmtree(out)
    os.rename(tmp, out)                      # atomic publish
    _gc(ckpt_dir, keep)
    return str(out)


def save_async(ckpt_dir: str, step: int, tree, *, extra=None,
               keep: int = 3) -> threading.Thread:
    """Snapshot to host memory synchronously, write in a background thread."""
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}

    def _write():
        out = Path(ckpt_dir) / f"step_{step:08d}"
        tmp = Path(ckpt_dir) / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        enc = {k: _encode(v) for k, v in flat.items()}
        np.savez(tmp / "arrays.npz", **{k: a for k, (a, _) in enc.items()})
        manifest = {
            "step": step, "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": enc[k][1]}
                       for k, v in flat.items()},
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if out.exists():
            shutil.rmtree(out)
        os.rename(tmp, out)
        _gc(ckpt_dir, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(Path(ckpt_dir).glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = sorted(Path(ckpt_dir).glob("step_*"))
    return int(steps[-1].name.split("_")[1]) if steps else None


def restore(ckpt_dir: str, target_tree, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, dict]:
    """Restore into the structure of ``target_tree``.

    ``shardings`` (optional pytree of NamedSharding) re-shards on load —
    this is the elastic-resize path: the same checkpoint restores onto a
    smaller or larger mesh.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    src = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())
    data = np.load(src / "arrays.npz")
    flat_target = _flatten(target_tree)
    restored = {}
    for key, ref in flat_target.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = _decode(data[key],
                      manifest["leaves"].get(key, {}).get("dtype", ""))
        if list(arr.shape) != list(np.shape(ref)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(ref)}")
        restored[key] = arr
    flat_sh = _flatten(shardings) if shardings is not None else {}

    def rebuild(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = restored[key].astype(leaf.dtype)
        if key in flat_sh:
            return jax.device_put(arr, flat_sh[key])
        return jax.numpy.asarray(arr)

    tree = jax.tree_util.tree_map_with_path(rebuild, target_tree)
    return tree, manifest

"""Fault tolerance: heartbeat failure detection, checkpoint/restart,
elastic re-meshing, and straggler mitigation.

At 1000+ nodes the failure model is: some host stops making progress
(hardware fault, preemption) or persistently lags (straggler).  The
supervisor wraps the training loop:

  * every step each worker "heartbeats" (here: a callback hook; on a real
    fleet, a distributed KV store / GCS object);
  * a missed-deadline heartbeat marks the worker failed -> the job restores
    the latest checkpoint and continues, optionally on a *smaller* data
    axis (elastic re-mesh: the checkpoint re-shards on load because arrays
    are stored mesh-agnostically and the data pipeline is a pure function
    of (seed, step, index));
  * stragglers (per-step time > straggler_factor x EMA) are counted and,
    past a threshold, treated as failures (re-dispatch policy).

The failure injection hook makes all of this unit-testable on CPU.

This module covers the *training* loop.  The serving-side sibling —
worker respawn under ``RestartPolicy``, transparent request retry, the
per-class circuit breaker, and the ``repro.ual.faults`` deterministic
injection harness — lives in ``repro.ual.cluster.supervision`` /
``repro.ual.service.breaker`` (see ``docs/serving.md``,
"Self-healing").
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.checkpoint.checkpoint import latest_step, restore, save


class WorkerFailure(RuntimeError):
    def __init__(self, worker: int, reason: str):
        super().__init__(f"worker {worker}: {reason}")
        self.worker = worker
        self.reason = reason


@dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/ckpt"
    ckpt_every: int = 50
    heartbeat_timeout_s: float = 300.0
    straggler_factor: float = 2.5
    straggler_strikes: int = 3
    max_restarts: int = 5


@dataclass
class StragglerMonitor:
    factor: float = 2.5
    strikes_to_fail: int = 3
    ema: float = 0.0
    alpha: float = 0.1
    strikes: Dict[int, int] = field(default_factory=dict)

    def observe(self, worker: int, step_time: float) -> Optional[str]:
        """Returns 'straggler' | 'fail' | None."""
        if self.ema == 0.0:
            self.ema = step_time
            return None
        verdict = None
        if step_time > self.factor * self.ema:
            self.strikes[worker] = self.strikes.get(worker, 0) + 1
            verdict = ("fail" if self.strikes[worker] >= self.strikes_to_fail
                       else "straggler")
        else:
            self.strikes[worker] = 0
        self.ema = (1 - self.alpha) * self.ema + self.alpha * step_time
        return verdict


class Supervisor:
    """Checkpoint/restart training supervisor (single-controller view)."""

    def __init__(self, cfg: FaultConfig, *, make_state: Callable[[], dict],
                 step_fn: Callable[[dict, int], dict],
                 on_remesh: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.make_state = make_state
        self.step_fn = step_fn
        self.on_remesh = on_remesh
        self.monitor = StragglerMonitor(cfg.straggler_factor,
                                        cfg.straggler_strikes)
        self.restarts = 0
        self.events: List[dict] = []

    def run(self, n_steps: int,
            failure_hook: Optional[Callable[[int], Optional[Exception]]] = None
            ) -> dict:
        state = self._restore_or_init()
        step = int(state.pop("__step__", 0))
        while step < n_steps:
            try:
                if failure_hook is not None:
                    err = failure_hook(step)
                    if err is not None:
                        raise err
                t0 = time.time()
                state = self.step_fn(state, step)
                verdict = self.monitor.observe(0, time.time() - t0)
                if verdict == "fail":
                    raise WorkerFailure(0, "persistent straggler")
                step += 1
                if step % self.cfg.ckpt_every == 0 or step == n_steps:
                    save(self.cfg.ckpt_dir, step, state,
                         extra={"step": step})
            except (WorkerFailure, RuntimeError) as e:
                self.restarts += 1
                self.events.append({"step": step, "error": str(e),
                                    "restart": self.restarts})
                if self.restarts > self.cfg.max_restarts:
                    raise
                if isinstance(e, WorkerFailure) and self.on_remesh:
                    self.on_remesh(e.worker)
                state = self._restore_or_init()
                step = int(state.pop("__step__", 0))
        return state

    def _restore_or_init(self) -> dict:
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            s = self.make_state()
            s["__step__"] = 0
            return s
        template = self.make_state()
        state, manifest = restore(self.cfg.ckpt_dir, template, step=last)
        state["__step__"] = manifest["extra"].get("step", last)
        return state

"""Process-wide telemetry: flight-recorder tracing + a unified metrics
registry (see ``docs/observability.md``).

Two singletons, both swappable for tests::

    from repro import obs

    obs.tracer().enable()              # or REPRO_TRACE=1 in the env
    ...                                # run traced work
    obs.tracer().export_chrome("trace.json")   # open in Perfetto

    obs.registry().snapshot()          # every instrument + source, one dict

Tracing is off by default and a disabled tracer is a strict no-op on the
hot paths (``tracer().enabled`` is the one attribute producers check).
Set ``REPRO_TRACE=1`` to start the process with tracing on — that is also
how ``ClusterService(trace=True)`` turns it on inside spawned workers.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               Namespace, percentile)
from repro.obs.trace import Span, Tracer, validate_chrome

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Namespace",
    "Span", "Tracer", "enable_tracing", "percentile", "registry",
    "set_registry", "set_tracer", "tracer", "validate_chrome",
]

TRACE_ENV = "REPRO_TRACE"

_tracer: Optional[Tracer] = None
_registry: Optional[MetricsRegistry] = None


def tracer() -> Tracer:
    """The process-wide tracer (created on first use; enabled at birth
    when ``REPRO_TRACE`` is a truthy env value)."""
    global _tracer
    if _tracer is None:
        on = os.environ.get(TRACE_ENV, "").strip().lower()
        _tracer = Tracer(enabled=on not in ("", "0", "false", "off"))
    return _tracer


def set_tracer(new: Optional[Tracer]) -> Tracer:
    """Swap the process-wide tracer (tests, benches); returns the previous
    one so callers can restore it."""
    global _tracer
    prev = tracer()
    _tracer = new
    return prev


def enable_tracing(on: bool = True) -> Tracer:
    """Convenience: flip the global tracer's enabled flag."""
    t = tracer()
    t.enabled = bool(on)
    return t


def registry() -> MetricsRegistry:
    """The process-wide metrics registry (created on first use)."""
    global _registry
    if _registry is None:
        _registry = MetricsRegistry()
    return _registry


def set_registry(new: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Swap the process-wide registry (tests); returns the previous one."""
    global _registry
    prev = registry()
    _registry = new
    return prev

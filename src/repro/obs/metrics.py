"""Typed metrics instruments and the process-wide registry.

Three instrument kinds — :class:`Counter` (monotonic), :class:`Gauge`
(point-in-time, optionally a read-through callable) and
:class:`Histogram` (bounded sample window with percentiles) — live in a
:class:`MetricsRegistry` keyed by dotted name.  Producers across the
stack (``ServiceMetrics``, the pallas engine cache, the mapping cache,
the cluster router) register into the same registry, so
``obs.registry().snapshot()`` is one JSON-schema view of the whole
process where there used to be four bespoke dicts.  The bespoke
``stats()`` surfaces keep their existing shapes — they now *read
through* these instruments instead of private counters.

Namespacing: each producer instance calls ``registry.namespace("service")``
and gets a unique prefix (``service``, ``service#1`` …) so two services in
one process never collide; ``Namespace.drop()`` removes the instruments on
shutdown so the registry never grows without bound.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "Namespace"]


def percentile(samples: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile of an unsorted sample list (None when
    empty) — the one percentile definition every surface shares, so the
    service, cluster merge and benches can't drift apart."""
    if not samples:
        return None
    xs = sorted(samples)
    idx = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[idx]


class Counter:
    """Monotonic count (requests completed, samples executed …)."""
    kind = "counter"
    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, object]:
        v = self._value
        return {"type": "counter", "value": int(v) if v == int(v) else v}


class Gauge:
    """Point-in-time value.  Pass ``fn=`` for a read-through gauge that
    samples a live source (queue depth, cache size) at snapshot time."""
    kind = "gauge"
    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str,
                 fn: Optional[Callable[[], float]] = None) -> None:
        self.name = name
        self._value = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        self._value = v

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:
                return float("nan")
        return self._value

    def snapshot(self) -> Dict[str, object]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Bounded-window distribution: keeps the last ``window`` observations
    for percentiles plus lifetime count/total (so means survive window
    eviction).  ``samples()`` exposes the raw window — that is what the
    cluster merge ships between processes to compute *real* cluster
    percentiles instead of max-of-p99."""
    kind = "histogram"
    __slots__ = ("name", "window", "_buf", "_n", "_count", "_total",
                 "_max", "_lock")

    def __init__(self, name: str, window: int = 4096) -> None:
        self.name = name
        self.window = max(1, int(window))
        self._buf: List[float] = []
        self._n = 0                      # ring cursor
        self._count = 0
        self._total = 0.0
        self._max: Optional[float] = None
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            if len(self._buf) < self.window:
                self._buf.append(v)
            else:
                self._buf[self._n % self.window] = v
            self._n += 1
            self._count += 1
            self._total += v
            if self._max is None or v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def total(self) -> float:
        return self._total

    def samples(self) -> List[float]:
        with self._lock:
            return list(self._buf)

    def mean(self) -> Optional[float]:
        return (self._total / self._count) if self._count else None

    def percentile(self, q: float) -> Optional[float]:
        return percentile(self.samples(), q)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            xs = list(self._buf)
            count, total, mx = self._count, self._total, self._max
        return {
            "type": "histogram",
            "count": count,
            "mean": (total / count) if count else None,
            "p50": percentile(xs, 50),
            "p99": percentile(xs, 99),
            "max": mx,
            "window": len(xs),
        }


class Namespace:
    """A producer's private prefix inside the registry: instrument names
    are ``<prefix>.<name>``, and ``drop()`` removes them all when the
    producer shuts down."""

    def __init__(self, registry: "MetricsRegistry", prefix: str) -> None:
        self.registry = registry
        self.prefix = prefix

    def _full(self, name: str) -> str:
        return f"{self.prefix}.{name}"

    def counter(self, name: str) -> Counter:
        return self.registry.counter(self._full(name))

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        return self.registry.gauge(self._full(name), fn)

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self.registry.histogram(self._full(name), window)

    def drop(self) -> None:
        self.registry.drop_prefix(self.prefix)


class MetricsRegistry:
    """Dotted-name instrument registry with get-or-create semantics.

    Besides owned instruments, external aggregates can attach as
    *sources* — named callables sampled at snapshot time
    (``register_source("engine", engine.stats)``) — which is how the
    engine cache, mapping cache and router appear in the unified view
    without rewriting their internals.
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._instruments: Dict[str, object] = {}
        self._sources: Dict[str, Callable[[], object]] = {}
        self._ns_counts: Dict[str, int] = {}
        self.created_at = time.time()

    def _get_or_create(self, name: str, kind, *args):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = kind(name, *args)
            elif not isinstance(inst, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).kind}, requested {kind.kind}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get_or_create(name, Gauge)
        if fn is not None:
            g._fn = fn
        return g

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._get_or_create(name, Histogram, window)

    def get(self, name: str):
        return self._instruments.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    # -- namespaces ---------------------------------------------------------
    def namespace(self, base: str) -> Namespace:
        """A unique prefix for one producer instance: first caller gets
        ``base``, later ones ``base#1``, ``base#2`` …"""
        with self._lock:
            n = self._ns_counts.get(base, 0)
            self._ns_counts[base] = n + 1
            prefix = base if n == 0 else f"{base}#{n}"
        return Namespace(self, prefix)

    def drop_prefix(self, prefix: str) -> int:
        dot = prefix + "."
        with self._lock:
            doomed = [k for k in self._instruments
                      if k == prefix or k.startswith(dot)]
            for k in doomed:
                del self._instruments[k]
            for k in [k for k in self._sources
                      if k == prefix or k.startswith(dot)]:
                del self._sources[k]
                doomed.append(k)
        return len(doomed)

    # -- sources ------------------------------------------------------------
    def register_source(self, name: str, fn: Callable[[], object], *,
                        replace: bool = False) -> None:
        with self._lock:
            if name in self._sources and not replace:
                raise ValueError(f"source {name!r} already registered")
            self._sources[name] = fn

    def unregister_source(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    # -- unified view -------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """One JSON-serializable view of everything registered:
        ``{"metrics": {name: typed-dict}, "sources": {name: value}}``."""
        with self._lock:
            instruments = dict(self._instruments)
            sources = dict(self._sources)
        out: Dict[str, object] = {
            "metrics": {name: inst.snapshot()
                        for name, inst in sorted(instruments.items())},
            "sources": {},
            "uptime_s": time.time() - self.created_at,
        }
        for name, fn in sorted(sources.items()):
            try:
                out["sources"][name] = fn()
            except Exception as e:                # a dead source must not
                out["sources"][name] = {          # poison the whole view
                    "error": f"{type(e).__name__}: {e}"}
        return out

"""Flight-recorder tracing: nested spans, a bounded ring buffer, and
Chrome-trace/Perfetto export.

The tracer is the "where did request X spend its 40 ms?" half of the
telemetry subsystem (the metrics registry in ``repro.obs.metrics`` is the
aggregate half).  Design constraints, in order:

  1. **Disabled must be free.**  Every hot path guards on ``tracer.enabled``
     (a plain attribute read); a disabled ``span()`` returns a shared
     no-op singleton without reading the clock or allocating a ``Span``.
  2. **Cross-thread requests.**  A service request is born on the caller
     thread, pulled by the dispatcher thread and executed on a worker
     thread, so context-manager nesting cannot describe it.  Producers
     instead capture raw ``perf_counter`` stamps and materialize spans
     retrospectively with :meth:`Tracer.record`.
  3. **Cross-process timelines.**  ``perf_counter`` epochs differ between
     processes, so every tracer remembers ``epoch = time.time() -
     perf_counter()`` at birth; :meth:`Tracer.ingest` re-bases foreign
     spans onto the local clock so a cluster export renders one aligned
     timeline with one track per worker.
  4. **Flight recorder, not a log.**  The buffer is a bounded ring:
     old entries fall off, ``stats()["dropped"]`` says how many spans
     they carried, and memory stays bounded no matter how long the
     service runs.  Hot producers buffer whole request trees as single
     compact entries (:meth:`Tracer.record_tree`) and ``Span`` objects
     only materialize on the read side.

Spans export as Chrome trace-event JSON (``ph:"X"`` complete events plus
``ph:"M"`` track-name metadata) — load the file at https://ui.perfetto.dev
or ``chrome://tracing``.  ``python -m repro.obs.trace`` is the CLI: it
traces a demo service run end to end, or ``--inspect``\\ s an existing
trace file.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional

__all__ = ["Span", "Tracer"]


@dataclass
class Span:
    """One completed interval.  ``t0``/``dur_s`` are in the *owning
    tracer's* ``perf_counter`` timebase; ``Tracer.ingest`` re-bases them
    when a span crosses a process boundary (plain dataclass — picklable,
    so cluster workers ship these over the result pipe as-is)."""
    name: str
    t0: float
    dur_s: float
    trace_id: str
    span_id: str
    parent_id: Optional[str] = None
    cat: str = "default"
    track: Optional[str] = None
    args: Dict[str, object] = field(default_factory=dict)


class _PendingTree:
    """A whole request span tree buffered as ONE flight-recorder entry.

    The serving hot path records six spans per completed request;
    building six ``Span`` objects eagerly costs ~15 us on a loaded host
    — most of the enabled-tracing overhead.  Producers instead hand over
    raw ``(name, t0, t1, cat, args)`` tuples (root first) and the tracer
    materializes real spans lazily on the read side (``spans()`` /
    ``drain()`` / export), which is cold.  Expansion is cached so a
    tree's span ids are stable across reads."""
    __slots__ = ("trace_id", "track", "items", "_spans")

    def __init__(self, trace_id: str, track: str, items) -> None:
        self.trace_id = trace_id
        self.track = track
        self.items = items
        self._spans: Optional[List[Span]] = None

    def weight(self) -> int:
        return len(self.items)

    def expand(self, tracer: "Tracer") -> List[Span]:
        if self._spans is None:
            root_id = tracer.new_span_id()
            out = []
            for i, (name, t0, t1, cat, args) in enumerate(self.items):
                out.append(Span(
                    name=name, t0=t0, dur_s=max(0.0, t1 - t0),
                    trace_id=self.trace_id,
                    span_id=root_id if i == 0 else tracer.new_span_id(),
                    parent_id=None if i == 0 else root_id,
                    cat=cat, track=self.track,
                    args=args if args is not None else {}))
            self._spans = out
        return self._spans


def _entry_weight(entry) -> int:
    return 1 if isinstance(entry, Span) else entry.weight()


class _NullSpan:
    """The shared disabled-tracer span: ``with tracer.span(...)`` costs one
    attribute read and nothing else.  All fields are inert placeholders."""
    __slots__ = ()
    trace_id = ""
    span_id = ""

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **kwargs) -> None:
        return None


_NULL_SPAN = _NullSpan()


class _ActiveSpan:
    """A live context-manager span: pushed on the owning tracer's
    thread-local stack on ``__enter__`` (so children find their parent),
    recorded on ``__exit__``."""
    __slots__ = ("_tracer", "name", "cat", "trace_id", "span_id",
                 "parent_id", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 trace_id: Optional[str], parent_id: Optional[str],
                 args: Optional[dict]) -> None:
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.args = dict(args) if args else {}
        self._t0 = 0.0

    def set(self, **kwargs) -> None:
        """Attach attributes to the span while it is open."""
        self.args.update(kwargs)

    def __enter__(self) -> "_ActiveSpan":
        tr = self._tracer
        stack = tr._stack()
        if self.trace_id is None:
            if stack:
                top = stack[-1]
                self.trace_id = top.trace_id
                if self.parent_id is None:
                    self.parent_id = top.span_id
            else:
                self.trace_id = tr.new_trace_id()
        self.span_id = tr.new_span_id()
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:            # tolerate interleaved exits
            stack.remove(self)
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self._tracer._record(Span(
            name=self.name, t0=self._t0, dur_s=t1 - self._t0,
            trace_id=self.trace_id, span_id=self.span_id,
            parent_id=self.parent_id, cat=self.cat,
            track=threading.current_thread().name, args=self.args))


class Tracer:
    """Process-wide span recorder with a bounded ring buffer.

    ``enabled`` is the single hot-path gate: producers read it as a plain
    attribute and skip all capture work when False.  The buffer, counters
    and id generators are guarded by one lock — span *recording* is one
    deque append under that lock, span *capture* (timestamps) is lock-free
    on the producer's stack.
    """

    def __init__(self, enabled: bool = False, capacity: int = 32768) -> None:
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        # Wall-clock anchor for this tracer's perf_counter timebase: lets
        # export and cross-process ingest align spans from different
        # processes on one absolute timeline.
        self.epoch = time.time() - time.perf_counter()
        self._lock = threading.Lock()
        self._buf = _RingList(self.capacity)
        self._recorded = 0
        self._dropped = 0
        self._ids = itertools.count(1)
        self._local = threading.local()

    # -- lifecycle ----------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    # -- id minting ---------------------------------------------------------
    # lock-free: next() on itertools.count is atomic under CPython, and
    # id minting sits on the traced-request hot path (one trace id + six
    # span ids per served request)
    def new_trace_id(self) -> str:
        return f"t{next(self._ids):08x}"

    def new_span_id(self) -> str:
        return f"s{next(self._ids):08x}"

    # -- capture ------------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def current(self) -> Optional[_ActiveSpan]:
        """The innermost open span on this thread, or None."""
        stack = getattr(self._local, "stack", None)
        return stack[-1] if stack else None

    def span(self, name: str, cat: str = "default", *,
             trace: Optional[str] = None, parent: Optional[str] = None,
             args: Optional[dict] = None):
        """Context-manager span.  Nested uses inherit trace/parent from the
        enclosing span on this thread.  When the tracer is disabled this
        returns a shared no-op singleton (no clock read, no allocation)."""
        if not self.enabled:
            return _NULL_SPAN
        return _ActiveSpan(self, name, cat, trace, parent, args)

    def record(self, name: str, t0: float, t1: float, *,
               cat: str = "default", trace: Optional[str] = None,
               parent: Optional[str] = None, track: Optional[str] = None,
               args: Optional[dict] = None) -> str:
        """Retrospectively record a span from two ``perf_counter`` stamps —
        the cross-thread producer API (service requests capture stamps on
        three different threads, then materialize the spans at resolve
        time).  Returns the new span id so callers can parent children
        under it."""
        if trace is None:
            cur = self.current()
            if cur is not None:
                trace = cur.trace_id
                if parent is None:
                    parent = cur.span_id
            else:
                trace = self.new_trace_id()
        sid = self.new_span_id()
        self._record(Span(
            name=name, t0=t0, dur_s=max(0.0, t1 - t0), trace_id=trace,
            span_id=sid, parent_id=parent, cat=cat,
            track=track or threading.current_thread().name,
            args=dict(args) if args else {}))
        return sid

    def _record(self, span: Span) -> None:
        with self._lock:
            evicted = self._buf.append(span)
            self._recorded += 1
            if evicted is not None:
                self._dropped += _entry_weight(evicted)

    def record_many(self, spans: Iterable[Span]) -> None:
        """Record pre-built spans under ONE lock acquisition — the bulk
        producer API for paths that materialize several spans at once."""
        with self._lock:
            for s in spans:
                evicted = self._buf.append(s)
                self._recorded += 1
                if evicted is not None:
                    self._dropped += _entry_weight(evicted)

    def record_tree(self, trace_id: str, items, *,
                    track: Optional[str] = None) -> None:
        """Buffer a whole span tree — ``(name, t0, t1, cat, args)`` tuples,
        root first — as ONE ring entry, deferring ``Span`` construction to
        the read side.  This is the serving hot path's producer API: cost
        is one small object plus one append, ~5x cheaper than recording
        the six spans eagerly."""
        entry = _PendingTree(
            trace_id, track or threading.current_thread().name, items)
        with self._lock:
            evicted = self._buf.append(entry)
            self._recorded += len(items)
            if evicted is not None:
                self._dropped += _entry_weight(evicted)

    # -- readout ------------------------------------------------------------
    def spans(self, trace_id: Optional[str] = None) -> List[Span]:
        """Snapshot of the buffer (oldest first), optionally filtered to
        one trace.  Pending trees materialize here (under the lock, so
        their span ids are minted exactly once)."""
        with self._lock:
            out: List[Span] = []
            for e in self._buf.items():
                if isinstance(e, Span):
                    out.append(e)
                else:
                    out.extend(e.expand(self))
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def drain(self) -> List[Span]:
        """Pop and return everything buffered — the cluster-worker shipping
        primitive (each span leaves the worker exactly once)."""
        with self._lock:
            out: List[Span] = []
            for e in self._buf.items():
                if isinstance(e, Span):
                    out.append(e)
                else:
                    out.extend(e.expand(self))
            self._buf.clear()
        return out

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._recorded = 0
            self._dropped = 0

    def ingest(self, spans: Iterable[Span], *, epoch: Optional[float] = None,
               track_prefix: Optional[str] = None) -> int:
        """Adopt spans recorded by another tracer (typically another
        process).  ``epoch`` is the foreign tracer's wall-clock anchor;
        span timestamps are re-based onto this tracer's timebase so one
        export renders an aligned timeline.  ``track_prefix`` namespaces
        the foreign tracks (``worker0/engine-0`` …).  Works regardless of
        ``self.enabled`` — ingest is recorder input, not a hot path."""
        shift = 0.0 if epoch is None else epoch - self.epoch
        n = 0
        for s in spans:
            track = s.track or "main"
            if track_prefix:
                track = f"{track_prefix}/{track}"
            self._record(Span(
                name=s.name, t0=s.t0 + shift, dur_s=s.dur_s,
                trace_id=s.trace_id, span_id=s.span_id,
                parent_id=s.parent_id, cat=s.cat, track=track,
                args=s.args))
            n += 1
        return n

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {
                "enabled": self.enabled,
                "capacity": self.capacity,
                "buffered": sum(_entry_weight(e) for e in self._buf.items()),
                "recorded": self._recorded,
                "dropped": self._dropped,
            }

    # -- structure ----------------------------------------------------------
    def tree(self, trace_id: str) -> List[dict]:
        """Nested view of one trace: a list of root nodes, each
        ``{"name", "dur_ms", "args", "children": [...]}``."""
        spans = self.spans(trace_id)
        nodes = {s.span_id: {"name": s.name, "dur_ms": s.dur_s * 1e3,
                             "t0": s.t0, "args": s.args, "children": []}
                 for s in spans}
        roots = []
        for s in spans:
            node = nodes[s.span_id]
            parent = nodes.get(s.parent_id) if s.parent_id else None
            (parent["children"] if parent else roots).append(node)
        for node in nodes.values():
            node["children"].sort(key=lambda n: n["t0"])
        roots.sort(key=lambda n: n["t0"])
        return roots

    @staticmethod
    def render_tree(roots: List[dict], indent: int = 0) -> str:
        lines = []
        for node in roots:
            extra = ""
            if node["args"]:
                pairs = ", ".join(f"{k}={v}" for k, v in node["args"].items())
                extra = f"  [{pairs}]"
            lines.append(f"{'  ' * indent}{node['name']:<28s} "
                         f"{node['dur_ms']:8.3f} ms{extra}")
            if node["children"]:
                lines.append(Tracer.render_tree(node["children"], indent + 1))
        return "\n".join(lines)

    # -- export -------------------------------------------------------------
    def to_chrome(self) -> Dict[str, object]:
        """Chrome trace-event representation of the buffer: one ``ph:"X"``
        complete event per span plus ``ph:"M"`` metadata naming each
        track.  Tracks map to (pid, tid) rows — the local process is pid 0
        with one tid per thread; ingested ``prefix/...`` tracks get their
        own pid per prefix so Perfetto renders one lane per worker."""
        spans = self.spans()
        events: List[dict] = []
        pids: Dict[str, int] = {}
        tids: Dict[tuple, int] = {}
        t_base = min((s.t0 for s in spans), default=0.0)
        for s in spans:
            track = s.track or "main"
            group, _, lane = track.partition("/")
            if not lane:
                group, lane = "proc", track
            pid = pids.get(group)
            if pid is None:
                pid = pids[group] = len(pids)
                events.append({"name": "process_name", "ph": "M", "pid": pid,
                               "tid": 0, "args": {"name": group}})
            tid = tids.get((group, lane))
            if tid is None:
                tid = tids[(group, lane)] = sum(
                    1 for k in tids if k[0] == group)
                events.append({"name": "thread_name", "ph": "M", "pid": pid,
                               "tid": tid, "args": {"name": lane}})
            args = {"trace_id": s.trace_id, "span_id": s.span_id}
            if s.parent_id:
                args["parent_id"] = s.parent_id
            args.update({k: _jsonable(v) for k, v in s.args.items()})
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": (s.t0 - t_base) * 1e6, "dur": s.dur_s * 1e6,
                "pid": pid, "tid": tid, "args": args,
            })
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "producer": "repro.obs",
                "epoch_unix_s": self.epoch + t_base,
            },
        }

    def export_chrome(self, path) -> Path:
        """Write the buffer as Chrome trace-event JSON; open the file at
        https://ui.perfetto.dev (or chrome://tracing)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as f:
            json.dump(self.to_chrome(), f)
        return path


class _RingList:
    """Ring buffer over a plain list — append returns the entry it
    evicted, if any (the deque API hides evictions, and the drop counter
    is part of the flight-recorder contract).  Entries are ``Span``s or
    ``_PendingTree``s."""
    __slots__ = ("_cap", "_items", "_head")

    def __init__(self, capacity: int) -> None:
        self._cap = max(1, capacity)
        self._items: list = []
        self._head = 0

    def append(self, item):
        if len(self._items) < self._cap:
            self._items.append(item)
            return None
        evicted = self._items[self._head]
        self._items[self._head] = item
        self._head = (self._head + 1) % self._cap
        return evicted

    def items(self) -> list:
        return self._items[self._head:] + self._items[:self._head]

    def clear(self) -> None:
        self._items = []
        self._head = 0

    def __len__(self) -> int:
        return len(self._items)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def validate_chrome(doc: dict) -> List[str]:
    """Schema check for an exported trace document; returns a list of
    problems (empty = valid).  Used by the smoke telemetry gate and the
    CLI ``--inspect`` mode."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M"):
            problems.append(f"event {i}: unexpected ph {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        if ph == "X":
            for key in ("ts", "dur"):
                if not isinstance(ev.get(key), (int, float)):
                    problems.append(f"event {i}: {key!r} not numeric")
    return problems


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.trace",
        description="Trace a demo service run end to end and export "
                    "Chrome-trace JSON, or inspect an existing trace file.")
    ap.add_argument("--out", default="artifacts/trace/demo_trace.json",
                    help="output path for the Chrome-trace JSON")
    ap.add_argument("--requests", type=int, default=16,
                    help="demo requests to trace (default 16)")
    ap.add_argument("--inspect", metavar="FILE",
                    help="validate + summarize an existing trace file "
                         "instead of running the demo")
    args = ap.parse_args(argv)

    if args.inspect:
        with open(args.inspect) as f:
            doc = json.load(f)
        problems = validate_chrome(doc)
        events = [e for e in doc.get("traceEvents", ())
                  if isinstance(e, dict)]
        spans = [e for e in events if e.get("ph") == "X"]
        names: Dict[str, int] = {}
        for ev in spans:
            names[ev["name"]] = names.get(ev["name"], 0) + 1
        print(f"{args.inspect}: {len(spans)} spans, "
              f"{len(events) - len(spans)} metadata events")
        for name, n in sorted(names.items(), key=lambda kv: -kv[1]):
            print(f"  {n:6d}  {name}")
        for p in problems:
            print(f"  PROBLEM: {p}")
        return 1 if problems else 0

    # Demo: trace one service run on the sim backend.
    import numpy as np
    from repro import obs, ual

    tracer = obs.Tracer(enabled=True)
    prev = obs.set_tracer(tracer)
    try:
        target = ual.Target.from_name("hycube", rows=4, cols=4)
        program = ual.Program.from_kernel(
            "gemm", n_banks=target.fabric.n_mem_ports)
        rng = np.random.default_rng(0)
        with ual.Service(max_batch=8, max_wait_ms=2.0) as svc:
            futs = [svc.submit(program, target, program.random_inputs(rng),
                               tenant=f"tenant{i % 2}")
                    for i in range(args.requests)]
            for fut in futs:
                fut.result(timeout=60.0)
        first = futs[0].info.get("trace", {})
        if first:
            print("request 0 breakdown:",
                  {k: round(v, 3) for k, v in first.items()
                   if isinstance(v, (int, float))})
            print(Tracer.render_tree(tracer.tree(first["trace_id"])))
        out = tracer.export_chrome(args.out)
        n = len(tracer.spans())
        print(f"wrote {n} spans -> {out} "
              f"(open at https://ui.perfetto.dev)")
    finally:
        obs.set_tracer(prev)
    return 0


if __name__ == "__main__":               # pragma: no cover
    raise SystemExit(_main())

"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh) cell:

    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = coll_bytes  / (chips * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are not in cost_analysis, so we parse the post-partitioning HLO text
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) gives the useful-compute ratio.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

# -- TPU v5e hardware constants ------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
LINK_BW = 50e9                    # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:%[\w.\-]+ = )?\(?([a-z0-9\[\],\s{}():/#\w.\-]*?)"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", ls)
        if not m:
            continue
        kind = m.group(2)
        if "-done(" in ls:
            continue                       # avoid double counting start/done
        # operand types appear inside the call parens
        inside = ls[ls.index("(") + 1:]
        b = _shape_bytes(inside)
        if b == 0:
            # fallback: result type on the lhs
            b = _shape_bytes(ls.split("=")[0] if "=" in ls else ls)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    return out


@dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    per_device_hbm_peak: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Lower-bound step time = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful compute vs the machine at the step-time lower bound."""
        if self.step_time_lb == 0:
            return 0.0
        return (self.model_flops / self.step_time_lb) \
            / (self.chips * PEAK_FLOPS_BF16)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_hbm_peak": self.per_device_hbm_peak,
            "collectives": self.collectives,
        }


def model_flops(cfg, shape_kind: str, seq: int, batch: int,
                decode: bool = False) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference forward)."""
    n_active = cfg.active_param_count()
    tokens = batch * (1 if decode else seq)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * tokens


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: Dict[str, float], hlo_text: str, mflops: float,
            mem_peak: float = 0.0) -> RooflineResult:
    colls = parse_collectives(hlo_text)
    cbytes = sum(v["bytes"] for v in colls.values())
    return RooflineResult(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=cbytes, model_flops=mflops,
        per_device_hbm_peak=mem_peak, collectives=colls,
    )


def analyze_per_device(arch: str, shape: str, mesh_name: str, chips: int,
                       hlo_cost: Dict[str, object], mflops: float,
                       mem_peak: float = 0.0) -> "RooflineResult":
    """Roofline from the trip-count-aware per-device HLO cost model.

    The compiled module is the per-device SPMD program, so all quantities
    are already per chip: ``hlo_flops`` etc. store per-device values and
    the roofline terms divide by single-chip peaks (chips kept for the
    useful-compute ratio).
    """
    res = RooflineResult(
        arch=arch, shape=shape, mesh=mesh_name, chips=1,
        hlo_flops=float(hlo_cost["flops_per_device"]),
        hlo_bytes=float(hlo_cost["bytes_per_device"]),
        collective_bytes=float(hlo_cost["collective_wire_bytes_per_device"]),
        model_flops=mflops / chips,        # useful flops per chip
        per_device_hbm_peak=mem_peak,
        collectives=dict(hlo_cost["collectives"]),
    )
    return res

"""Per-op HBM-traffic profile of a dry-run cell (§Perf memory profiler).

    PYTHONPATH=src python -m repro.analysis.memprof --arch gemma3-27b \
        --shape train_4k [--overrides '{"shard_strategy":"fsdp"}']
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

from repro.analysis.collectives import memory_main   # noqa: E402

if __name__ == "__main__":
    memory_main()

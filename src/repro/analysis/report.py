"""Markdown report generation for EXPERIMENTS.md from dry-run artifacts.

    PYTHONPATH=src python -m repro.analysis.report [--dir artifacts/dryrun]

Emits the §Dry-run and §Roofline tables: per (arch x shape x mesh) cell the
compile status, per-device memory, the three roofline terms, the dominant
bottleneck, useful-FLOPs ratio and roofline fraction, plus a one-line
improvement note derived from the dominant term.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _note(r: dict) -> str:
    b = r["bottleneck"]
    if b == "memory":
        if r.get("useful_flops_ratio", 1) < 0.5:
            return "cut remat re-reads (checkpoint policy) / fuse scan body"
        return "reduce activation traffic: larger microbatch tiles, fused ops"
    if b == "collective":
        colls = r.get("collectives", {})
        top = max(colls, key=lambda k: colls[k].get(
            "wire_bytes", colls[k].get("bytes", 0))) if colls else "?"
        return f"dominant {top}: reshard to shrink it or overlap with compute"
    return "compute-bound: good; push MXU utilization (layout, fusion)"


def load(dry_dir: Path, tag: str = ""):
    cells = []
    for f in sorted(dry_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if "cell" not in rec:
            continue                     # modeled/aux artifacts
        is_tagged = bool(rec.get("overrides"))
        if (tag == "") != (not is_tagged):
            continue
        cells.append(rec)
    return cells


def dryrun_table(cells) -> str:
    out = ["| cell | status | compile s | args GB/dev | temp GB/dev | note |",
           "|---|---|---|---|---|---|"]
    for rec in cells:
        cell = rec["cell"]
        if rec["status"] == "skipped":
            out.append(f"| {cell} | skipped | — | — | — | {rec['reason']} |")
            continue
        if rec["status"] == "error":
            out.append(f"| {cell} | ERROR | — | — | — |"
                       f" {rec.get('error', '')[:60]} |")
            continue
        m = rec.get("memory_analysis", {})
        args_gb = m.get("argument_size_in_bytes", 0) / 2**30
        temp_gb = m.get("temp_size_in_bytes", 0) / 2**30
        out.append(f"| {cell} | ok | {rec['compile_s']:.0f} "
                   f"| {args_gb:.2f} | {temp_gb:.2f} | |")
    return "\n".join(out)


def roofline_table(cells, mesh: str = "pod16x16") -> str:
    out = ["| arch | shape | bound | t_comp s | t_mem s | t_coll s "
           "| useful | roofline | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for rec in cells:
        if rec["status"] != "ok" or rec["mesh"] != mesh:
            continue
        r = rec["roofline"]
        out.append(
            f"| {rec['arch']} | {rec['shape']} | **{r['bottleneck']}** "
            f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {_note(r)} |")
    return "\n".join(out)


def summary(cells) -> dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    bn = {}
    fracs = []
    for c in ok:
        if c["mesh"] != "pod16x16":
            continue
        b = c["roofline"]["bottleneck"]
        bn[b] = bn.get(b, 0) + 1
        fracs.append((c["roofline"]["roofline_fraction"], c["cell"]))
    fracs.sort()
    return {"ok": len(ok), "skipped": len(skipped), "errors": len(err),
            "bottlenecks_single_pod": bn,
            "worst_cells": fracs[:5], "best_cells": fracs[-5:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    cells = load(Path(args.dir), args.tag)
    print("## Dry-run status\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod 16x16 unless noted)\n")
    print(roofline_table(cells, args.mesh))
    print("\n## Summary\n")
    print(json.dumps(summary(cells), indent=1))


if __name__ == "__main__":
    main()

"""Compile-time CGRA configuration verifier — static diagnostics.

Morpher pairs compilation with *validation*: a mapped configuration is
only trusted once checked.  Runtime validation (the DFG-interpreter
oracle) proves value-level correctness, but several hazard classes are
decidable **statically** over the modulo schedule — the schedule is
periodic, the interconnect is compiler-scheduled, and the dense lowered
tables (``core.lowering.LinkedConfig``) expose every operand source
directly.  This pass walks a ``MachineConfig`` + ``LinkedConfig`` (+ the
``Program`` I/O spec when available) and emits structured diagnostics
*before* a single cycle is simulated, so a broken config fails
``ual.compile()`` instead of surfacing deep inside the batched simulator
or the Pallas engine (or worse: silently, as an operand reading absent).

Diagnostic codes (stable — see ``docs/diagnostics.md`` for the full
reference table):

  ======== ======== ====================================================
  code     severity meaning
  ======== ======== ====================================================
  UAL001   error    scratchpad port oversubscription in one II slot
  UAL002   error    same-cycle write-write race (constant-foldable
                    scratchpad addresses)
  UAL003   warning  same-cycle load/store overlap at one constant
                    address (PE-order dependent value)
  UAL004   error    unresolved wire chain: a ``SRC_IN`` operand select
                    (or wire-fed register write) whose driver fixed
                    point never resolves — lowers to a silent ``K_NONE``
  UAL005   error    bypass chain longer than ``fabric.max_hops``
  UAL006   warning  use-before-def: register read never written in any
                    schedule slot (reads as constant 0)
  UAL007   warning  dead code: an instruction's result is consumed by
                    nothing (no operand, no register write, no store)
  UAL008   error    table integrity: out-of-range PE/register index or
                    illegal source kind in the dense tables
  UAL009   error    schedule inconsistency: an instruction's ``t0`` is
                    not congruent to its slot modulo II / negative
                    recurrence distance
  UAL010   error    memory op placed on a PE without scratchpad access
  UAL011   info     memory-port budget unknown (``n_mem_ports == 0``) —
                    the oversubscription check is disabled
  UAL012   error    constant-foldable scratchpad address out of bounds
                    for the program's data layout
  ======== ======== ====================================================

The verifier is pure analysis: it never mutates its inputs and never
lowers when handed a pre-lowered artifact (the pipeline's ``verify``
pass reuses the lowering pass's output, so verification adds zero
re-lowering).  Handed *only* a ``LinkedConfig`` (tables shipped across
processes without the source config), the wire-level detectors fall back
to the ``LinkedConfig.unresolved_inputs`` counter stamped at lowering
time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.lowering import (K_CONST, K_NONE, K_O, K_R, K_RESULT,
                                 LinkedConfig, link_config)
from repro.core.machine import (OPC, OPCODES, SRC_IN, MachineConfig, XB_IN,
                                XB_NONE, XB_O, XB_REG)

ERROR, WARNING, INFO = "error", "warning", "info"

#: code -> (default severity, one-line meaning) — the stable registry;
#: ``docs/diagnostics.md`` renders this table for humans
CODES: Dict[str, Tuple[str, str]] = {
    "UAL001": (ERROR, "scratchpad port oversubscription in one II slot"),
    "UAL002": (ERROR, "same-cycle write-write race at one scratchpad "
                      "address"),
    "UAL003": (WARNING, "same-cycle load/store overlap at one scratchpad "
                        "address"),
    "UAL004": (ERROR, "unresolved wire chain (operand lowers to a silent "
                      "K_NONE)"),
    "UAL005": (ERROR, "bypass chain exceeds fabric.max_hops"),
    "UAL006": (WARNING, "use-before-def: register read never written"),
    "UAL007": (WARNING, "dead code: instruction result consumed by "
                        "nothing"),
    "UAL008": (ERROR, "table integrity: out-of-range index or illegal "
                      "source kind"),
    "UAL009": (ERROR, "schedule inconsistency (t0 vs slot, negative "
                      "dist)"),
    "UAL010": (ERROR, "memory op on a PE without scratchpad access"),
    "UAL011": (INFO, "memory-port budget unknown; port check disabled"),
    "UAL012": (ERROR, "constant scratchpad address out of bounds"),
}


@dataclass(frozen=True)
class Diagnostic:
    """One finding: a stable code, severity, locus and rendering."""

    code: str
    severity: str
    message: str
    slot: Optional[int] = None       # II slot, when the finding has one
    pe: Optional[int] = None         # PE index, when the finding has one

    @property
    def locus(self) -> str:
        parts = []
        if self.slot is not None:
            parts.append(f"slot {self.slot}")
        if self.pe is not None:
            parts.append(f"pe {self.pe}")
        return "/".join(parts)

    def render(self) -> str:
        at = f" [{self.locus}]" if self.locus else ""
        return f"{self.code} {self.severity}{at}: {self.message}"

    def __str__(self) -> str:
        return self.render()


@dataclass
class CheckReport:
    """The collected diagnostics of one verification run."""

    name: str = ""                   # "program @ fabric", for rendering
    diagnostics: List[Diagnostic] = field(default_factory=list)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def infos(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == INFO]

    @property
    def ok(self) -> bool:
        """No error-severity findings (warnings/infos don't fail)."""
        return not self.errors

    def codes(self) -> Set[str]:
        return {d.code for d in self.diagnostics}

    def counts(self) -> Dict[str, int]:
        return {"errors": len(self.errors), "warnings": len(self.warnings),
                "infos": len(self.infos)}

    def summary(self) -> str:
        c = self.counts()
        if not self.diagnostics:
            return "clean (0 findings)"
        return (f"{c['errors']} error(s), {c['warnings']} warning(s), "
                f"{c['infos']} info(s): {', '.join(sorted(self.codes()))}")

    def render(self) -> str:
        head = f"verify {self.name}: " if self.name else "verify: "
        lines = [head + self.summary()]
        lines += ["  " + d.render() for d in self.diagnostics]
        return "\n".join(lines)

    def to_json(self) -> Dict[str, object]:
        return {"name": self.name, "ok": self.ok, **self.counts(),
                "codes": sorted(self.codes()),
                "diagnostics": [{"code": d.code, "severity": d.severity,
                                 "slot": d.slot, "pe": d.pe,
                                 "message": d.message}
                                for d in self.diagnostics]}

    def __str__(self) -> str:
        return self.render()


class VerifyError(RuntimeError):
    """A configuration failed static verification (error-severity
    findings).  Carries the full ``CheckReport`` as ``.report``; the
    exception message is the rendered report."""

    def __init__(self, report: CheckReport):
        super().__init__(report.render())
        self.report = report


# ---------------------------------------------------------------------------
# Detectors over the dense lowered tables
# ---------------------------------------------------------------------------

_MEM_OPC = (OPC["LOAD"], OPC["STORE"])


def _fires(linked: LinkedConfig, s: int, p: int) -> bool:
    """Whether the instruction at (slot, pe) can ever fire."""
    return (linked.scalar[s, p, 0] != OPC["NOP"]
            and linked.scalar[s, p, 3] >= 0)


def _check_integrity(linked: LinkedConfig, out: List[Diagnostic]) -> None:
    """UAL008 (index/kind range) + UAL009 (schedule consistency)."""
    S, P, R = linked.II, linked.n_pes, linked.n_regs
    ops_kinds = {K_NONE, K_O, K_R, K_CONST}
    regw_kinds = {K_NONE, K_O, K_R, K_RESULT}
    for s in range(S):
        for p in range(P):
            opc = int(linked.scalar[s, p, 0])
            t0 = int(linked.scalar[s, p, 3])
            if not 0 <= opc < len(OPCODES):
                out.append(Diagnostic("UAL008", ERROR,
                                      f"opcode {opc} out of range "
                                      f"[0, {len(OPCODES)})", s, p))
                continue
            if opc != OPC["NOP"] and t0 >= 0 and t0 % S != s:
                out.append(Diagnostic(
                    "UAL009", ERROR,
                    f"{OPCODES[opc]} has t0={t0} but t0 % II = "
                    f"{t0 % S} != slot {s}", s, p))
            for k in range(3):
                kind, pe, reg, dist = (int(v) for v in
                                       linked.ops[s, p, k, :4])
                if kind not in ops_kinds:
                    out.append(Diagnostic(
                        "UAL008", ERROR,
                        f"operand {k} has illegal source kind {kind}"
                        + (" (K_RESULT is regw-only)"
                           if kind == K_RESULT else ""), s, p))
                    continue
                if kind in (K_O, K_R) and not 0 <= pe < P:
                    out.append(Diagnostic(
                        "UAL008", ERROR,
                        f"operand {k} reads PE {pe}, fabric has {P}",
                        s, p))
                if kind == K_R and not 0 <= reg < R:
                    out.append(Diagnostic(
                        "UAL008", ERROR,
                        f"operand {k} reads register {reg}, PEs have "
                        f"{R}", s, p))
                if dist < 0:
                    out.append(Diagnostic(
                        "UAL009", ERROR,
                        f"operand {k} has negative recurrence distance "
                        f"{dist}", s, p))
            for r in range(R):
                kind, pe, reg = (int(v) for v in linked.regw[s, p, r])
                if kind not in regw_kinds:
                    out.append(Diagnostic(
                        "UAL008", ERROR,
                        f"register write r{r} has illegal source kind "
                        f"{kind}", s, p))
                    continue
                if kind in (K_O, K_R, K_RESULT) and not 0 <= pe < P:
                    out.append(Diagnostic(
                        "UAL008", ERROR,
                        f"register write r{r} reads PE {pe}, fabric "
                        f"has {P}", s, p))
                if kind == K_R and not 0 <= reg < R:
                    out.append(Diagnostic(
                        "UAL008", ERROR,
                        f"register write r{r} reads register {reg}, "
                        f"PEs have {R}", s, p))


def _check_ports(linked: LinkedConfig, out: List[Diagnostic]) -> None:
    """UAL001 (static per-slot port pressure) + UAL011 (unknown budget).

    Instructions sharing an II slot fire in the same cycles once every
    firing window has opened (the schedule is periodic), so the per-slot
    memory-op count IS the steady-state port pressure — what the engines
    otherwise only discover mid-run via ``check_ports``.
    """
    limit = linked.n_mem_ports
    if limit <= 0:
        out.append(Diagnostic(
            "UAL011", INFO,
            "n_mem_ports=0 (unknown/unbounded): port oversubscription "
            "is not statically checkable and the engines' runtime "
            "check is disabled"))
        return
    for s in range(linked.II):
        mem_pes = [p for p in range(linked.n_pes)
                   if int(linked.scalar[s, p, 0]) in _MEM_OPC
                   and _fires(linked, s, p)]
        if len(mem_pes) > limit:
            out.append(Diagnostic(
                "UAL001", ERROR,
                f"{len(mem_pes)} memory ops on PEs {mem_pes} share "
                f"slot {s}, scratchpad has {limit} port(s)", s))


def _check_mem_pes(linked: LinkedConfig, out: List[Diagnostic]) -> None:
    """UAL010: LOAD/STORE on a PE without LSU access."""
    mem_set = set(linked.mem_pes)
    for s in range(linked.II):
        for p in range(linked.n_pes):
            opc = int(linked.scalar[s, p, 0])
            if (opc in _MEM_OPC and _fires(linked, s, p)
                    and p not in mem_set):
                out.append(Diagnostic(
                    "UAL010", ERROR,
                    f"{OPCODES[opc]} on PE {p}, which has no scratchpad "
                    f"access (mem PEs: {sorted(mem_set)})", s, p))


def _const_addr_mem_ops(linked: LinkedConfig, s: int
                        ) -> List[Tuple[int, bool, int]]:
    """Constant-foldable memory ops of one slot: (pe, is_load, addr).

    A LOAD with no index operand reads ``const``; a STORE with no second
    operand writes ``const`` — both decidable without executing.
    """
    ops = []
    for p in range(linked.n_pes):
        if not _fires(linked, s, p):
            continue
        opc = int(linked.scalar[s, p, 0])
        const = int(linked.scalar[s, p, 1])
        if opc == OPC["LOAD"] and linked.ops[s, p, 0, 0] == K_NONE:
            ops.append((p, True, const))
        elif opc == OPC["STORE"] and linked.ops[s, p, 1, 0] == K_NONE:
            ops.append((p, False, const))
    return ops


def _check_mem_conflicts(linked: LinkedConfig, out: List[Diagnostic],
                         total_words: Optional[int]) -> None:
    """UAL002 (write-write), UAL003 (load/store overlap), UAL012 (bounds).

    Same-(pe, register) write-write races are structurally unrepresentable
    in the dense tables (one ``regw`` row per destination — ``emit_config``
    raises on collision), so the same-cycle race surface that remains is
    the shared scratchpad at constant-foldable addresses.
    """
    for s in range(linked.II):
        const_ops = _const_addr_mem_ops(linked, s)
        by_addr: Dict[int, List[Tuple[int, bool]]] = {}
        for p, is_load, addr in const_ops:
            by_addr.setdefault(addr, []).append((p, is_load))
            if total_words is not None and not 0 <= addr < total_words:
                out.append(Diagnostic(
                    "UAL012", ERROR,
                    f"{'LOAD' if is_load else 'STORE'} at constant "
                    f"address {addr}, scratchpad has {total_words} "
                    f"words", s, p))
        for addr, users in by_addr.items():
            writers = [p for p, is_load in users if not is_load]
            readers = [p for p, is_load in users if is_load]
            if len(writers) > 1:
                out.append(Diagnostic(
                    "UAL002", ERROR,
                    f"PEs {writers} all store to address {addr} in the "
                    f"same cycle (write-write race)", s))
            if writers and readers:
                out.append(Diagnostic(
                    "UAL003", WARNING,
                    f"PE {readers} load address {addr} in the same "
                    f"cycle PE {writers} store it (value depends on "
                    f"PE order)", s))


def _check_liveness(linked: LinkedConfig, out: List[Diagnostic]) -> None:
    """UAL006 (use-before-def) + UAL007 (dead code).

    Consumption is aggregated per PE output latch / per register across
    the whole schedule (every wrap), so a value produced in one slot and
    consumed in another is live.  The dead-code check is one-level (a
    result feeding only a never-read register still counts as consumed)
    and conservative per PE, so it never flags a live multi-slot chain.
    """
    S, P, R = linked.II, linked.n_pes, linked.n_regs
    consumed_o: Set[int] = set()           # PEs whose O latch/result is read
    read_regs: Set[Tuple[int, int]] = set()
    written_regs: Set[Tuple[int, int]] = set()
    read_locus: Dict[Tuple[int, int], Tuple[int, int]] = {}
    for s in range(S):
        for p in range(P):
            if _fires(linked, s, p):
                for k in range(3):
                    kind, pe, reg = (int(v) for v in
                                     linked.ops[s, p, k, :3])
                    if kind == K_O and 0 <= pe < P:
                        consumed_o.add(pe)
                    elif kind == K_R and 0 <= pe < P and 0 <= reg < R:
                        read_regs.add((pe, reg))
                        read_locus.setdefault((pe, reg), (s, p))
            for r in range(R):
                kind, pe, reg = (int(v) for v in linked.regw[s, p, r])
                written = kind != K_NONE
                if written:
                    written_regs.add((p, r))
                if kind in (K_O, K_RESULT) and 0 <= pe < P:
                    consumed_o.add(pe)
                elif kind == K_R and 0 <= pe < P and 0 <= reg < R:
                    read_regs.add((pe, reg))
                    read_locus.setdefault((pe, reg), (s, p))
    for pe, reg in sorted(read_regs - written_regs):
        s, p = read_locus[(pe, reg)]
        out.append(Diagnostic(
            "UAL006", WARNING,
            f"register r{reg} of PE {pe} is read but never written in "
            f"any slot (reads as constant 0)", s, p))
    side_effect = {OPC["NOP"], OPC["STORE"]}
    for s in range(S):
        for p in range(P):
            opc = int(linked.scalar[s, p, 0])
            if (opc not in side_effect and _fires(linked, s, p)
                    and p not in consumed_o):
                out.append(Diagnostic(
                    "UAL007", WARNING,
                    f"{OPCODES[opc]} result is consumed by nothing (no "
                    f"operand, no register write, no store)", s, p))


# ---------------------------------------------------------------------------
# Wire-level detectors over the raw MachineConfig
# ---------------------------------------------------------------------------

def _resolve_depths(cfg: MachineConfig, s: int) -> np.ndarray:
    """Per-link bypass-chain depth for slot ``s`` (-1 = never resolves).

    Unlike ``core.lowering._resolve_drivers`` this relaxes to a full
    fixed point (not ``max_hops`` rounds), so a chain that *would*
    resolve given more hops is distinguishable from one that never
    resolves at all (undriven or cyclic).
    """
    f = cfg.fabric
    n_links = len(f.links)
    depth = np.full(n_links, -1, np.int64)
    for _ in range(n_links + 1):
        changed = False
        for p in range(f.n_pes):
            for j, li in enumerate(f.out_links(p)):
                kind, idx = (int(v) for v in cfg.xbar[s, p, j])
                if kind == XB_NONE or depth[li] >= 0:
                    continue
                if kind in (XB_O, XB_REG):
                    depth[li] = 1
                    changed = True
                elif (kind == XB_IN and 0 <= idx < n_links
                        and depth[idx] >= 0):
                    depth[li] = depth[idx] + 1
                    changed = True
        if not changed:
            break
    return depth


def _check_wires(cfg: MachineConfig, out: List[Diagnostic]) -> None:
    """UAL004 (unresolved/cyclic chains) + UAL005 (hop-budget excess).

    These need the raw config: the lowered tables have already collapsed
    every chain (an unresolved one into a silent ``K_NONE``), so only
    the crossbar settings can say *why* a select failed to resolve.
    """
    f = cfg.fabric
    n_links = len(f.links)
    for s in range(cfg.II):
        depth = _resolve_depths(cfg, s)

        def flag(li: int, what: str, p: int) -> None:
            if not 0 <= li < n_links:
                out.append(Diagnostic(
                    "UAL008", ERROR,
                    f"{what} selects link {li}, fabric has {n_links}",
                    s, p))
            elif depth[li] < 0:
                out.append(Diagnostic(
                    "UAL004", ERROR,
                    f"{what} reads link {li}, whose driver chain never "
                    f"resolves (undriven or cyclic) — it would lower "
                    f"to a silent K_NONE", s, p))
            elif depth[li] > f.max_hops:
                out.append(Diagnostic(
                    "UAL005", ERROR,
                    f"{what} reads link {li} through a {depth[li]}-hop "
                    f"bypass chain; fabric allows {f.max_hops} "
                    f"hop(s)/cycle", s, p))

        for p in range(f.n_pes):
            for k in range(3):
                kind, idx = int(cfg.op_src[s, p, k, 0]), \
                    int(cfg.op_src[s, p, k, 1])
                if kind == SRC_IN:
                    flag(idx, f"operand {k}", p)
            for r in range(cfg.regw.shape[2]):
                kind, idx = (int(v) for v in cfg.regw[s, p, r])
                if kind == XB_IN:
                    flag(idx, f"register write r{r}", p)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def verify(cfg: Optional[MachineConfig] = None,
           linked: Optional[LinkedConfig] = None,
           program=None, name: str = "") -> CheckReport:
    """Statically verify a mapped configuration; returns a ``CheckReport``.

    ``cfg``     — the raw machine configuration (enables the wire-level
                  detectors UAL004/UAL005 with exact loci),
    ``linked``  — the lowered artifact (never re-lowered when given; if
                  omitted and ``cfg`` is present, it is lowered here),
    ``program`` — anything with ``.layout.total_words`` (the UAL
                  ``Program``), enabling the address-bounds check UAL012.

    At least one of ``cfg``/``linked`` is required.  The report's ``ok``
    is True iff no error-severity findings; use ``raise_if_errors`` (or
    the pipeline's ``verify`` pass) to turn errors into ``VerifyError``.
    """
    if cfg is None and linked is None:
        raise ValueError("verify() needs a MachineConfig, a LinkedConfig, "
                         "or both")
    if linked is None:
        linked = link_config(cfg)
    diags: List[Diagnostic] = []
    _check_integrity(linked, diags)
    _check_ports(linked, diags)
    _check_mem_pes(linked, diags)
    total_words = None
    if program is not None:
        layout = getattr(program, "layout", None)
        total_words = getattr(layout, "total_words", None)
    _check_mem_conflicts(linked, diags, total_words)
    _check_liveness(linked, diags)
    if cfg is not None:
        _check_wires(cfg, diags)
    elif linked.unresolved_inputs:
        # tables shipped without their source config: the lowering-time
        # counter is the only witness of the silent-K_NONE collapses
        diags.append(Diagnostic(
            "UAL004", ERROR,
            f"{linked.unresolved_inputs} wire select(s) failed to "
            f"resolve at lowering time (collapsed to K_NONE); re-verify "
            f"with the source MachineConfig for exact loci"))
    return CheckReport(name=name, diagnostics=diags)


def raise_if_errors(report: CheckReport) -> CheckReport:
    """Raise ``VerifyError`` if the report has error-severity findings;
    returns the report unchanged otherwise (chainable)."""
    if not report.ok:
        raise VerifyError(report)
    return report

"""Per-op collective profile of a dry-run cell (the §Perf 'profiler').

    PYTHONPATH=src python -m repro.analysis.collectives \
        --arch gemma3-27b --shape train_4k [--multi-pod] [--top 15]

Re-lowers the cell on the production mesh and prints the top collectives
by wire bytes with their result shapes, group sizes and trip counts —
the dry-run equivalent of reading a comm profile.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
import argparse     # noqa: E402
import json         # noqa: E402

from repro.analysis.hlo_cost import HloCostModel      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--overrides", default=None)
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    from repro.launch.dryrun import make_production_mesh
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    overrides = json.loads(args.overrides) if args.overrides else None
    cfg, shape, lowered, compiled = lower_cell(
        args.arch, args.shape, mesh,
        "pod2x16x16" if args.multi_pod else "pod16x16", overrides)
    model = HloCostModel(compiled.as_text())
    acc = model.top_collectives()
    rows = sorted(acc.items(), key=lambda kv: -kv[1]["wire_bytes"])
    total = sum(v["wire_bytes"] for v in acc.values())
    print(f"\n{args.arch} {args.shape}: total wire {total / 1e9:.1f} GB/dev")
    print(f"{'kind':18s} {'g':>4s} {'count':>7s} {'wire GB':>9s}  shape")
    for (kind, shp, g), v in rows[:args.top]:
        print(f"{kind:18s} {g:4d} {v['count']:7.0f} "
              f"{v['wire_bytes'] / 1e9:9.2f}  {shp}")


if __name__ == "__main__":
    main()


def memory_main():  # pragma: no cover — CLI variant used by §Perf loop
    import sys
    sys.argv[0] = "collectives"
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--overrides", default=None)
    args = ap.parse_args()
    from repro.launch.dryrun import lower_cell
    from repro.launch.dryrun import make_production_mesh
    mesh = make_production_mesh()
    overrides = json.loads(args.overrides) if args.overrides else None
    cfg, shape, lowered, compiled = lower_cell(args.arch, args.shape, mesh,
                                               "pod16x16", overrides)
    model = HloCostModel(compiled.as_text())
    acc = model.top_memory()
    rows = sorted(acc.items(), key=lambda kv: -kv[1]["bytes"])
    total = sum(v["bytes"] for v in acc.values())
    print(f"\n{args.arch} {args.shape}: total HBM traffic "
          f"{total / 1e12:.2f} TB/dev")
    print(f"{'opcode':22s} {'count':>8s} {'GB':>9s}  shape")
    for (kind, shp), v in rows[:args.top]:
        print(f"{kind:22s} {v['count']:8.0f} {v['bytes'] / 1e9:9.1f}  {shp}")

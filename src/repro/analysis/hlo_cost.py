"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless
of trip count, which under-reports scanned-layer models by ~n_layers x.
This parser rebuilds the cost bottom-up through the call graph:

  * dot ops        -> FLOPs = 2 * |output| * prod(contracting dims)
  * fusion ops     -> bytes = operands + outputs (fusion internals are free);
                      FLOPs = cost of the fused computation
  * while ops      -> body+cond cost x known_trip_count (annotated by XLA in
                      backend_config)
  * collectives    -> per-device wire bytes with ring-model factors and the
                      replica-group size parsed from the op

Because the compiled module is the per-device SPMD program, every quantity
here is PER DEVICE: roofline terms divide by single-chip peaks directly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}
_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%?[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _called_comps(line: str) -> List[str]:
    names = _CALL_ATTR_RE.findall(line)
    for grp in _BRANCHES_RE.findall(line):
        names.extend(n.strip() for n in grp.split(",") if n.strip())
    return names
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "opt-barrier"}


def _arrays(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _ARRAY_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _arrays(type_str):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(type_str: str) -> int:
    total = 0
    for _, dims in _arrays(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.transcendentals += mult * other.transcendentals
        for k, v in other.coll.items():
            d = self.coll.setdefault(k, {"count": 0.0, "operand_bytes": 0.0,
                                         "wire_bytes": 0.0})
            for kk in d:
                d[kk] += mult * v.get(kk, 0.0)


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Op]] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.entry = self._find_entry(hlo_text)

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+(%?[\w\.\-]+)", text, re.M)
        return m.group(1) if m else next(iter(self.comps))

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            cm = _COMP_RE.match(line)
            if cm and "{" in line:
                cur = cm.group(1)
                self.comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            om = _OP_RE.match(line)
            if om:
                self.comps[cur].append(
                    _Op(om.group(2), om.group(3), om.group(4), line))

    # -- per-op costing -------------------------------------------------------
    @staticmethod
    def _call_pos(op: _Op) -> int:
        """Position of the real call-site ``opcode(`` (NOT the op's own name,
        which usually contains the opcode, e.g. ``%all-to-all.55``)."""
        m = re.search(r"(?<![\w.%\-])" + re.escape(op.opcode) + r"\(",
                      op.line)
        return m.start() if m else op.line.index(op.opcode)

    def _dot_flops(self, op: _Op, symtab: Dict[str, str]) -> float:
        out_elems = _elems_of(op.type_str)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
        cdims = [int(d) for d in m.group(1).split(",") if d] if m else []
        args = self._op_args(op)
        contract = 1
        if args and cdims:
            ltype = symtab.get(args[0].lstrip("%"), args[0])
            arrs = _arrays(ltype)
            if arrs:
                dims = arrs[0][1]
                for c in cdims:
                    if c < len(dims):
                        contract *= dims[c]
        return 2.0 * out_elems * max(contract, 1)

    def _operand_bytes(self, op: _Op, symtab: Dict[str, str]) -> float:
        total = 0.0
        for a in self._op_args(op):
            nm = a.lstrip("%")
            if nm in symtab:
                total += _bytes_of(symtab[nm])
            else:
                total += _bytes_of(a)
        return total

    def _emulated_bf16(self, prod: _Op, symtab: Dict[str, str]) -> bool:
        """True when ``prod`` yields an f32 buffer that is semantically bf16.

        The CPU host backend (the dry-run target) emulates bf16 arithmetic
        in f32 with explicit f32->bf16->f32 rounding round-trips, so SPMD
        collectives over bf16 tensors appear at f32 width.  A real TPU
        reduces bf16 natively; wire bytes must be counted at bf16 width.
        """
        if "f32[" not in prod.type_str:
            return False
        if prod.opcode == "convert":
            args = self._op_args(prod)
            t = symtab.get(args[0].lstrip("%"), "") if args else ""
            return "bf16[" in t
        if prod.opcode == "fusion":
            for n in _called_comps(prod.line):
                key = n.lstrip("%")
                ops = self.comps.get(n) or self.comps.get(key) \
                    or self.comps.get("%" + key) or []
                for o in ops:
                    if o.opcode == "convert" and "bf16[" in o.type_str:
                        return True
        return False

    def _collective_operand_bytes(self, op: _Op, symtab: Dict[str, str],
                                  by_name: Dict[str, "_Op"]) -> float:
        total = 0.0
        for a in self._op_args(op):
            nm = a.lstrip("%")
            b = _bytes_of(symtab.get(nm, a))
            prod = by_name.get(nm)
            if prod is not None and self._emulated_bf16(prod, symtab):
                b *= 0.5
            total += b
        return total

    def _op_args(self, op: _Op) -> List[str]:
        seg = op.line[self._call_pos(op) + len(op.opcode):]
        depth = 0
        buf = ""
        for ch in seg:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                buf += ch
        return [a.strip() for a in buf.split(",") if a.strip()]

    def _dus_update_bytes(self, op: _Op, symtab: Dict[str, str]) -> float:
        args = self._op_args(op)
        if len(args) >= 2:
            nm = args[1].lstrip("%")
            return _bytes_of(symtab.get(nm, args[1]))
        return _bytes_of(op.type_str)

    def _fusion_bytes(self, op: _Op, symtab: Dict[str, str],
                      called: List[str]) -> float:
        """Operand+output bytes with slice-aware parameter accounting."""
        # map fused-computation params -> how they are consumed
        slice_params: Dict[int, float] = {}
        dus_root = None
        for n in called:
            ops = self.comps.get(n) or self.comps.get("%" + n.lstrip("%")) \
                or self.comps.get(n.lstrip("%")) or []
            psym = {o.name.lstrip("%"): o.type_str for o in ops}
            pidx = {}
            consumers: Dict[str, List[_Op]] = {}
            for o in ops:
                if o.opcode == "parameter":
                    m = re.search(r"parameter\((\d+)\)", o.line)
                    if m:
                        pidx[o.name.lstrip("%")] = int(m.group(1))
                for a in self._op_args(o):
                    consumers.setdefault(a.lstrip("%"), []).append(o)
            for pname, idx in pidx.items():
                cons = consumers.get(pname, [])
                if cons and all(c.opcode == "dynamic-slice" for c in cons):
                    slice_params[idx] = sum(
                        _bytes_of(c.type_str) for c in cons)
                if cons and all(c.opcode == "dynamic-update-slice"
                                and self._op_args(c)
                                and self._op_args(c)[0].lstrip("%") == pname
                                for c in cons):
                    # in-place updated buffer: traffic = update bytes
                    slice_params[idx] = sum(
                        self._dus_update_bytes(c, psym) for c in cons)
            for o in ops:
                if o.line.lstrip().startswith("ROOT") \
                        and o.opcode == "dynamic-update-slice":
                    dus_root = self._dus_update_bytes(o, psym)
        args = self._op_args(op)
        total = 0.0
        for i, a in enumerate(args):
            if i in slice_params:
                total += slice_params[i]
            else:
                nm = a.lstrip("%")
                total += _bytes_of(symtab.get(nm, a))
        if dus_root is not None:
            total += dus_root
        else:
            total += _bytes_of(op.type_str)
        return total

    @staticmethod
    def _group_size(line: str, default: int = 2) -> int:
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return max(1, int(m.group(2)))
        m = _GROUPS_LIST_RE.search(line)
        if m:
            return max(1, len(m.group(1).split(",")))
        return default

    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        key = comp.lstrip("%")
        for k in (comp, key, "%" + key):
            if k in self._memo:
                return self._memo[k]
        ops = self.comps.get(comp) or self.comps.get("%" + key) \
            or self.comps.get(key) or []
        symtab = {o.name.lstrip("%"): o.type_str for o in ops}
        by_name = {o.name.lstrip("%"): o for o in ops}
        total = Cost()
        for op in ops:
            oc = op.opcode
            if oc in _SKIP_OPS:
                continue
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                body = Cost()
                for n in _called_comps(op.line):
                    body.add(self.cost(n))
                total.add(body, mult=trip)
                continue
            if oc in ("fusion", "call", "conditional", "map"):
                names = _called_comps(op.line)
                inner = Cost()
                for n in names:
                    inner.add(self.cost(n))
                total.flops += inner.flops
                total.transcendentals += inner.transcendentals
                # fusion memory = operands + outputs, but slice-aware:
                # a fused dynamic-slice only touches the slice, and a
                # DUS root writes the update region in place (XLA aliases
                # scan carries) — crucial for scanned stacked weights.
                total.bytes += self._fusion_bytes(op, symtab, names)
                total.coll = _merge_coll(total.coll, inner.coll)
                continue
            if oc in ("dynamic-slice", "dynamic-update-slice"):
                if oc == "dynamic-slice":
                    total.bytes += 2.0 * _bytes_of(op.type_str)
                else:
                    upd = self._dus_update_bytes(op, symtab)
                    total.bytes += 2.0 * upd
                total.flops += _elems_of(op.type_str) * 0  # pure data movement
                continue
            if any(oc.startswith(c) for c in COLLECTIVES):
                if oc.endswith("-done"):
                    continue
                kind = next(c for c in COLLECTIVES if oc.startswith(c))
                g = self._group_size(op.line)
                ob = self._collective_operand_bytes(op, symtab, by_name)
                out_b = _bytes_of(op.type_str)
                if kind == "all-gather":
                    wire = ob * (g - 1)
                elif kind == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif kind == "all-reduce":
                    wire = 2.0 * ob * (g - 1) / g
                elif kind == "all-to-all":
                    wire = ob * (g - 1) / g
                else:  # collective-permute
                    wire = ob
                d = total.coll.setdefault(
                    kind, {"count": 0.0, "operand_bytes": 0.0,
                           "wire_bytes": 0.0})
                d["count"] += 1
                d["operand_bytes"] += ob
                d["wire_bytes"] += wire
                total.bytes += ob + out_b
                continue
            if oc == "dot":
                total.flops += self._dot_flops(op, symtab)
                total.bytes += self._operand_bytes(op, symtab) \
                    + _bytes_of(op.type_str)
                continue
            if oc in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                      "sine", "cosine", "logistic"):
                total.transcendentals += _elems_of(op.type_str)
            # generic op: elementwise flops + memory traffic
            total.flops += _elems_of(op.type_str)
            total.bytes += self._operand_bytes(op, symtab) \
                + _bytes_of(op.type_str)
        self._memo[comp] = total
        return total


    # -- per-op memory attribution (perf-loop profiling aid) ----------------
    def top_memory(self, comp: Optional[str] = None, mult: float = 1.0,
                   acc: Optional[Dict] = None) -> Dict:
        """Aggregate HBM traffic by (opcode, result type) with trip counts."""
        acc = {} if acc is None else acc
        comp = comp or self.entry
        key = comp.lstrip("%")
        ops = self.comps.get(comp) or self.comps.get("%" + key) \
            or self.comps.get(key) or []
        symtab = {o.name.lstrip("%"): o.type_str for o in ops}

        def put(kind, shape, b):
            d = acc.setdefault((kind, shape[:70]), {"count": 0.0,
                                                    "bytes": 0.0})
            d["count"] += mult
            d["bytes"] += mult * b

        for op in ops:
            oc = op.opcode
            if oc in _SKIP_OPS:
                continue
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                for n in _called_comps(op.line):
                    self.top_memory(n, mult * trip, acc)
                continue
            if oc in ("fusion", "call", "conditional", "map"):
                b = self._fusion_bytes(op, symtab, _called_comps(op.line))
                put(oc, op.type_str.strip(), b)
                continue
            if oc in ("dynamic-slice", "dynamic-update-slice"):
                if oc == "dynamic-slice":
                    put(oc, op.type_str.strip(), 2.0 * _bytes_of(op.type_str))
                else:
                    put(oc, op.type_str.strip(),
                        2.0 * self._dus_update_bytes(op, symtab))
                continue
            if any(oc.startswith(c) for c in COLLECTIVES):
                if not oc.endswith("-done"):
                    put(oc, op.type_str.strip(),
                        self._operand_bytes(op, symtab)
                        + _bytes_of(op.type_str))
                continue
            put(oc, op.type_str.strip(),
                self._operand_bytes(op, symtab) + _bytes_of(op.type_str))
        return acc

    # -- per-op collective attribution (perf-loop profiling aid) -----------
    def top_collectives(self, comp: Optional[str] = None, mult: float = 1.0,
                        acc: Optional[Dict] = None) -> Dict:
        """Aggregate collectives by (kind, result type) with trip-count
        multipliers — the dry-run 'profile' the §Perf loop iterates on."""
        acc = {} if acc is None else acc
        comp = comp or self.entry
        key = comp.lstrip("%")
        ops = self.comps.get(comp) or self.comps.get("%" + key) \
            or self.comps.get(key) or []
        symtab = {o.name.lstrip("%"): o.type_str for o in ops}
        by_name = {o.name.lstrip("%"): o for o in ops}
        for op in ops:
            oc = op.opcode
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                for n in _called_comps(op.line):
                    self.top_collectives(n, mult * trip, acc)
                continue
            if oc in ("fusion", "call", "conditional", "map"):
                for n in _called_comps(op.line):
                    self.top_collectives(n, mult, acc)
                continue
            if any(oc.startswith(c) for c in COLLECTIVES) \
                    and not oc.endswith("-done"):
                kind = next(c for c in COLLECTIVES if oc.startswith(c))
                g = self._group_size(op.line)
                ob = self._collective_operand_bytes(op, symtab, by_name)
                out_b = _bytes_of(op.type_str)
                if kind == "all-gather":
                    wire = ob * (g - 1)
                elif kind == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif kind == "all-reduce":
                    wire = 2.0 * ob * (g - 1) / g
                elif kind == "all-to-all":
                    wire = ob * (g - 1) / g
                else:
                    wire = ob
                shape = op.type_str.strip()[:70]
                k = (kind, shape, g)
                d = acc.setdefault(k, {"count": 0.0, "wire_bytes": 0.0})
                d["count"] += mult
                d["wire_bytes"] += mult * wire
        return acc


def _merge_coll(a, b):
    out = dict(a)
    for k, v in b.items():
        d = out.setdefault(k, {"count": 0.0, "operand_bytes": 0.0,
                               "wire_bytes": 0.0})
        for kk in d:
            d[kk] += v.get(kk, 0.0)
    return out


def analyze_hlo(hlo_text: str) -> Dict[str, object]:
    model = HloCostModel(hlo_text)
    c = model.cost()
    wire = sum(v["wire_bytes"] for v in c.coll.values())
    operand = sum(v["operand_bytes"] for v in c.coll.values())
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "transcendentals_per_device": c.transcendentals,
        "collective_wire_bytes_per_device": wire,
        "collective_operand_bytes_per_device": operand,
        "collectives": c.coll,
    }

"""``Program`` — the portable compilation unit of the unified abstraction layer.

A Program bundles everything a CGRA toolchain needs to know about a kernel
*before* any hardware is chosen: the dataflow graph, the planned scratchpad
data layout (bank assignment + base addresses) and a named I/O spec
(array name -> length, plus which arrays are outputs).  It is immutable and
content-hashable: ``Program.digest`` is a stable SHA-256 over the canonical
structure, so identical kernels hash identically across processes — the
mapping cache (see ``ual.cache``) keys on it.

Constructors cover the three frontends the repo already has:

  * ``Program.from_builder``  — a ``DFGBuilder`` (annotated-kernel DSL),
  * ``Program.from_kernel``   — a ``core.kernel_lib`` entry by name,
  * ``Program.from_function`` — a pure scalar JAX function traced via
    ``trace_into`` into an elementwise loop body.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from functools import cached_property
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.dfg import (DFG, DataLayout, DFGBuilder, apply_layout,
                            flat_memory, flat_memory_batch, plan_layout,
                            trace_into, unflatten_memory,
                            unflatten_memory_batch)


@dataclass(frozen=True)
class Program:
    dfg: DFG                       # pre-layout DFG over *named* arrays
    layout: DataLayout             # planned scratchpad layout
    n_iters: int = 16              # default trip count (runtime, not hashed)
    make_mem: Optional[Callable[[np.random.Generator],
                                Dict[str, np.ndarray]]] = field(
        default=None, compare=False)   # default test-vector generator

    # -- I/O spec -------------------------------------------------------------
    @property
    def name(self) -> str:
        return self.dfg.name

    @property
    def arrays(self) -> Dict[str, int]:
        return self.dfg.arrays

    @property
    def outputs(self) -> Tuple[str, ...]:
        return self.dfg.outputs

    @property
    def inputs(self) -> Tuple[str, ...]:
        """Arrays the caller provides: everything not declared an output.
        Output arrays (including in/out accumulators) start zero-filled
        unless the caller passes them explicitly."""
        return tuple(n for n in self.dfg.arrays if n not in self.dfg.outputs)

    # -- lowering -------------------------------------------------------------
    @cached_property
    def laid(self) -> DFG:
        """The layout-applied DFG (base addresses folded into LOAD/STOREs)."""
        return apply_layout(self.dfg, self.layout)

    def check_arrays(self, mem: Dict[str, np.ndarray]) -> None:
        """Reject unknown names / oversized arrays (all backends call this,
        so a typo'd input fails identically on interp, sim and pallas)."""
        for name, arr in mem.items():
            if name not in self.arrays:
                raise KeyError(f"{self.name}: unknown array {name!r}; "
                               f"declared: {sorted(self.arrays)}")
            if len(arr) > self.arrays[name]:
                raise ValueError(f"{self.name}: array {name!r} has "
                                 f"{len(arr)} words, declared "
                                 f"{self.arrays[name]}")

    def flatten(self, mem: Dict[str, np.ndarray]) -> np.ndarray:
        """Named arrays -> flat scratchpad image (missing arrays zeroed)."""
        self.check_arrays(mem)
        return flat_memory(self.layout, mem)

    def unflatten(self, flat: np.ndarray) -> Dict[str, np.ndarray]:
        return unflatten_memory(self.layout, flat, self.dfg.arrays)

    def flatten_batch(self, mems: Sequence[Dict[str, np.ndarray]]
                      ) -> np.ndarray:
        """Batched ``flatten``: B dicts -> (B, total_words) in one
        vectorized pass per array name (no per-sample Python loop) — what
        the natively-batched backends feed the engines."""
        mems = list(mems)
        for m in mems:
            self.check_arrays(m)
        return flat_memory_batch(self.layout, mems)

    def unflatten_batch(self, flats: np.ndarray
                        ) -> "list[Dict[str, np.ndarray]]":
        """Batched ``unflatten``: (B, total_words) -> B named-array dicts
        (one contiguous copy per array name)."""
        return unflatten_memory_batch(self.layout, flats, self.dfg.arrays)

    def random_inputs(self, rng: np.random.Generator,
                      lo: int = -50, hi: int = 50) -> Dict[str, np.ndarray]:
        """Test vectors: ``make_mem`` if the frontend supplied one, else
        uniform random int32 for every non-output array."""
        if self.make_mem is not None:
            return dict(self.make_mem(rng))
        return {n: rng.integers(lo, hi, self.arrays[n]).astype(np.int32)
                for n in self.inputs}

    # -- content hash ---------------------------------------------------------
    @cached_property
    def digest(self) -> str:
        """Stable SHA-256 of the canonical structure (process-independent).

        Covers the DFG (ops, operand edges with recurrence dist/init,
        immediates, array bindings), the I/O spec and the data layout —
        everything that influences mapping.  Excludes ``n_iters`` and
        ``make_mem`` (runtime concerns) and the kernel name.
        """
        nodes = [[n.op, [[o.src, o.dist, o.init] for o in n.operands],
                  n.const, n.array] for n in self.dfg.nodes]
        spec = {
            "nodes": nodes,
            "arrays": sorted(self.dfg.arrays.items()),
            "outputs": list(self.dfg.outputs),
            "layout": {
                "bases": sorted(self.layout.bases.items()),
                "banks": sorted(self.layout.banks.items()),
                "n_banks": self.layout.n_banks,
                "bank_words": self.layout.bank_words,
            },
        }
        blob = json.dumps(spec, separators=(",", ":"), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def from_dfg(dfg: DFG, n_iters: int = 16, *,
                 make_mem: Optional[Callable] = None,
                 n_banks: int = 4, bank_words: Optional[int] = None
                 ) -> "Program":
        if bank_words is None:
            bank_words = max(2048, max(dfg.arrays.values(), default=0) + 64)
        layout = plan_layout(dfg, n_banks=n_banks, bank_words=bank_words)
        return Program(dfg, layout, n_iters, make_mem)

    @staticmethod
    def from_builder(builder: DFGBuilder, n_iters: int = 16, *,
                     make_mem: Optional[Callable] = None,
                     n_banks: int = 4, bank_words: Optional[int] = None
                     ) -> "Program":
        return Program.from_dfg(builder.build(), n_iters, make_mem=make_mem,
                                n_banks=n_banks, bank_words=bank_words)

    @staticmethod
    def from_kernel(name: str, *, n_banks: int = 4,
                    bank_words: Optional[int] = None) -> "Program":
        """A ``core.kernel_lib`` entry, with its test-vector generator."""
        from repro.core.kernel_lib import KERNELS
        if name not in KERNELS:
            raise KeyError(f"unknown kernel {name!r}; "
                           f"known: {sorted(KERNELS)}")
        dfg, make_mem, n_iters = KERNELS[name]()
        return Program.from_dfg(dfg, n_iters, make_mem=make_mem,
                                n_banks=n_banks, bank_words=bank_words)

    @staticmethod
    def from_function(fn: Callable, inputs: Dict[str, int], *,
                      outputs: Sequence[str] = ("out",),
                      n_iters: Optional[int] = None,
                      name: str = "traced") -> "Program":
        """Trace a pure scalar int32 function into an elementwise loop body.

        ``fn`` takes one scalar per entry of ``inputs`` (in dict order) and
        returns one scalar per entry of ``outputs``; iteration ``i`` applies
        it to element ``i`` of each input array.
        """
        b = DFGBuilder(name)
        for arr, ln in inputs.items():
            b.array(arr, ln)
        length = min(inputs.values())
        for arr in outputs:
            b.array(arr, length, output=True)
        i = b.counter()
        vals = [b.load(arr, i) for arr in inputs]
        outs = trace_into(b, fn, vals)
        if len(outs) != len(outputs):
            raise ValueError(f"{name}: fn returned {len(outs)} values for "
                             f"{len(outputs)} declared outputs")
        for arr, v in zip(outputs, outs):
            b.store(arr, i, v)
        return Program.from_builder(b, n_iters if n_iters is not None
                                    else length)

"""The staged compile pipeline behind ``ual.compile``.

``compile()`` used to be one opaque function; it is now a sequence of
instrumented passes, each timed with ``time.perf_counter`` and reporting a
``PassRecord(name, wall_s, stats)`` into ``CompileInfo.passes``:

  * ``layout``   — fold the planned scratchpad layout into the DFG
    (base addresses into LOAD/STOREs),
  * ``mii``      — Rau's iterative-modulo-scheduling lower bounds
    (ResMII / RecMII),
  * ``mapping``  — cache lookup, then the registered ``MapperStrategy``
    for temporal fabrics / the analytic ``spatial_ii`` model for spatial
    ones; mapping-free backends skip this pass,
  * ``lowering`` — lower the mapped configuration once to the dense
    linked tables (``core.lowering.LinkedConfig``) every execution
    engine consumes; memoized in the cache next to the ``MapResult``
    under the same digest key, so a warm compile re-lowers nothing,
  * ``verify``   — the static diagnostics pass
    (``repro.analysis.verifier``): port oversubscription, write-write
    races, unresolved wire chains, use-before-def / dead code, table
    integrity — decidable over the modulo schedule without running a
    cycle.  Error-severity findings fail the compile with a rendered
    ``VerifyError``; warnings/infos ride along in the pass record and
    on ``Executable.check_report``,
  * ``binding``  — bind the execution backend and record whether the
    result is runnable / validatable.

The pass list is data, not control flow: tooling can build a custom
``Pipeline`` (extra analysis passes, alternative mapping passes) and hand
it to ``compile(..., pipeline=...)`` without forking the compiler.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.verifier import CheckReport, VerifyError, verify
from repro.core.lowering import (LinkedConfig, config_fingerprint,
                                 link_config)
from repro.core.mapper import (MapResult, map_dfg, rec_mii, res_mii,
                               spatial_ii)
from repro.ual.backends import Backend
from repro.ual.cache import MappingCache, default_cache
from repro.ual.executable import PassRecord
from repro.ual.program import Program
from repro.ual.target import Target


@dataclass
class CompileContext:
    """Mutable state threaded through the passes of one compile."""

    program: Program
    target: Target
    cache: Optional[MappingCache] = None
    use_cache: bool = True
    backend: Optional[Backend] = None
    # -- filled in by passes --------------------------------------------------
    rec: Optional[int] = None            # RecMII
    res: Optional[int] = None            # ResMII
    mii: Optional[int] = None
    result: Optional[MapResult] = None   # None for mapping-free backends
    lowered: Optional[LinkedConfig] = None  # the lowered artifact
    spatial_subgraphs: int = 0
    cache_hit: bool = False
    restarts_paid: int = 0               # mapper restarts paid by THIS compile
    key: Optional[Tuple[str, str]] = None
    #: the per-key compile lock, HELD, when this compile is the cold
    #: winner for its key: acquired by the mapping pass before mapping,
    #: kept through the lowering pass (so racing threads wait for the
    #: whole mapping+lowering, paying exactly one of each), released by
    #: ``Pipeline.run``'s finally
    key_lock: Optional[object] = None
    #: the cross-PROCESS analogue (``MappingCache.process_lock_key``):
    #: an fcntl file lock HELD by the cold winner alongside ``key_lock``
    #: so racing *processes* sharing the disk cache also pay exactly one
    #: mapping + one lowering per key; released by ``Pipeline.run``
    process_lock: Optional[object] = None
    check_report: Optional[CheckReport] = None  # the verify pass's findings
    records: List[PassRecord] = field(default_factory=list)


class CompilePass:
    """One pipeline stage: mutate the context, return stats to report."""

    name: str = "?"

    def run(self, ctx: CompileContext) -> Optional[Dict[str, object]]:
        raise NotImplementedError


class LayoutPass(CompilePass):
    """Apply the planned scratchpad layout (``Program.laid``)."""

    name = "layout"

    def run(self, ctx):
        laid = ctx.program.laid
        return {"n_nodes": len(laid.nodes),
                "n_arrays": len(ctx.program.arrays),
                "n_banks": ctx.program.layout.n_banks}


class MIIBoundsPass(CompilePass):
    """Rau's lower bounds: RecMII always, ResMII for temporal fabrics."""

    name = "mii"

    def run(self, ctx):
        laid, fabric = ctx.program.laid, ctx.target.fabric
        ctx.rec = rec_mii(laid)
        ctx.res = res_mii(laid, fabric)
        ctx.mii = max(ctx.rec, ctx.res)
        return {"rec_mii": ctx.rec, "res_mii": ctx.res, "mii": ctx.mii}


class MappingPass(CompilePass):
    """Cache lookup + strategy dispatch (the expensive pass).

    Temporal fabrics resolve ``target.strategy`` through the mapper
    strategy registry; spatial fabrics use the analytic ``spatial_ii``
    model; mapping-free backends (``interp``) skip mapping entirely.
    Results are memoized per ``(program.digest, target.digest)`` —
    failures only in-process (``memory_only``): the time budget makes
    failure wall-clock dependent, so a failure observed on a loaded
    machine must never be pinned on disk for other processes to inherit.
    """

    name = "mapping"

    def run(self, ctx):
        target = ctx.target
        if not target.fabric.temporal:
            ii, n_parts = spatial_ii(ctx.program.laid, target.fabric)
            ctx.result = MapResult(True, ii, ctx.rec, strategy="spatial")
            ctx.spatial_subgraphs = n_parts
            return {"model": "spatial_ii", "II": ii, "subgraphs": n_parts}
        if ctx.backend is not None and not ctx.backend.requires_config:
            return {"skipped": "mapping-free backend"}

        key = (ctx.program.digest, target.digest)
        ctx.key = key

        def _map() -> MapResult:
            return map_dfg(ctx.program.laid, target.fabric,
                           ii_max=target.ii_max, seed=target.seed,
                           strategy=target.strategy,
                           max_restarts=target.max_restarts,
                           label_fn=target.label_fn,
                           time_budget_s=target.time_budget_s)

        # targets carrying a label_fn always compile cold: the hook is
        # unhashable, so caching it would serve stale placements
        cacheable = ctx.use_cache and target.label_fn is None
        if not cacheable:
            result = _map()
            ctx.restarts_paid = result.restarts
            ctx.result = result
            return {"cache": "bypass", "strategy": result.strategy,
                    "II": result.II, "restarts": result.restarts,
                    "success": result.success}
        c = ctx.cache if ctx.cache is not None else default_cache()
        result = c.get(key)
        if result is not None:
            ctx.result = result
            ctx.cache_hit = True
            return {"cache": "hit", "strategy": result.strategy,
                    "II": result.II, "success": result.success}
        # double-checked under the per-key lock: if another thread is
        # compiling this very key right now, wait for its result instead
        # of paying a second mapper run (uncounted peek — a hit here is
        # an in-flight compile finishing, not a warm cache).  The cold
        # winner KEEPS the lock through the lowering pass, so racers also
        # wait out the lowering — one mapper run AND one lowering per key
        lock = c.lock_key(key)
        lock.acquire()
        ctx.key_lock = lock              # released by Pipeline.run
        result = c.peek(key)
        if result is not None:
            ctx.key_lock = None
            lock.release()
            ctx.result = result
            ctx.cache_hit = True
            return {"cache": "hit", "inflight": True,
                    "strategy": result.strategy, "II": result.II,
                    "success": result.success}
        # still cold in this process: take the cross-process file lock
        # too (None for diskless caches) and peek once more — another
        # PROCESS may have just published the entry to the shared disk
        # dir while we waited.  Held through lowering like key_lock, so
        # a cold tenant pays one mapping + one lowering cluster-wide.
        plock = c.process_lock_key(key)
        if plock is not None:
            plock.acquire()
            ctx.process_lock = plock     # released by Pipeline.run
            result = c.peek(key)
            if result is not None:
                ctx.process_lock = ctx.key_lock = None
                plock.release()
                lock.release()
                ctx.result = result
                ctx.cache_hit = True
                return {"cache": "hit", "inflight": True,
                        "cross_process": True,
                        "strategy": result.strategy, "II": result.II,
                        "success": result.success}
        result = _map()
        ctx.restarts_paid = result.restarts
        c.put(key, result, memory_only=not result.success)
        ctx.result = result
        return {"cache": "miss", "strategy": result.strategy,
                "II": result.II, "restarts": result.restarts,
                "success": result.success}


class LoweringPass(CompilePass):
    """Lower the mapped configuration once to the dense linked tables.

    The lowered artifact (``core.lowering.LinkedConfig``) is what every
    execution engine consumes — the vectorized batched simulator gathers
    over it, the Pallas kernel keeps it CM-resident in VMEM.  It is a
    pure function of the machine configuration, so it is memoized in the
    cache next to the ``MapResult`` under the same
    ``(program.digest, target.digest)`` key: a warm compile reuses the
    cached tables with zero re-lowering.  Skipped when there is nothing
    to lower (mapping-free backends, spatial fabrics, failed mappings).
    """

    name = "lowering"

    def run(self, ctx):
        r = ctx.result
        if r is None or not r.success or r.config is None:
            return {"skipped": "no machine configuration"}
        cacheable = (ctx.use_cache and ctx.target.label_fn is None
                     and ctx.key is not None)
        # the fingerprint pins the tables to THIS configuration: the
        # budgeted mapper may produce a different config for the same key
        # (re-map after a lost mapping pickle, racing processes sharing
        # the disk dir), and stale tables must read as a miss
        fp = config_fingerprint(r.config)
        if not cacheable:
            ctx.lowered = link_config(r.config)
            return {"cache": "bypass", "cm_bytes": ctx.lowered.cm_bytes()}
        c = ctx.cache if ctx.cache is not None else default_cache()
        if ctx.key_lock is not None:
            # cold-compile winner: we still hold the key lock from the
            # mapping pass, so nobody else can be lowering this key
            lowered = c.get_lowered(ctx.key, fp)
            if lowered is None:
                lowered = link_config(r.config)
                c.put_lowered(ctx.key, lowered, fp)
                ctx.lowered = lowered
                return {"cache": "miss", "cm_bytes": lowered.cm_bytes()}
            ctx.lowered = lowered
            return {"cache": "hit", "cm_bytes": lowered.cm_bytes()}
        lowered = c.get_lowered(ctx.key, fp)
        if lowered is not None:
            ctx.lowered = lowered
            return {"cache": "hit", "cm_bytes": lowered.cm_bytes()}
        # mapping was warm but the tables are not (fingerprint mismatch,
        # lost lowered pickle): double-check under the per-key lock so
        # concurrent re-lowerings still collapse to one
        with c.lock_key(ctx.key):
            lowered = c.peek_lowered(ctx.key, fp)
            if lowered is not None:
                ctx.lowered = lowered
                return {"cache": "hit", "inflight": True,
                        "cm_bytes": lowered.cm_bytes()}
            lowered = link_config(r.config)
            c.put_lowered(ctx.key, lowered, fp)
        ctx.lowered = lowered
        return {"cache": "miss", "cm_bytes": lowered.cm_bytes()}


class VerifyPass(CompilePass):
    """Static diagnostics over the mapped config + lowered artifact.

    Runs the compile-time verifier (``repro.analysis.verifier``) on
    every compile that produced a machine configuration — including
    cache-warm ones, so corrupted cached tables are caught too.  Reuses
    the lowering pass's artifact (zero re-lowering; the exactly-one-
    lowering contract holds).  In ``strict`` mode (the default
    pipeline), error-severity findings abort the compile by raising
    ``VerifyError`` with the rendered report; warnings and infos are
    recorded in the pass stats and surfaced on
    ``Executable.check_report``.  ``strict=False`` (the
    ``repro.ual.check`` CLI) always collects the full report.
    """

    name = "verify"

    def __init__(self, strict: bool = True):
        self.strict = strict

    def run(self, ctx):
        r = ctx.result
        if r is None or not r.success or r.config is None:
            return {"skipped": "no machine configuration"}
        report = verify(cfg=r.config, linked=ctx.lowered,
                        program=ctx.program,
                        name=f"{ctx.program.name} @ "
                             f"{ctx.target.fabric.name}")
        ctx.check_report = report
        if self.strict and not report.ok:
            raise VerifyError(report)
        return {**report.counts(), "ok": report.ok,
                "codes": sorted(report.codes())}


class BindingPass(CompilePass):
    """Validation binding: tie the backend to the mapping artifacts.

    Records whether the executable can actually run (a config exists when
    the backend needs one) and whether ``validate()`` has an oracle path —
    surfacing at compile time what would otherwise only show up as a
    ``RuntimeError`` at ``run()`` time.
    """

    name = "binding"

    def run(self, ctx):
        be, r = ctx.backend, ctx.result
        needs = be.requires_config if be is not None else True
        runnable = (not needs) or (r is not None and r.success
                                   and r.config is not None)
        return {"backend": ctx.target.backend, "requires_config": needs,
                "runnable": runnable,
                "validatable": runnable and ctx.target.backend != "interp"}


@dataclass
class Pipeline:
    """An ordered pass list; ``run`` times each pass into the context."""

    passes: List[CompilePass]

    def run(self, ctx: CompileContext) -> CompileContext:
        from repro import obs
        tr = obs.tracer()
        try:
            for p in self.passes:
                t0 = time.perf_counter()
                stats = p.run(ctx)
                t1 = time.perf_counter()
                ctx.records.append(PassRecord(p.name, t1 - t0, stats or {}))
                if tr.enabled:
                    # one span per pass, same wall-times as the
                    # PassRecord; nests under compile()'s root span
                    tr.record(f"pass:{p.name}", t0, t1, cat="compile",
                              args=stats or None)
        finally:
            # the cold winner's per-key compile locks (see CompileContext
            # .key_lock / .process_lock) are released here even when a
            # pass raises or a custom pipeline omits the lowering pass
            if ctx.process_lock is not None:
                plock, ctx.process_lock = ctx.process_lock, None
                plock.release()
            if ctx.key_lock is not None:
                lock, ctx.key_lock = ctx.key_lock, None
                lock.release()
        return ctx


def default_pipeline(strict_verify: bool = True) -> Pipeline:
    """The standard pass list.  ``strict_verify=False`` keeps the verify
    pass but collects error findings into ``Executable.check_report``
    instead of raising — what the ``repro.ual.check`` CLI uses to render
    complete reports for broken configs."""
    return Pipeline([LayoutPass(), MIIBoundsPass(), MappingPass(),
                     LoweringPass(), VerifyPass(strict=strict_verify),
                     BindingPass()])

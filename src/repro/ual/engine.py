"""Persistent JIT execution engine: trace-once / run-many for the pallas path.

The ``pallas`` backend used to pay full tracing + lowering cost on every
call — ``cgra_exec`` rebuilt its ``pallas_call`` per invocation with the
batch size and trip count baked in as Python constants, and re-uploaded
the linked tables each time.  The paper's abstraction-layer bet (and
HyCUBE's CM-resident-on-chip bet, Morpher's map-once/simulate-many split)
is the opposite: produce the compiled artifact ONCE, execute it many
times.  This module is that half of the story:

  * ``CompiledKernelCache`` — the engine registry, keyed on
    ``(lowered fingerprint, backend opts)`` with per-``(M, bucket)`` trace
    entries below that: the full key of one compiled trace is
    ``(lowering fingerprint, backend opts, batch bucket)``,
  * each ``KernelEngine`` wraps the shared ``cgra_exec`` kernel body in
    ONE ``jax.jit`` with the linked tables uploaded to device once and
    closed over as constants (the CM-in-VMEM analogue at the host level),
  * ``n_iters`` is a *traced* scalar operand (dynamic ``fori_loop`` bound
    + fired-masking inside the kernel), so one trace serves every
    iteration count,
  * batch sizes are padded up a small **bucket ladder** (default
    ``1, 8, 32, lanes``): the execution service's variable-sized
    micro-batches hit warm traces instead of retracing per shape, and
    batches beyond the largest bucket run as warm largest-bucket chunks —
    the trace count stays O(#buckets) no matter how traffic is shaped.

Streaming (the STRELA mode — data flows through a resident config):
``run`` is upload -> sweep -> download in strict sequence, so on large
batches the host<->device transfer time is dead time.  ``run_stream``
instead pipelines warm-bucket chunks with **double buffering**: jax
dispatch is asynchronous, so while chunk *i* computes on device the
host pads/uploads chunk *i+1* and converts chunk *i-1*'s drained
results — the same bucket-ladder traces (zero new traces), with the
transfer work overlapped against compute.  Chunks are yielded as they
drain; the generator's return value reports ``overlap_frac`` (fraction
of wall time the host spent working instead of blocked on the device),
``stream_chunks`` and throughput.

Observability: every engine counts traces, calls, per-bucket hits,
padding waste and streaming activity (``streams``/``stream_chunks``);
``CompiledKernelCache.stats()`` aggregates them (the execution service
surfaces this in ``Service.stats()["engine"]``, and
``Executable.warmup()`` reports it in ``last_info``).

Multi-device (the serving-cluster substrate, ``repro.ual.cluster``):

  * ``KernelEngine(device=...)`` pins one engine to one device — tables
    and inputs are committed there, so N engines on N devices execute
    truly independent replicas (the Router's ReplicaPool path),
  * ``ShardedKernelEngine`` ``shard_map``s the *batch axis* of the same
    kernel over the host's 1-D ``data`` mesh
    (``launch.mesh.make_host_mesh``): tables are replicated once, each
    device runs one per-device bucket block, and ONE trace drives all
    local devices.  Padding is per-device — a global block is
    ``n_devices x bucket_for(ceil(chunk / n_devices))`` rows — so the
    bucket-ladder trace economy survives sharding unchanged.  Engines
    are cached per ``(fingerprint, lanes, interpret, placement)`` via
    ``engine_for(device=...)`` / ``sharded_engine_for``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import (Dict, Iterable, Iterator, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro import obs
from repro.core.lowering import LinkedConfig, lowered_fingerprint


def make_cgra_call(*args, **kwargs):
    """Lazy indirection to the shared ``pallas_call`` constructor: keeps
    ``import repro.ual`` free of the jax import (fork-based tooling like
    ``compile_many`` must be able to spawn workers before jax starts its
    threads), while tests can still monkeypatch-count traces here."""
    from repro.kernels.cgra_exec.kernel import make_cgra_call as real
    return real(*args, **kwargs)


def bucket_ladder(lanes: int = 128,
                  buckets: Optional[Sequence[int]] = None) -> Tuple[int, ...]:
    """The batch-size ladder: ascending, deduplicated, capped at ``lanes``
    (one VPU tile — bigger batches run as warm largest-bucket chunks)."""
    if buckets is None:
        buckets = (1, 8, 32, lanes)
    ladder = sorted({int(b) for b in buckets if 1 <= int(b) <= lanes})
    if not ladder:
        raise ValueError(f"bucket ladder {buckets!r} has no entry in "
                         f"[1, lanes={lanes}]")
    return tuple(ladder)


class KernelEngine:
    """One persistent engine: a lowered artifact + backend opts.

    Owns the device-resident tables (uploaded once, closed over as jit
    constants) and the single jitted entry point; ``jax.jit`` specializes
    it per ``(M, bucket)`` shape, and the ladder keeps that set small.

    ``device=`` pins the engine (tables AND per-call operands) to one
    device — the replica path: N pinned engines on N host devices
    execute concurrently with zero shared state.
    """

    ENGINE_NAME = "pallas-jit"

    def _info_extra(self) -> Dict[str, object]:
        """Engine-flavor extras merged into per-call info and stats."""
        return {}

    def __init__(self, linked: LinkedConfig, *, lanes: int = 128,
                 interpret: bool = True,
                 buckets: Optional[Sequence[int]] = None,
                 device=None) -> None:
        import jax
        import jax.numpy as jnp

        self.linked = linked
        self.lanes = lanes
        self.interpret = interpret
        self.device = device          # None -> jax default placement
        self.buckets = bucket_ladder(lanes, buckets)
        self.fingerprint = lowered_fingerprint(linked)
        self._jax = jax
        self._jnp = jnp
        # upload the CM image once per engine; every trace closes over
        # these device arrays as constants — never re-fed per call
        self._tables = self._put_tables(linked)
        # counters: traces bumps at TRACE time (a Python side effect of
        # the traced function), so it counts actual retraces, not calls.
        # Two locks: _trace_lock serializes cold traces (held for seconds),
        # _stats_lock guards the counters and the warm-shape set (held for
        # nanoseconds) so concurrent Service workers never lose an update
        # and stats() never iterates a mutating set
        self.traces = 0
        self.calls = 0
        self.samples = 0
        self.padded_samples = 0
        self.streams = 0             # run_stream invocations completed
        self.stream_chunks = 0       # chunks drained across all streams
        self.bucket_calls: Dict[int, int] = {}
        self._warm: set = set()              # (M, bucket) already traced
        self._trace_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._fn = jax.jit(self._traced)

    # -- placement (overridden by the sharded engine) -------------------------
    def _put_tables(self, linked: LinkedConfig) -> tuple:
        """Upload the CM image to this engine's placement."""
        jax, jnp = self._jax, self._jnp
        return tuple(
            jax.device_put(jnp.asarray(t, jnp.int32), self.device)
            for t in (linked.scalar, linked.ops, linked.regw))

    def _put_operand(self, arr):
        """One per-call operand (niter / mem block) onto the placement.
        Committed explicitly when the engine is device-pinned, so jit
        runs on THAT device instead of moving everything to the default."""
        if self.device is None:
            return self._jnp.asarray(arr)
        return self._jax.device_put(self._jnp.asarray(arr), self.device)

    # -- the traced function --------------------------------------------------
    def _traced(self, niter, mem):
        """``mem`` is one padded (bucket, M) block; retraced per shape."""
        self.traces += 1
        bucket, M = mem.shape
        call = make_cgra_call(self.linked, M=M, bB=bucket, n_tiles=1,
                              interpret=self.interpret)
        return call(niter, *self._tables, mem.T).T

    # -- execution ------------------------------------------------------------
    def bucket_for(self, b: int) -> int:
        """Smallest ladder bucket >= b (callers chunk at the largest)."""
        for bk in self.buckets:
            if bk >= b:
                return bk
        return self.buckets[-1]

    # -- the block plan (overridden by the sharded engine) --------------------
    def _capacity(self) -> int:
        """Rows one block can carry; ``run`` chunks bigger batches."""
        return self.buckets[-1]

    def _block_rows(self, chunk: int) -> int:
        """Padded row count the block for ``chunk`` samples executes at
        (``chunk <= _capacity()``).  The sharded engine pads per device:
        ``n_devices * bucket_for(ceil(chunk / n_devices))``."""
        return self.bucket_for(chunk)

    def _call_block(self, block: np.ndarray, niter
                    ) -> Tuple[np.ndarray, bool]:
        """One padded (bucket, M) block through the jitted entry point;
        cold ``(M, bucket)`` shapes trace under the trace lock so
        concurrent workers pay exactly one trace per bucket.  Returns
        ``(out, was_cold)`` — cold means THIS call found the shape
        untraced (info attribution stays per-call under concurrency)."""
        key = (block.shape[1], block.shape[0])
        with self._stats_lock:
            warm = key in self._warm
        if warm:
            return np.asarray(self._fn(niter, self._put_operand(block))), \
                False
        with self._trace_lock:
            out = np.asarray(self._fn(niter, self._put_operand(block)))
            with self._stats_lock:
                self._warm.add(key)
        return out, True

    def run(self, flats: np.ndarray, n_iters: int
            ) -> Tuple[np.ndarray, Dict[str, object]]:
        """Execute a (B, M) batch of scratchpad images for ``n_iters``.

        Pads each chunk up the bucket ladder (B > largest bucket runs as
        warm largest-bucket chunks) and slices the padding back off;
        returns ``(out (B, M), per-call info)``.
        """
        jnp = self._jnp
        flats = np.ascontiguousarray(flats, np.int32)
        B, M = flats.shape
        niter = self._put_operand(
            jnp.asarray(n_iters, jnp.int32).reshape(1, 1))
        used: List[int] = []
        cold_blocks = 0
        top = self._capacity()
        if B <= top and self._block_rows(B) == B:
            # pad-free fast path: the batch IS a bucket — no padding
            # rows to append, no staging buffer to copy through
            out, was_cold = self._call_block(flats, niter)
            cold_blocks = int(was_cold)
            used.append(B)
        else:
            out = np.empty((B, M), np.int32)
            i = 0
            while i < B:
                chunk = min(B - i, top)
                rows = self._block_rows(chunk)
                block = flats[i:i + chunk]
                if rows != chunk:
                    block = np.concatenate(
                        [block, np.zeros((rows - chunk, M), np.int32)])
                block_out, was_cold = self._call_block(block, niter)
                out[i:i + chunk] = block_out[:chunk]
                cold_blocks += was_cold
                used.append(rows)
                i += chunk
        with self._stats_lock:
            for rows in used:
                self.bucket_calls[rows] = \
                    self.bucket_calls.get(rows, 0) + 1
            self.padded_samples += sum(used) - B
            self.calls += 1
            self.samples += B
            traces_total = self.traces
        info = {
            "engine": self.ENGINE_NAME,
            "buckets": used,
            "padded": sum(used) - B,
            "traced": cold_blocks,
            "traces_total": traces_total,
            **self._info_extra(),
        }
        return out, info

    # -- streaming ------------------------------------------------------------
    def _dispatch_block(self, block: np.ndarray, niter
                        ) -> Tuple[object, bool]:
        """Asynchronously dispatch one padded block; returns the device
        future WITHOUT materializing it.  Warm shapes return immediately
        (jax async dispatch); cold shapes trace synchronously under the
        trace lock — a cold trace takes seconds and must not sit in the
        pipeline as if it were a 1 ms hop."""
        key = (block.shape[1], block.shape[0])
        with self._stats_lock:
            warm = key in self._warm
        if warm:
            return self._fn(niter, self._put_operand(block)), False
        with self._trace_lock:
            fut = self._fn(niter, self._put_operand(block))
            fut.block_until_ready()
            with self._stats_lock:
                self._warm.add(key)
        return fut, True

    def run_stream(self, source: Union[np.ndarray, Iterable[np.ndarray]],
                   n_iters: int, *, chunk: Optional[int] = None,
                   depth: int = 2
                   ) -> Iterator[Tuple[np.ndarray, Dict[str, object]]]:
        """Streaming execution: pipeline warm-bucket chunks with double
        buffering, yielding ``(out_chunk (b, M), chunk_info)`` as each
        chunk drains.

        ``source`` is a (B, M) batch or an iterable of (b, M) row blocks
        (blocks larger than ``chunk`` are re-chunked).  While chunk *i*
        computes on device, the host pads/uploads chunk *i+1* and
        converts chunk *i-1*'s results — jax async dispatch keeps up to
        ``depth`` chunks in flight, so host<->device transfer work
        overlaps compute instead of serializing with it (``run``'s
        upload -> sweep -> download).  Chunks ride the same bucket-ladder
        traces as ``run``: a warmed engine streams with ZERO new traces.

        The generator's return value (``StopIteration.value``) is the
        stream summary: ``stream_chunks``, ``samples``, ``wall_s``,
        ``throughput_sps``, ``wait_s`` (host time blocked on the device)
        and ``overlap_frac`` = 1 - wait/wall — the fraction of the wall
        the host spent preparing/draining other chunks while the device
        worked.  A fully serialized pipeline (or an empty stream)
        reports 0.0.
        """
        jnp = self._jnp
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        top = self._capacity()
        step = top if chunk is None else max(1, min(int(chunk), top))
        niter = self._put_operand(
            jnp.asarray(n_iters, jnp.int32).reshape(1, 1))

        def blocks() -> Iterator[np.ndarray]:
            blks = [source] if isinstance(source, np.ndarray) else source
            for blk in blks:
                blk = np.ascontiguousarray(blk, np.int32)
                for i in range(0, len(blk), step):
                    yield blk[i:i + step]

        t_start = time.perf_counter()
        wait_s = 0.0
        used: List[int] = []
        cold_blocks = 0
        n_samples = 0
        n_chunks = 0
        n_dispatched = 0
        tr = obs.tracer()
        tron = tr.enabled
        # one trace groups every chunk span of this stream in the export
        stream_trace = tr.new_trace_id() if tron else None
        inflight: deque = deque()  # (future, b, rows, was_cold, t_disp, i)

        def drain() -> Tuple[np.ndarray, Dict[str, object]]:
            nonlocal wait_s, cold_blocks, n_samples, n_chunks
            fut, b, rows, was_cold, t_disp, i_chunk = inflight.popleft()
            t0 = time.perf_counter()
            fut.block_until_ready()
            t1 = time.perf_counter()
            wait_s += t1 - t0
            out = np.asarray(fut)[:b]
            cold_blocks += was_cold
            used.append(rows)
            n_samples += b
            n_chunks += 1
            if tron:
                # device-busy window approximated from dispatch end to
                # ready; drain = host-side conversion back to numpy
                attrs = {"chunk": i_chunk, "bucket": rows, "samples": b}
                tr.record("stream:compute", t_disp, t1, cat="engine",
                          trace=stream_trace, args=attrs)
                tr.record("stream:drain", t1, time.perf_counter(),
                          cat="engine", trace=stream_trace, args=attrs)
            return out, {"chunk": n_chunks - 1, "bucket": rows,
                         "samples": b, "traced": int(was_cold)}

        for blk in blocks():
            b = blk.shape[0]
            t_up = time.perf_counter() if tron else 0.0
            rows = self._block_rows(b)
            if rows != b:
                blk = np.concatenate(
                    [blk, np.zeros((rows - b, blk.shape[1]), np.int32)])
            fut, was_cold = self._dispatch_block(blk, niter)
            t_disp = time.perf_counter() if tron else 0.0
            if tron:
                tr.record("stream:upload", t_up, t_disp, cat="engine",
                          trace=stream_trace,
                          args={"chunk": n_dispatched, "bucket": rows,
                                "samples": b, "traced": int(was_cold)})
            inflight.append((fut, b, rows, was_cold, t_disp, n_dispatched))
            n_dispatched += 1
            while len(inflight) > depth:
                yield drain()
        while inflight:
            yield drain()

        wall = time.perf_counter() - t_start
        with self._stats_lock:
            for rows in used:
                self.bucket_calls[rows] = self.bucket_calls.get(rows, 0) + 1
            self.padded_samples += sum(used) - n_samples
            self.calls += 1
            self.samples += n_samples
            self.streams += 1
            self.stream_chunks += n_chunks
            traces_total = self.traces
        return {
            "engine": self.ENGINE_NAME,
            "stream_chunks": n_chunks,
            "samples": n_samples,
            "buckets": used,
            "padded": sum(used) - n_samples,
            "traced": cold_blocks,
            "traces_total": traces_total,
            "wall_s": wall,
            "wait_s": wait_s,
            "overlap_frac": (round(max(0.0, 1.0 - wait_s / wall), 4)
                             if wall > 0 and n_chunks else 0.0),
            "throughput_sps": n_samples / wall if wall > 0 else 0.0,
            **self._info_extra(),
        }

    def warmup(self, M: int,
               buckets: Optional[Sequence[int]] = None) -> Dict[str, object]:
        """Pre-trace the ladder (or a subset) for scratchpad width ``M``
        with a zero batch — ``n_iters`` is traced, so one warm trace per
        bucket covers every trip count.  Requested sizes off the engine's
        ladder snap UP to the bucket that will actually execute them
        (``bucket_for``), so re-warming is always a no-op.  Returns this
        engine's stats."""
        want = sorted({self._block_rows(min(b, self._capacity())) for b in
                       bucket_ladder(self.lanes, buckets or self.buckets)})
        for rows in want:
            with self._stats_lock:
                warm = (M, rows) in self._warm
            if not warm:
                self.run(np.zeros((rows, M), np.int32), 1)
        return self.stats()

    # -- observability --------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._stats_lock:
            traces = self.traces
            bucket_calls = dict(sorted(self.bucket_calls.items()))
            snap = {
                "calls": self.calls,
                "samples": self.samples,
                "padded_samples": self.padded_samples,
                "streams": self.streams,
                "stream_chunks": self.stream_chunks,
                "warm_shapes": sorted(self._warm),
            }
        calls = sum(bucket_calls.values())
        hits = max(0, calls - traces)
        return {
            "traces": traces,
            "bucket_calls": bucket_calls,
            "hit_ratio": round(hits / calls, 4) if calls else None,
            "buckets": self.buckets,
            **snap,
            **self._info_extra(),
        }


class ShardedKernelEngine(KernelEngine):
    """The multi-device engine: one trace drives all local devices.

    ``shard_map``s the batch axis of the persistent kernel over a 1-D
    ``data`` mesh (default: ``launch.mesh.make_host_mesh()`` — every
    device on the host).  The linked tables are uploaded once with a
    *replicated* sharding; each device executes one per-device bucket
    block of the batch, so a global block is
    ``n_devices x bucket_for(ceil(chunk / n_devices))`` rows and the
    bucket-ladder trace economy is unchanged — the warm-shape set and
    trace count stay O(#buckets) while throughput scales with the mesh.

    ``check_rep=False`` on the shard_map is required: pallas_call has no
    replication rule, and the body touches only per-device data anyway.

    Parity contract: bit-exact with the single-device engine (and the
    interp oracle) for every batch size, including ragged final chunks —
    padding rows are zero blocks whose outputs are sliced off, exactly
    as in the single-device path.
    """

    ENGINE_NAME = "pallas-jit-sharded"

    def __init__(self, linked: LinkedConfig, *, lanes: int = 128,
                 interpret: bool = True,
                 buckets: Optional[Sequence[int]] = None,
                 mesh=None) -> None:
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        if mesh.devices.ndim != 1:
            raise ValueError(
                f"ShardedKernelEngine needs a 1-D mesh (the batch axis), "
                f"got shape {mesh.devices.shape}")
        self.mesh = mesh
        self.axis = mesh.axis_names[0]
        self.n_devices = int(mesh.devices.size)
        super().__init__(linked, lanes=lanes, interpret=interpret,
                         buckets=buckets)

    def _info_extra(self) -> Dict[str, object]:
        return {"n_devices": self.n_devices}

    def _put_tables(self, linked: LinkedConfig) -> tuple:
        """The CM image once per device: replicated over the mesh."""
        from jax.sharding import NamedSharding, PartitionSpec
        jax, jnp = self._jax, self._jnp
        rep = NamedSharding(self.mesh, PartitionSpec())
        return tuple(
            jax.device_put(jnp.asarray(t, jnp.int32), rep)
            for t in (linked.scalar, linked.ops, linked.regw))

    def _put_operand(self, arr):
        return self._jnp.asarray(arr)

    def _traced(self, niter, mem):
        """``mem`` is one (n_devices * bucket, M) global block; each
        device's shard runs the same pallas_call at the per-device
        bucket shape — one trace, every device."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        self.traces += 1
        rows, M = mem.shape
        bucket = rows // self.n_devices
        call = make_cgra_call(self.linked, M=M, bB=bucket, n_tiles=1,
                              interpret=self.interpret)

        def shard_fn(niter, mem_shard):
            return call(niter, *self._tables, mem_shard.T).T

        return shard_map(shard_fn, mesh=self.mesh,
                         in_specs=(P(), P(self.axis, None)),
                         out_specs=P(self.axis, None),
                         check_rep=False)(niter, mem)

    # -- the sharded block plan ----------------------------------------------
    def _capacity(self) -> int:
        return self.n_devices * self.buckets[-1]

    def _block_rows(self, chunk: int) -> int:
        per_device = -(-chunk // self.n_devices)      # ceil
        return self.n_devices * self.bucket_for(per_device)


class CompiledKernelCache:
    """The engine registry: one ``KernelEngine`` per
    ``(lowered fingerprint, lanes, interpret, placement)``, created on
    first use and kept for the life of the process — the
    trace-once/run-many cache the pallas backend, ``Executable.warmup``
    and the execution service share.  Placement distinguishes the default
    engine, device-pinned replica engines (``device=``) and the sharded
    multi-device engine (``sharded_engine_for``).
    """

    def __init__(self, buckets: Optional[Sequence[int]] = None) -> None:
        self.default_buckets = buckets
        self._engines: Dict[Tuple[str, int, bool, Optional[str]],
                            KernelEngine] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _placement(device, mesh, sharded: bool) -> Optional[str]:
        if sharded:
            if mesh is None:
                return "sharded:host"
            return "sharded:" + ",".join(
                str(d.id) for d in mesh.devices.flat)
        return None if device is None else f"dev:{device.id}"

    def engine_for(self, linked: LinkedConfig, *, lanes: int = 128,
                   interpret: bool = True,
                   buckets: Optional[Sequence[int]] = None,
                   device=None) -> KernelEngine:
        key = (lowered_fingerprint(linked), lanes, interpret,
               self._placement(device, None, False))
        with self._lock:
            eng = self._engines.get(key)
            if eng is None:
                eng = KernelEngine(linked, lanes=lanes, interpret=interpret,
                                   buckets=buckets or self.default_buckets,
                                   device=device)
                self._engines[key] = eng
            return eng

    def sharded_engine_for(self, linked: LinkedConfig, *, lanes: int = 128,
                           interpret: bool = True,
                           buckets: Optional[Sequence[int]] = None,
                           mesh=None) -> ShardedKernelEngine:
        """The multi-device engine for ``linked`` (default mesh: every
        host device on a 1-D ``data`` axis), cached like ``engine_for``."""
        key = (lowered_fingerprint(linked), lanes, interpret,
               self._placement(None, mesh, True))
        with self._lock:
            eng = self._engines.get(key)
            if eng is None:
                eng = ShardedKernelEngine(
                    linked, lanes=lanes, interpret=interpret,
                    buckets=buckets or self.default_buckets, mesh=mesh)
                self._engines[key] = eng
            return eng

    def run(self, linked: LinkedConfig, flats: np.ndarray, n_iters: int, *,
            lanes: int = 128, interpret: bool = True, device=None
            ) -> Tuple[np.ndarray, Dict[str, object]]:
        eng = self.engine_for(linked, lanes=lanes, interpret=interpret,
                              device=device)
        return eng.run(flats, n_iters)

    def sharded_run(self, linked: LinkedConfig, flats: np.ndarray,
                    n_iters: int, *, lanes: int = 128,
                    interpret: bool = True, mesh=None
                    ) -> Tuple[np.ndarray, Dict[str, object]]:
        eng = self.sharded_engine_for(linked, lanes=lanes,
                                      interpret=interpret, mesh=mesh)
        return eng.run(flats, n_iters)

    def run_stream(self, linked: LinkedConfig, source, n_iters: int, *,
                   chunk: Optional[int] = None, depth: int = 2,
                   lanes: int = 128, interpret: bool = True, device=None
                   ) -> Iterator[Tuple[np.ndarray, Dict[str, object]]]:
        """Streaming execution through the cached engine for ``linked``
        (see ``KernelEngine.run_stream``); yields drained chunks, returns
        the stream summary via ``StopIteration.value``."""
        eng = self.engine_for(linked, lanes=lanes, interpret=interpret,
                              device=device)
        return eng.run_stream(source, n_iters, chunk=chunk, depth=depth)

    def warmup(self, linked: LinkedConfig, M: int, *,
               buckets: Optional[Sequence[int]] = None, lanes: int = 128,
               interpret: bool = True, device=None) -> Dict[str, object]:
        eng = self.engine_for(linked, lanes=lanes, interpret=interpret,
                              device=device)
        return eng.warmup(M, buckets)

    def stats(self) -> Dict[str, object]:
        """Aggregate over every engine: total traces / calls / samples,
        hit ratio, plus the per-engine breakdown."""
        with self._lock:
            engines = dict(self._engines)
        per = {}
        for (fp, lanes, it, placement), e in engines.items():
            name = f"{fp[:12]}/lanes={lanes}/{'interp' if it else 'tpu'}"
            if placement is not None:
                name += f"/{placement}"
            per[name] = e.stats()
        traces = sum(e["traces"] for e in per.values())
        bucket_calls = sum(sum(e["bucket_calls"].values())
                           for e in per.values())
        hits = max(0, bucket_calls - traces)
        return {
            "engines": len(per),
            "traces": traces,
            "calls": sum(e["calls"] for e in per.values()),
            "samples": sum(e["samples"] for e in per.values()),
            "padded_samples": sum(e["padded_samples"] for e in per.values()),
            "streams": sum(e["streams"] for e in per.values()),
            "stream_chunks": sum(e["stream_chunks"] for e in per.values()),
            "hit_ratio": round(hits / bucket_calls, 4) if bucket_calls
            else None,
            "per_engine": per,
        }

    def __len__(self) -> int:
        with self._lock:
            return len(self._engines)


_default: Optional[CompiledKernelCache] = None
_default_lock = threading.Lock()


def default_engine() -> CompiledKernelCache:
    """The process-wide engine cache the pallas backend uses by default.
    Its aggregate stats are registered as the ``engine`` source in the
    metrics registry (``obs.registry().snapshot()["sources"]["engine"]``)
    — the source reads through this accessor, so swapping the default
    engine needs no re-registration."""
    global _default
    with _default_lock:
        if _default is None:
            _default = CompiledKernelCache()
            obs.registry().register_source(
                "engine", lambda: default_engine().stats(), replace=True)
        return _default


def set_default_engine(cache: Optional[CompiledKernelCache]
                       ) -> CompiledKernelCache:
    """Swap the process-wide engine cache (e.g. a fresh one in tests);
    returns the previous one so callers can restore it."""
    global _default
    prev = default_engine()
    with _default_lock:
        _default = cache
    return prev

"""Persistent JIT execution engine: trace-once / run-many for the pallas path.

The ``pallas`` backend used to pay full tracing + lowering cost on every
call — ``cgra_exec`` rebuilt its ``pallas_call`` per invocation with the
batch size and trip count baked in as Python constants, and re-uploaded
the linked tables each time.  The paper's abstraction-layer bet (and
HyCUBE's CM-resident-on-chip bet, Morpher's map-once/simulate-many split)
is the opposite: produce the compiled artifact ONCE, execute it many
times.  This module is that half of the story:

  * ``CompiledKernelCache`` — the engine registry, keyed on
    ``(lowered fingerprint, backend opts)`` with per-``(M, bucket)`` trace
    entries below that: the full key of one compiled trace is
    ``(lowering fingerprint, backend opts, batch bucket)``,
  * each ``KernelEngine`` wraps the shared ``cgra_exec`` kernel body in
    ONE ``jax.jit`` with the linked tables uploaded to device once and
    closed over as constants (the CM-in-VMEM analogue at the host level),
  * ``n_iters`` is a *traced* scalar operand (dynamic ``fori_loop`` bound
    + fired-masking inside the kernel), so one trace serves every
    iteration count,
  * batch sizes are padded up a small **bucket ladder** (default
    ``1, 8, 32, lanes``): the execution service's variable-sized
    micro-batches hit warm traces instead of retracing per shape, and
    batches beyond the largest bucket run as warm largest-bucket chunks —
    the trace count stays O(#buckets) no matter how traffic is shaped.

Observability: every engine counts traces, calls, per-bucket hits and
padding waste; ``CompiledKernelCache.stats()`` aggregates them (the
execution service surfaces this in ``Service.stats()["engine"]``, and
``Executable.warmup()`` reports it in ``last_info``).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lowering import LinkedConfig, lowered_fingerprint


def make_cgra_call(*args, **kwargs):
    """Lazy indirection to the shared ``pallas_call`` constructor: keeps
    ``import repro.ual`` free of the jax import (fork-based tooling like
    ``compile_many`` must be able to spawn workers before jax starts its
    threads), while tests can still monkeypatch-count traces here."""
    from repro.kernels.cgra_exec.kernel import make_cgra_call as real
    return real(*args, **kwargs)


def bucket_ladder(lanes: int = 128,
                  buckets: Optional[Sequence[int]] = None) -> Tuple[int, ...]:
    """The batch-size ladder: ascending, deduplicated, capped at ``lanes``
    (one VPU tile — bigger batches run as warm largest-bucket chunks)."""
    if buckets is None:
        buckets = (1, 8, 32, lanes)
    ladder = sorted({int(b) for b in buckets if 1 <= int(b) <= lanes})
    if not ladder:
        raise ValueError(f"bucket ladder {buckets!r} has no entry in "
                         f"[1, lanes={lanes}]")
    return tuple(ladder)


class KernelEngine:
    """One persistent engine: a lowered artifact + backend opts.

    Owns the device-resident tables (uploaded once, closed over as jit
    constants) and the single jitted entry point; ``jax.jit`` specializes
    it per ``(M, bucket)`` shape, and the ladder keeps that set small.
    """

    def __init__(self, linked: LinkedConfig, *, lanes: int = 128,
                 interpret: bool = True,
                 buckets: Optional[Sequence[int]] = None) -> None:
        import jax
        import jax.numpy as jnp

        self.linked = linked
        self.lanes = lanes
        self.interpret = interpret
        self.buckets = bucket_ladder(lanes, buckets)
        self.fingerprint = lowered_fingerprint(linked)
        # upload the CM image once per engine; every trace closes over
        # these device arrays as constants — never re-fed per call
        self._tables = tuple(
            jax.device_put(jnp.asarray(t, jnp.int32))
            for t in (linked.scalar, linked.ops, linked.regw))
        self._jnp = jnp
        # counters: traces bumps at TRACE time (a Python side effect of
        # the traced function), so it counts actual retraces, not calls.
        # Two locks: _trace_lock serializes cold traces (held for seconds),
        # _stats_lock guards the counters and the warm-shape set (held for
        # nanoseconds) so concurrent Service workers never lose an update
        # and stats() never iterates a mutating set
        self.traces = 0
        self.calls = 0
        self.samples = 0
        self.padded_samples = 0
        self.bucket_calls: Dict[int, int] = {}
        self._warm: set = set()              # (M, bucket) already traced
        self._trace_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._fn = jax.jit(self._traced)

    # -- the traced function --------------------------------------------------
    def _traced(self, niter, mem):
        """``mem`` is one padded (bucket, M) block; retraced per shape."""
        self.traces += 1
        bucket, M = mem.shape
        call = make_cgra_call(self.linked, M=M, bB=bucket, n_tiles=1,
                              interpret=self.interpret)
        return call(niter, *self._tables, mem.T).T

    # -- execution ------------------------------------------------------------
    def bucket_for(self, b: int) -> int:
        """Smallest ladder bucket >= b (callers chunk at the largest)."""
        for bk in self.buckets:
            if bk >= b:
                return bk
        return self.buckets[-1]

    def _call_block(self, block: np.ndarray, niter
                    ) -> Tuple[np.ndarray, bool]:
        """One padded (bucket, M) block through the jitted entry point;
        cold ``(M, bucket)`` shapes trace under the trace lock so
        concurrent workers pay exactly one trace per bucket.  Returns
        ``(out, was_cold)`` — cold means THIS call found the shape
        untraced (info attribution stays per-call under concurrency)."""
        key = (block.shape[1], block.shape[0])
        with self._stats_lock:
            warm = key in self._warm
        if warm:
            return np.asarray(self._fn(niter, self._jnp.asarray(block))), \
                False
        with self._trace_lock:
            out = np.asarray(self._fn(niter, self._jnp.asarray(block)))
            with self._stats_lock:
                self._warm.add(key)
        return out, True

    def run(self, flats: np.ndarray, n_iters: int
            ) -> Tuple[np.ndarray, Dict[str, object]]:
        """Execute a (B, M) batch of scratchpad images for ``n_iters``.

        Pads each chunk up the bucket ladder (B > largest bucket runs as
        warm largest-bucket chunks) and slices the padding back off;
        returns ``(out (B, M), per-call info)``.
        """
        jnp = self._jnp
        flats = np.ascontiguousarray(flats, np.int32)
        B, M = flats.shape
        niter = jnp.asarray(n_iters, jnp.int32).reshape(1, 1)
        out = np.empty((B, M), np.int32)
        used: List[int] = []
        cold_blocks = 0
        top = self.buckets[-1]
        i = 0
        while i < B:
            chunk = min(B - i, top)
            bucket = self.bucket_for(chunk)
            block = flats[i:i + chunk]
            if bucket != chunk:
                block = np.concatenate(
                    [block, np.zeros((bucket - chunk, M), np.int32)])
            block_out, was_cold = self._call_block(block, niter)
            out[i:i + chunk] = block_out[:chunk]
            cold_blocks += was_cold
            used.append(bucket)
            i += chunk
        with self._stats_lock:
            for bucket in used:
                self.bucket_calls[bucket] = \
                    self.bucket_calls.get(bucket, 0) + 1
            self.padded_samples += sum(used) - B
            self.calls += 1
            self.samples += B
            traces_total = self.traces
        info = {
            "engine": "pallas-jit",
            "buckets": used,
            "padded": sum(used) - B,
            "traced": cold_blocks,
            "traces_total": traces_total,
        }
        return out, info

    def warmup(self, M: int,
               buckets: Optional[Sequence[int]] = None) -> Dict[str, object]:
        """Pre-trace the ladder (or a subset) for scratchpad width ``M``
        with a zero batch — ``n_iters`` is traced, so one warm trace per
        bucket covers every trip count.  Requested sizes off the engine's
        ladder snap UP to the bucket that will actually execute them
        (``bucket_for``), so re-warming is always a no-op.  Returns this
        engine's stats."""
        want = sorted({self.bucket_for(b) for b in
                       bucket_ladder(self.lanes, buckets or self.buckets)})
        for bucket in want:
            with self._stats_lock:
                warm = (M, bucket) in self._warm
            if not warm:
                self.run(np.zeros((bucket, M), np.int32), 1)
        return self.stats()

    # -- observability --------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._stats_lock:
            traces = self.traces
            bucket_calls = dict(sorted(self.bucket_calls.items()))
            snap = {
                "calls": self.calls,
                "samples": self.samples,
                "padded_samples": self.padded_samples,
                "warm_shapes": sorted(self._warm),
            }
        calls = sum(bucket_calls.values())
        hits = max(0, calls - traces)
        return {
            "traces": traces,
            "bucket_calls": bucket_calls,
            "hit_ratio": round(hits / calls, 4) if calls else None,
            "buckets": self.buckets,
            **snap,
        }


class CompiledKernelCache:
    """The engine registry: one ``KernelEngine`` per
    ``(lowered fingerprint, lanes, interpret)``, created on first use and
    kept for the life of the process — the trace-once/run-many cache the
    pallas backend, ``Executable.warmup`` and the execution service share.
    """

    def __init__(self, buckets: Optional[Sequence[int]] = None) -> None:
        self.default_buckets = buckets
        self._engines: Dict[Tuple[str, int, bool], KernelEngine] = {}
        self._lock = threading.Lock()

    def engine_for(self, linked: LinkedConfig, *, lanes: int = 128,
                   interpret: bool = True,
                   buckets: Optional[Sequence[int]] = None) -> KernelEngine:
        key = (lowered_fingerprint(linked), lanes, interpret)
        with self._lock:
            eng = self._engines.get(key)
            if eng is None:
                eng = KernelEngine(linked, lanes=lanes, interpret=interpret,
                                   buckets=buckets or self.default_buckets)
                self._engines[key] = eng
            return eng

    def run(self, linked: LinkedConfig, flats: np.ndarray, n_iters: int, *,
            lanes: int = 128, interpret: bool = True
            ) -> Tuple[np.ndarray, Dict[str, object]]:
        eng = self.engine_for(linked, lanes=lanes, interpret=interpret)
        return eng.run(flats, n_iters)

    def warmup(self, linked: LinkedConfig, M: int, *,
               buckets: Optional[Sequence[int]] = None, lanes: int = 128,
               interpret: bool = True) -> Dict[str, object]:
        eng = self.engine_for(linked, lanes=lanes, interpret=interpret)
        return eng.warmup(M, buckets)

    def stats(self) -> Dict[str, object]:
        """Aggregate over every engine: total traces / calls / samples,
        hit ratio, plus the per-engine breakdown."""
        with self._lock:
            engines = dict(self._engines)
        per = {f"{fp[:12]}/lanes={lanes}/{'interp' if it else 'tpu'}":
               e.stats() for (fp, lanes, it), e in engines.items()}
        traces = sum(e["traces"] for e in per.values())
        bucket_calls = sum(sum(e["bucket_calls"].values())
                           for e in per.values())
        hits = max(0, bucket_calls - traces)
        return {
            "engines": len(per),
            "traces": traces,
            "calls": sum(e["calls"] for e in per.values()),
            "samples": sum(e["samples"] for e in per.values()),
            "padded_samples": sum(e["padded_samples"] for e in per.values()),
            "hit_ratio": round(hits / bucket_calls, 4) if bucket_calls
            else None,
            "per_engine": per,
        }

    def __len__(self) -> int:
        with self._lock:
            return len(self._engines)


_default: Optional[CompiledKernelCache] = None
_default_lock = threading.Lock()


def default_engine() -> CompiledKernelCache:
    """The process-wide engine cache the pallas backend uses by default."""
    global _default
    with _default_lock:
        if _default is None:
            _default = CompiledKernelCache()
        return _default


def set_default_engine(cache: Optional[CompiledKernelCache]
                       ) -> CompiledKernelCache:
    """Swap the process-wide engine cache (e.g. a fresh one in tests);
    returns the previous one so callers can restore it."""
    global _default
    prev = default_engine()
    with _default_lock:
        _default = cache
    return prev

"""``Executable`` — a compiled (Program, Target) pair, dict-in/dict-out.

``compile()`` produces one of these.  It owns the mapping artifacts
(``MapResult`` with the machine configuration), the **lowered artifact**
(the dense linked tables every execution engine consumes — produced once
by the pipeline's lowering pass) and compile-time metadata (cache hit?
how many mapper restarts did *this* compile pay?), and runs on any
registered backend with automatic flatten/unflatten of the named arrays:

    exe = compile(program, target)
    out = exe.run(a=a, b=b)                  # dict in, dict out
    outs = exe.run_batch([{...}, {...}])     # natively batched (sim/pallas)
    exe.last_info["throughput_sps"]          # samples/s of that call
    report = exe.validate(seed=0)            # vs the DFG-interpreter oracle

    for chunk in exe.run_stream(mems):       # streaming: chunks drain as
        consume(chunk)                       # later chunks still compute
    exe.last_info["overlap_frac"]            # transfer/compute overlap

Streaming (``run_stream`` / ``run_batch(stream=True)``) pipelines the
batch through the backend in warm-bucket chunks — on the pallas backend
chunk *i* computes on device while *i+1* uploads and *i-1* drains
(double buffering over jax async dispatch); other backends fall back to
chunked synchronous delivery.  The stream summary (``stream_chunks``,
``overlap_frac``, ``throughput_sps``) lands in ``last_info`` at
exhaustion and is also the generator's return value
(``StopIteration.value``) for concurrent sharers.

Execution info (engine stats, throughput) is *returned per call*
internally; ``last_info`` is only a convenience copy of the most recent
call's info, so one Executable can be shared across threads or worker
processes (batched serving, ``explore(workers=N)``) without the info of
concurrent calls racing each other — never read ``last_info`` to observe
a *specific* call's info in concurrent code.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.verifier import CheckReport
from repro.core.lowering import LinkedConfig
from repro.core.mapper import MapResult
from repro.ual.backends import Backend, get_backend
from repro.ual.program import Program
from repro.ual.target import Target


@dataclass
class PassRecord:
    """One pipeline pass's report: what ran, how long, what it found."""

    name: str
    wall_s: float = 0.0
    stats: Dict[str, object] = field(default_factory=dict)

    def __str__(self) -> str:
        kv = ", ".join(f"{k}={v}" for k, v in self.stats.items())
        return f"{self.name}: {self.wall_s * 1e3:.2f}ms ({kv})"


@dataclass
class CompileInfo:
    cache_hit: bool = False
    mapper_restarts: int = 0      # restarts paid by THIS compile (0 on hit)
    wall_s: float = 0.0
    key: Optional[Tuple[str, str]] = None
    passes: List[PassRecord] = field(default_factory=list)

    @property
    def pass_times(self) -> Dict[str, float]:
        """Per-pass wall seconds keyed by pass name (pipeline order)."""
        return {p.name: p.wall_s for p in self.passes}


@dataclass
class Executable:
    program: Program
    target: Target
    map_result: Optional[MapResult]          # None for mapping-free backends
    compile_info: CompileInfo = field(default_factory=CompileInfo)
    spatial_subgraphs: int = 0               # spatial fabrics: #subgraphs
    lowered: Optional[LinkedConfig] = None   # shared lowered artifact
    #: the compile-time verifier's findings (``repro.analysis.verifier``)
    #: — present whenever a machine configuration was verified.  Errors
    #: abort ``compile()`` (``VerifyError``), so a constructed Executable
    #: carries at most warnings/infos here; None for mapping-free
    #: backends, spatial fabrics and custom pipelines without the pass
    check_report: Optional[CheckReport] = None
    #: convenience copy of the most recent run/run_batch info — NOT a
    #: synchronization point; concurrent callers each get their own info
    #: internally and this attribute only reflects whichever call wrote last
    last_info: Dict[str, object] = field(default_factory=dict)

    # -- introspection --------------------------------------------------------
    @property
    def II(self) -> Optional[int]:
        """Achieved initiation interval; None for mapping-free executables
        (interp backend), where no II exists to compare."""
        return self.map_result.II if self.map_result else None

    @property
    def success(self) -> bool:
        return self.map_result.success if self.map_result else True

    def __str__(self) -> str:
        ii = self.II if self.success else "unmapped"
        hit = "cache" if self.compile_info.cache_hit else "cold"
        return (f"Executable({self.program.name} on {self.target.name}: "
                f"II={ii}, {hit}, {self.compile_info.wall_s:.2f}s)")

    # -- execution ------------------------------------------------------------
    def _resolve(self, backend: Optional[str]) -> Backend:
        name = backend or self.target.backend
        be = get_backend(name)
        if be.requires_config:
            if self.map_result is not None and not self.map_result.success:
                raise RuntimeError(
                    f"{self.program.name}: mapping onto "
                    f"{self.target.fabric.name} failed "
                    f"(ii_max={self.target.ii_max}, "
                    f"{self.map_result.restarts} restarts); raise ii_max / "
                    f"max_restarts or use a larger fabric")
            if self.map_result is None or self.map_result.config is None:
                raise RuntimeError(
                    f"{self.program.name}: backend {name!r} needs a machine "
                    f"configuration, but this executable has none (compiled "
                    f"for a mapping-free backend or a spatial fabric); "
                    f"recompile with a temporal fabric target")
        return be

    def _backend_kwargs(self, be: Backend) -> Dict[str, object]:
        """Extra keywords for backends that consume the lowered artifact.

        Executables compiled before the lowering pass existed (or through
        a custom pipeline without it) lower lazily here, once, and keep
        the artifact for subsequent calls.
        """
        if not getattr(be, "consumes_lowered", False):
            return {}
        if (self.lowered is None and self.map_result is not None
                and self.map_result.config is not None):
            from repro.core.lowering import link_config
            self.lowered = link_config(self.map_result.config)
        return {"lowered": self.lowered}

    def _execute(self, mem: Dict[str, np.ndarray], n_iters: int,
                 backend: Optional[str]
                 ) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        """One sample through a backend; returns (outputs, per-call info)."""
        be = self._resolve(backend)
        out, info = be.execute(self.program, self.map_result, mem, n_iters,
                               **self._backend_kwargs(be))
        return out, dict(info)

    def _execute_batch(self, mems: Sequence[Dict[str, np.ndarray]],
                       n_iters: int, backend: Optional[str],
                       **backend_opts: object
                       ) -> Tuple[List[Dict[str, np.ndarray]],
                                  Dict[str, object]]:
        """A batch through a backend; returns (outputs, per-call info with
        wall time and throughput in samples/s).  ``backend_opts`` are
        forwarded verbatim (e.g. ``device=`` on backends advertising
        ``supports_device`` — the replica router's placement path)."""
        be = self._resolve(backend)
        mems = list(mems)
        t0 = time.perf_counter()
        outs, info = be.execute_batch(self.program, self.map_result, mems,
                                      n_iters, **self._backend_kwargs(be),
                                      **backend_opts)
        wall = time.perf_counter() - t0
        info = dict(info)
        info["wall_s"] = wall
        info["batch"] = len(mems)
        info["throughput_sps"] = len(mems) / wall if wall > 0 else float("inf")
        return outs, info

    def _execute_stream(self, mems, n_iters: int, backend: Optional[str],
                        chunk: Optional[int] = None, **backend_opts: object):
        """A batch through a backend's streaming path; yields
        ``(out_dicts, chunk_info)`` per drained chunk and *returns* the
        stream summary (wall time, samples, ``overlap_frac``,
        ``throughput_sps``) as the generator's value."""
        be = self._resolve(backend)
        t0 = time.perf_counter()
        gen = be.execute_stream(self.program, self.map_result, mems, n_iters,
                                chunk=chunk, **self._backend_kwargs(be),
                                **backend_opts)
        n_samples = 0
        n_chunks = 0
        while True:
            try:
                outs, cinfo = next(gen)
            except StopIteration as stop:
                summary = dict(stop.value or {})
                break
            n_samples += len(outs)
            n_chunks += 1
            yield outs, cinfo
        wall = time.perf_counter() - t0
        summary.setdefault("stream_chunks", n_chunks)
        summary["stream"] = True
        summary["wall_s"] = wall
        summary["batch"] = n_samples
        summary["throughput_sps"] = (n_samples / wall if wall > 0
                                     else float("inf"))
        return summary

    def warmup(self, buckets: Optional[Sequence[int]] = None, *,
               backend: Optional[str] = None) -> Dict[str, object]:
        """Pre-trace the execution engine's batch-bucket ladder (pallas:
        one jit trace per bucket; ``n_iters`` is traced, so those traces
        cover every trip count).  Returns the engine's stats (trace
        count, per-bucket calls, hit ratio) and records them in
        ``last_info["engine_stats"]``.  A no-op ``{}`` on backends with
        nothing to warm (sim/interp execute eagerly).
        """
        be = self._resolve(backend)
        if not hasattr(be, "warmup"):
            return {}
        kw = self._backend_kwargs(be)
        stats = be.warmup(self.program, self.map_result, buckets=buckets,
                          **kw)
        self.last_info = {"engine_stats": stats, "warmed": True}
        return stats

    def run(self, arrays: Optional[Dict[str, np.ndarray]] = None,
            n_iters: Optional[int] = None, *,
            backend: Optional[str] = None,
            **named: np.ndarray) -> Dict[str, np.ndarray]:
        """Execute with named input arrays; returns all named arrays after
        the run (outputs updated, inputs passed through).

        Arrays go in the ``arrays`` dict or as keyword arguments; use the
        dict form when an array name collides with a parameter name here
        (``arrays``/``n_iters``/``backend``).
        """
        mem = dict(arrays or {})
        mem.update(named)
        n = n_iters if n_iters is not None else self.program.n_iters
        out, info = self._execute(mem, n, backend)
        self.last_info = info
        return out

    def run_batch(self, mems: Sequence[Dict[str, np.ndarray]],
                  n_iters: Optional[int] = None, *,
                  backend: Optional[str] = None,
                  stream: bool = False,
                  chunk: Optional[int] = None
                  ) -> List[Dict[str, np.ndarray]]:
        """Execute a batch of named-array dicts; natively batched on the
        ``sim`` and ``pallas`` backends (one engine sweep for the whole
        batch).  The call's wall time, batch size and throughput
        (``throughput_sps``, samples/s) are recorded in ``last_info``.

        ``stream=True`` runs the batch through the backend's streaming
        path instead (chunked double buffering on pallas); the results
        come back as one flat list but ``last_info`` carries the stream
        summary (``stream_chunks``, ``overlap_frac``).  Use
        ``run_stream`` to consume chunks as they drain.
        """
        outs, info = self.run_batch_with_info(mems, n_iters, backend=backend,
                                              stream=stream, chunk=chunk)
        self.last_info = info
        return outs

    def run_batch_with_info(self, mems: Sequence[Dict[str, np.ndarray]],
                            n_iters: Optional[int] = None, *,
                            backend: Optional[str] = None,
                            stream: bool = False,
                            chunk: Optional[int] = None,
                            **backend_opts: object
                            ) -> Tuple[List[Dict[str, np.ndarray]],
                                       Dict[str, object]]:
        """``run_batch`` for concurrent sharers of one Executable: returns
        ``(outputs, info)`` per call — wall time, batch size and
        ``throughput_sps`` — WITHOUT publishing through ``last_info``, so
        parallel callers (the execution service's workers, ``explore``
        pools) never read another call's numbers.  Extra keywords are
        forwarded to the backend (``device=`` for per-replica placement
        on backends advertising ``supports_device``).  ``stream=True``
        collects the backend's streaming path into one flat list and
        returns the stream summary as the info."""
        n = n_iters if n_iters is not None else self.program.n_iters
        if not stream:
            return self._execute_batch(mems, n, backend, **backend_opts)
        outs: List[Dict[str, np.ndarray]] = []
        gen = self._execute_stream(mems, n, backend, chunk=chunk,
                                   **backend_opts)
        while True:
            try:
                chunk_outs, _ = next(gen)
            except StopIteration as stop:
                return outs, dict(stop.value or {})
            outs.extend(chunk_outs)

    def run_stream(self, mems: Sequence[Dict[str, np.ndarray]],
                   n_iters: Optional[int] = None, *,
                   backend: Optional[str] = None,
                   chunk: Optional[int] = None):
        """Streaming execution: a generator yielding lists of output
        dicts chunk-by-chunk as results drain from the device, while
        later chunks are still uploading/computing (double buffering on
        the pallas backend — same bucket-ladder traces as ``run_batch``,
        zero new traces on a warm engine).

        ``chunk`` bounds samples per chunk (default: the engine's top
        warm bucket).  At exhaustion ``last_info`` holds the stream
        summary — ``stream_chunks``, ``overlap_frac`` (fraction of wall
        time the host was NOT blocked waiting on the device),
        ``throughput_sps`` — and the same dict is the generator's return
        value for callers that drive ``next()`` manually."""
        n = n_iters if n_iters is not None else self.program.n_iters
        gen = self._execute_stream(mems, n, backend, chunk=chunk)
        while True:
            try:
                outs, _ = next(gen)
            except StopIteration as stop:
                info = dict(stop.value or {})
                self.last_info = info
                return info
            yield outs

    # -- validation -----------------------------------------------------------
    def validate(self, seed: int = 0, n_iters: Optional[int] = None,
                 make_mem=None, backends: Optional[Sequence[str]] = None,
                 n_vectors: int = 1):
        """Random test vectors -> oracle vs backend(s), bit-exact.

        Generates ``n_vectors`` input sets (the Program's ``make_mem`` or
        uniform random), runs the DFG-interpreter oracle on each, then
        every requested backend as ONE natively-batched sweep over the
        shared lowered artifact — not ``n_vectors`` scalar runs — and
        counts word mismatches over the declared output arrays.
        """
        from repro.core.dfg import interpret
        from repro.core.validate import ValidationReport

        if not self.success:
            return ValidationReport(self.program.name, self.target.fabric.name,
                                    self.map_result, False,
                                    n_iters or self.program.n_iters)
        n = n_iters if n_iters is not None else self.program.n_iters
        rng = np.random.default_rng(seed)
        gen = make_mem if make_mem is not None else self.program.random_inputs
        mems_in = [dict(gen(rng)) for _ in range(n_vectors)]
        expects = [interpret(self.program.dfg, m, n) for m in mems_in]

        names = backends if backends is not None else (self.target.backend,)
        if "interp" in names:
            raise ValueError(
                "validate(): 'interp' IS the validation oracle — comparing "
                "it against itself always passes; validate a device backend "
                "instead, e.g. backends=('sim',) or ('sim', 'pallas')")
        mism = 0
        sim_stats = None
        per_backend: Dict[str, bool] = {}
        # the (B, total_words) image is backend-independent: flatten the
        # test vectors ONCE and hand the image to every natively-batched
        # backend that advertises ``accepts_flats`` — a multi-backend
        # sweep over the same vectors pays one flatten, not len(names)
        flats = None
        for bname in names:
            opts: Dict[str, object] = {}
            if getattr(get_backend(bname), "accepts_flats", False):
                if flats is None:
                    flats = self.program.flatten_batch(mems_in)
                opts["flats"] = flats
            gots, info = self._execute_batch(mems_in, n, bname, **opts)
            bad = sum(int((expect[a] != got[a]).sum())
                      for expect, got in zip(expects, gots)
                      for a in self.program.outputs)
            per_backend[bname] = bad == 0
            mism += bad
            if "sim_stats" in info:
                sim_stats = info["sim_stats"]
        return ValidationReport(self.program.name, self.target.fabric.name,
                                self.map_result, mism == 0, n, sim_stats,
                                mism, backend_results=per_backend,
                                n_vectors=n_vectors)

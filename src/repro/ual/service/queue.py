"""Admission layer of the execution service: requests, futures, the queue.

A ``Request`` is one single-sample execution wish: a ``Program``, a
``Target``, the named input arrays, and admission metadata (tenant,
submit time, absolute deadline).  Requests are grouped by ``Request.key``
— ``(program.digest, target.digest, backend, n_iters)`` — the exact
compatibility class that can ride one ``run_batch`` sweep: same lowered
artifact, same backend, same trip count.

The caller gets a ``Response`` back immediately: a minimal Future —
``result(timeout)`` blocks for the outputs, ``done()``/``exception()``
inspect without blocking, and admission-control verdicts surface as
``ServiceRejected`` (``response.rejected`` / ``response.reason``) so an
overloaded or expired request is a *value*, not a lost thread.

``AdmissionQueue`` is the thread-safe FIFO between ``submit()`` and the
dispatcher.  It is deliberately unbounded here — the *service* enforces
the bound by counting in-flight requests and rejecting at submit time
(``queue-full``), which keeps the overload contract in one place instead
of splitting it between two queues.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.ual.program import Program
from repro.ual.target import Target


class ServiceRejected(RuntimeError):
    """The service declined a request; ``reason`` says why.

    Raised out of ``Response.result()`` for admission-control verdicts:
    ``queue-full`` (backpressure), ``deadline-exceeded`` (the request
    aged out before execution), ``compile-failed`` (its key cannot map),
    ``verifier-error`` (its key maps but the lowered config fails static
    verification — the detail carries the ``CheckReport`` summary),
    ``shutdown`` (the service stopped with the request still queued).
    """

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"{reason}: {detail}" if detail else reason)
        self.reason = reason


class Response:
    """Future-style handle for one submitted request.

    ``result(timeout)`` blocks until the micro-batch carrying the request
    has executed, then returns the named output arrays (same shape as
    ``Executable.run``) or raises the failure.  ``info`` carries per-call
    execution metadata once done (``latency_ms``, ``batch`` — the
    achieved micro-batch size, ``throughput_sps`` of the sweep).
    """

    __slots__ = ("_event", "_out", "_exc", "info", "_cb_lock", "_callbacks")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._out: Optional[Dict[str, np.ndarray]] = None
        self._exc: Optional[BaseException] = None
        self.info: Dict[str, object] = {}
        self._cb_lock = threading.Lock()
        self._callbacks: List = []

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def rejected(self) -> bool:
        """Whether admission control declined this request (vs. a normal
        completion or an execution error)."""
        return isinstance(self._exc, ServiceRejected)

    @property
    def reason(self) -> Optional[str]:
        """The rejection reason, or None for accepted requests."""
        return self._exc.reason if self.rejected else None

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError("response not ready")
        return self._exc

    def result(self, timeout: Optional[float] = None
               ) -> Dict[str, np.ndarray]:
        if not self._event.wait(timeout):
            raise TimeoutError("response not ready")
        if self._exc is not None:
            raise self._exc
        return self._out

    def add_done_callback(self, fn) -> None:
        """Run ``fn(self)`` once this response resolves — immediately if
        it already has.  Callbacks fire on the resolving thread (or the
        caller's, for an already-done response), so keep them short; the
        cluster front-end's workers use this to forward results without
        one blocked thread per in-flight request.  Registration and
        resolution are serialized under a lock, so a callback is invoked
        exactly once however the two race."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    # -- resolution (service-side) -------------------------------------------
    def _resolve(self, out: Optional[Dict[str, np.ndarray]] = None,
                 exc: Optional[BaseException] = None,
                 **info: object) -> None:
        self.info.update(info)
        self._out = out
        self._exc = exc
        with self._cb_lock:
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)


@dataclass
class Request:
    """One admitted single-sample request, en route to a micro-batch."""

    tenant: str
    program: Program
    target: Target
    mem: Dict[str, np.ndarray]
    n_iters: int
    t_submit: float                       # perf_counter at admission
    deadline: Optional[float] = None      # absolute perf_counter, or None
    response: Response = field(default_factory=Response)

    @property
    def key(self) -> Tuple[str, str, str, int]:
        """The batching compatibility class: requests sharing this key
        execute on one lowered artifact in one ``run_batch`` sweep."""
        return (self.program.digest, self.target.digest,
                self.target.backend, self.n_iters)

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


class AdmissionQueue:
    """Thread-safe FIFO between ``submit()`` and the dispatcher.

    ``get(timeout)`` returns None on timeout so the dispatcher can wake
    to flush aged micro-batches even when no new requests arrive.
    """

    def __init__(self) -> None:
        self._dq: deque = deque()
        self._cond = threading.Condition()

    def put(self, item: object) -> None:
        with self._cond:
            self._dq.append(item)
            self._cond.notify()

    def get(self, timeout: Optional[float] = None) -> Optional[object]:
        with self._cond:
            if timeout is None:
                while not self._dq:
                    self._cond.wait()
                return self._dq.popleft()
            deadline = time.perf_counter() + timeout
            while not self._dq:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    return None
                self._cond.wait(remaining)
            return self._dq.popleft()

    def drain(self) -> List[object]:
        """Non-blocking: everything currently queued, FIFO order."""
        with self._cond:
            items = list(self._dq)
            self._dq.clear()
            return items

    def __len__(self) -> int:
        with self._cond:
            return len(self._dq)

"""Admission layer of the execution service: requests, futures, the queue.

A ``Request`` is one single-sample execution wish: a ``Program``, a
``Target``, the named input arrays, and admission metadata (tenant,
submit time, absolute deadline).  Requests are grouped by ``Request.key``
— ``(program.digest, target.digest, backend, n_iters)`` — the exact
compatibility class that can ride one ``run_batch`` sweep: same lowered
artifact, same backend, same trip count.

The caller gets a ``Response`` back immediately: a minimal Future —
``result(timeout)`` blocks for the outputs, ``done()``/``exception()``
inspect without blocking, and admission-control verdicts surface as
``ServiceRejected`` (``response.rejected`` / ``response.reason``) so an
overloaded or expired request is a *value*, not a lost thread.

``StreamResponse`` is the handle for ``submit_stream``: one chunked
request pipelined through a warm trace — member ``Response`` futures per
sample, ``chunks()`` for streaming consumption, and an aggregated stream
``info`` (overlap, chunks, throughput).

``AdmissionQueue`` is the thread-safe FIFO between ``submit()`` and the
dispatcher.  It is deliberately unbounded here — the *service* enforces
the bound by counting in-flight requests and rejecting at submit time
(``queue-full``), which keeps the overload contract in one place instead
of splitting it between two queues.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.ual.program import Program
from repro.ual.target import Target


class ServiceRejected(RuntimeError):
    """The service declined a request; ``reason`` says why.

    Raised out of ``Response.result()`` for admission-control verdicts:
    ``queue-full`` (backpressure), ``deadline-exceeded`` (the request
    aged out before execution), ``compile-failed`` (its key cannot map),
    ``verifier-error`` (its key maps but the lowered config fails static
    verification — the detail carries the ``CheckReport`` summary),
    ``shutdown`` (the service stopped with the request still queued).
    """

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"{reason}: {detail}" if detail else reason)
        self.reason = reason


class Response:
    """Future-style handle for one submitted request.

    ``result(timeout)`` blocks until the micro-batch carrying the request
    has executed, then returns the named output arrays (same shape as
    ``Executable.run``) or raises the failure.  ``info`` carries per-call
    execution metadata once done (``latency_ms``, ``batch`` — the
    achieved micro-batch size, ``throughput_sps`` of the sweep).
    """

    __slots__ = ("_event", "_out", "_exc", "info", "_cb_lock", "_callbacks")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._out: Optional[Dict[str, np.ndarray]] = None
        self._exc: Optional[BaseException] = None
        self.info: Dict[str, object] = {}
        self._cb_lock = threading.Lock()
        self._callbacks: List = []

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def rejected(self) -> bool:
        """Whether admission control declined this request (vs. a normal
        completion or an execution error)."""
        return isinstance(self._exc, ServiceRejected)

    @property
    def reason(self) -> Optional[str]:
        """The rejection reason, or None for accepted requests."""
        return self._exc.reason if self.rejected else None

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError("response not ready")
        return self._exc

    def result(self, timeout: Optional[float] = None
               ) -> Dict[str, np.ndarray]:
        if not self._event.wait(timeout):
            raise TimeoutError("response not ready")
        if self._exc is not None:
            raise self._exc
        return self._out

    def add_done_callback(self, fn) -> None:
        """Run ``fn(self)`` once this response resolves — immediately if
        it already has.  Callbacks fire on the resolving thread (or the
        caller's, for an already-done response), so keep them short; the
        cluster front-end's workers use this to forward results without
        one blocked thread per in-flight request.  Registration and
        resolution are serialized under a lock, so a callback is invoked
        exactly once however the two race."""
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    # -- resolution (service-side) -------------------------------------------
    def _resolve(self, out: Optional[Dict[str, np.ndarray]] = None,
                 exc: Optional[BaseException] = None,
                 **info: object) -> None:
        self.info.update(info)
        self._out = out
        self._exc = exc
        with self._cb_lock:
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)


class StreamResponse:
    """Handle for one ``Service.submit_stream`` call: a chunked request
    pipelined through a single warm trace.

    Wraps one member ``Response`` per sample.  ``chunks()`` yields lists
    of named-output dicts chunk-by-chunk as they drain from the engine
    (earlier chunks are consumable while later ones still compute);
    ``results()`` blocks for the flat list.  Admission verdicts surface
    exactly like ``Response``: ``rejected`` / ``reason`` report the first
    rejection among the members (all-or-nothing at submit time, per-
    request ``deadline-exceeded`` afterwards).

    ``info`` aggregates the executed spans' stream summaries —
    ``stream_chunks``, ``samples``, ``overlap_frac`` (wall-weighted),
    ``throughput_sps`` — and grows as spans finish; read it after
    ``results()`` for the final numbers.
    """

    __slots__ = ("_responses", "chunk", "_lock", "_spans")

    def __init__(self, responses: List[Response], chunk: int) -> None:
        self._responses = list(responses)
        self.chunk = max(1, int(chunk))
        self._lock = threading.Lock()
        self._spans: List[Dict[str, object]] = []

    def __len__(self) -> int:
        return len(self._responses)

    @property
    def responses(self) -> List[Response]:
        """The member futures, submission order (one per sample)."""
        return list(self._responses)

    def done(self) -> bool:
        return all(r.done() for r in self._responses)

    @property
    def rejected(self) -> bool:
        return any(r.rejected for r in self._responses)

    @property
    def reason(self) -> Optional[str]:
        for r in self._responses:
            if r.rejected:
                return r.reason
        return None

    def chunks(self, timeout: Optional[float] = None):
        """Yield ``chunk``-sized lists of output dicts as they resolve,
        submission order — the streaming consumption loop."""
        group: List[Response] = []
        for r in self._responses:
            group.append(r)
            if len(group) >= self.chunk:
                yield [g.result(timeout) for g in group]
                group = []
        if group:
            yield [g.result(timeout) for g in group]

    def results(self, timeout: Optional[float] = None
                ) -> List[Dict[str, np.ndarray]]:
        """Block for every sample; the flat list, submission order."""
        return [r.result(timeout) for r in self._responses]

    # -- service-side ---------------------------------------------------------
    def _merge_span(self, summary: Dict[str, object]) -> None:
        """Record one executed span's stream summary (worker thread)."""
        with self._lock:
            self._spans.append(dict(summary))

    @property
    def info(self) -> Dict[str, object]:
        """Aggregate stream summary over the spans executed so far."""
        with self._lock:
            spans = list(self._spans)
        n_chunks = sum(int(s.get("stream_chunks", 0)) for s in spans)
        samples = sum(int(s.get("batch", s.get("samples", 0)))
                      for s in spans)
        wall = sum(float(s.get("wall_s", 0.0)) for s in spans)
        weighted = [(float(s["overlap_frac"]), float(s.get("wall_s", 0.0)))
                    for s in spans if s.get("overlap_frac") is not None]
        wsum = sum(w for _, w in weighted)
        overlap = (round(sum(o * w for o, w in weighted) / wsum, 4)
                   if wsum > 0 else
                   (round(sum(o for o, _ in weighted) / len(weighted), 4)
                    if weighted else None))
        return {
            "spans": len(spans),
            "stream_chunks": n_chunks,
            "samples": samples,
            "wall_s": round(wall, 6),
            "overlap_frac": overlap,
            "throughput_sps": (round(samples / wall, 1) if wall > 0
                               else None),
        }


class RequestTrace:
    """Per-request trace stamps, attached to a ``Request`` only while the
    process tracer is enabled (``repro.obs``).

    A request crosses three threads (caller -> dispatcher -> worker), so
    its spans cannot nest as context managers; instead each stage stamps
    a raw ``perf_counter`` here and the worker materializes the span tree
    retrospectively at resolve time.  Stage boundaries:

        t_submit  admission (``Service.submit``)
        t_pulled  dispatcher pulled it off the admission FIFO
        t_emit    its micro-batch left the coalescer (flush/steal)
        t_exec0   worker started the engine sweep
        t_exec1   sweep done (outputs materialized)

    and the derived breakdown on ``fut.info["trace"]`` is
    ``queue_ms`` (submit -> pulled), ``coalesce_ms`` (pulled -> exec
    start: coalescer wait + batch-FIFO/dispatch wait), ``exec_ms``
    (sweep) and ``resolve_ms`` (sweep end -> future resolved), so
    queue + coalesce + exec sums to the end-to-end latency exactly.
    """

    __slots__ = ("trace_id", "t_submit", "t_pulled", "t_emit",
                 "t_exec0", "t_exec1", "exec_args")

    def __init__(self, trace_id: str, t_submit: float) -> None:
        self.trace_id = trace_id
        self.t_submit = t_submit
        self.t_pulled: Optional[float] = None
        self.t_emit: Optional[float] = None
        self.t_exec0: Optional[float] = None
        self.t_exec1: Optional[float] = None
        self.exec_args: Dict[str, object] = {}


@dataclass
class Request:
    """One admitted single-sample request, en route to a micro-batch."""

    tenant: str
    program: Program
    target: Target
    mem: Dict[str, np.ndarray]
    n_iters: int
    t_submit: float                       # perf_counter at admission
    deadline: Optional[float] = None      # absolute perf_counter, or None
    response: Response = field(default_factory=Response)
    trace: Optional[RequestTrace] = None  # set only while tracing is on

    @property
    def key(self) -> Tuple[str, str, str, int]:
        """The batching compatibility class: requests sharing this key
        execute on one lowered artifact in one ``run_batch`` sweep."""
        return (self.program.digest, self.target.digest,
                self.target.backend, self.n_iters)

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


class AdmissionQueue:
    """Thread-safe FIFO between ``submit()`` and the dispatcher.

    ``get(timeout)`` returns None on timeout so the dispatcher can wake
    to flush aged micro-batches even when no new requests arrive.
    """

    def __init__(self) -> None:
        self._dq: deque = deque()
        self._cond = threading.Condition()

    def put(self, item: object) -> None:
        with self._cond:
            self._dq.append(item)
            self._cond.notify()

    def get(self, timeout: Optional[float] = None) -> Optional[object]:
        with self._cond:
            if timeout is None:
                while not self._dq:
                    self._cond.wait()
                return self._dq.popleft()
            deadline = time.perf_counter() + timeout
            while not self._dq:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    return None
                self._cond.wait(remaining)
            return self._dq.popleft()

    def drain(self) -> List[object]:
        """Non-blocking: everything currently queued, FIFO order."""
        with self._cond:
            items = list(self._dq)
            self._dq.clear()
            return items

    def __len__(self) -> int:
        with self._cond:
            return len(self._dq)

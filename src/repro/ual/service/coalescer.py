"""The coalescer: single-sample requests -> flush-ready micro-batches.

Requests are bucketed by ``Request.key`` (program digest, target digest,
backend, trip count — the class that shares one lowered artifact).  A
bucket flushes on whichever comes first:

  * **size** — it reaches ``max_batch`` (returned directly from
    ``offer``, so a hot tenant never waits on the clock),
  * **age** — its *oldest* request has waited ``max_wait_s``
    (``pop_expired``), bounding the latency a lone request pays for the
    chance of company, or
  * **deadline** — a member's deadline arrives: the bucket flushes so
    the scheduler can issue the ``deadline-exceeded`` verdict (and run
    the still-live members) *at* the deadline, not at the next age
    flush — rejection latency stays bounded by the deadline itself.

``next_deadline`` tells the dispatcher how long it may sleep before some
bucket comes due — the queue->coalesce->sweep loop polls nothing.

The coalescer is owned by the single dispatcher thread; it is not
thread-safe and needs no lock.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.ual.service.queue import Request

Key = Tuple[str, str, str, int]


class Coalescer:
    def __init__(self, max_batch: int, max_wait_s: float) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._groups: Dict[Key, List[Request]] = {}

    def _due(self, group: List[Request]) -> float:
        """Absolute time this bucket must flush: its age limit, pulled
        earlier by the tightest member deadline."""
        due = group[0].t_submit + self.max_wait_s
        for req in group:
            if req.deadline is not None and req.deadline < due:
                due = req.deadline
        return due

    def offer(self, req: Request) -> Optional[List[Request]]:
        """Add a request to its bucket; return the bucket when it just
        filled to ``max_batch`` (the caller dispatches it), else None."""
        group = self._groups.setdefault(req.key, [])
        group.append(req)
        if len(group) >= self.max_batch:
            del self._groups[req.key]
            return group
        return None

    def pop_expired(self, now: float) -> List[List[Request]]:
        """Buckets that have come due (aged out, or a member deadline)."""
        out = []
        for key in list(self._groups):
            group = self._groups[key]
            if now >= self._due(group):
                out.append(group)
                del self._groups[key]
        return out

    def steal_oldest(self, now: float,
                     min_age_s: float = 0.0) -> Optional[List[Request]]:
        """Pop the earliest-due partial bucket whose oldest member has
        aged at least ``min_age_s`` — the dispatcher calls this when a
        replica is IDLE (``Router.idle_slots``): a waiting bucket trades
        its remaining chance of company for immediate execution on
        capacity that would otherwise do nothing.  ``min_age_s`` damps
        thrash: a brand-new bucket under a briefly-idle pool still gets
        a moment to coalesce.  Returns None when nothing qualifies."""
        best_key = None
        best_due = None
        for key, group in self._groups.items():
            if now - group[0].t_submit < min_age_s:
                continue
            due = self._due(group)
            if best_due is None or due < best_due:
                best_key, best_due = key, due
        if best_key is None:
            return None
        return self._groups.pop(best_key)

    def flush_all(self) -> List[List[Request]]:
        """Everything pending, regardless of size or age (shutdown)."""
        out = list(self._groups.values())
        self._groups.clear()
        return out

    def next_deadline(self, now: float) -> Optional[float]:
        """Seconds until the earliest bucket comes due (may be <= 0 when
        one already is), or None when nothing is pending."""
        if not self._groups:
            return None
        return min(self._due(g) for g in self._groups.values()) - now

    def pending(self) -> int:
        return sum(len(g) for g in self._groups.values())

"""``repro.ual.service`` — the dynamic-batching CGRA execution service.

PR 3 made ``Executable.run_batch`` 100x+ cheaper per sample than scalar
runs — but only for callers who hand-assemble a batch.  Real serving
traffic arrives one sample at a time, from many tenants, against many
kernels.  This package decouples request arrival from fabric execution
(the STRELA move, with Morpher's framing that the *platform* owns the
orchestration):

    queue -> coalesce -> batched sweep

  * ``queue``     — admission: ``Request``/``Response`` futures, the
    thread-safe FIFO, ``ServiceRejected`` for overload verdicts,
  * ``coalescer`` — compatibility buckets keyed on
    ``(program.digest, target.digest, backend, n_iters)``; flush on
    ``max_batch`` or ``max_wait_ms``, whichever first,
  * ``scheduler`` — ``Service`` itself: dispatcher + workers executing
    each micro-batch as ONE ``run_batch`` sweep on shared warm
    Executables (compiled through the mapping cache — a cold tenant pays
    one mapping + one lowering, service-wide),
  * ``metrics``   — the ``stats()`` surface: p50/p99 latency, achieved
    batch size, samples/s, queue depth, rejects by reason.

Bulk chunked traffic goes through ``Service.submit_stream`` — one
tenant's request pipelined through a single warm trace in bounded spans
(``StreamResponse``: per-sample futures, ``chunks()`` streaming
consumption, aggregated overlap info).

The public names re-exported at ``repro.ual`` are ``Service``,
``Response``, ``StreamResponse`` and ``ServiceRejected``.
"""
from repro.ual.service.coalescer import Coalescer
from repro.ual.service.metrics import ServiceMetrics
from repro.ual.service.queue import (AdmissionQueue, Request, Response,
                                     ServiceRejected, StreamResponse)
from repro.ual.service.scheduler import Service

__all__ = ["AdmissionQueue", "Coalescer", "Request", "Response", "Service",
           "ServiceMetrics", "ServiceRejected", "StreamResponse"]

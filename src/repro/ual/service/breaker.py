"""Per-class circuit breaker: degrade a failing backend, probe, restore.

The pallas execution engine is the fast path, but it is also the deep
end of the stack — a JIT/runtime regression, a poisoned device, or an
injected fault (``repro.ual.faults``) can make its sweeps fail while
the rest of the service is perfectly healthy.  Because every degradable
backend pair here executes the *same lowered artifact* bit-exactly
(``sim`` consumes the dense linked tables exactly like ``pallas``),
falling back trades throughput for availability without changing a
single output word.

States, per compatibility class (``Request.key``):

  * ``closed``    — primary backend; consecutive-failure counter runs.
  * ``open``      — ``threshold`` consecutive primary failures tripped
    the class; every sweep runs on the fallback until ``cooldown_s``
    has passed.
  * ``half-open`` — cooldown elapsed: exactly ONE probe sweep tries the
    primary again (concurrent sweeps stay on the fallback).  Success
    closes the class (restore); failure re-opens it for another
    cooldown.

The owning ``Service`` drives the protocol: ``plan()`` before a sweep
(which backend, is this the probe), ``record_failure`` /
``record_success`` after, ``record_degraded`` when a failed sweep was
re-run in place on the fallback.  ``stats()`` is the
``Service.stats()["breaker"]`` payload.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

#: default degradation map: primary backend -> bit-exact fallback
#: (both consume the shared lowered artifact, so survivors stay exact)
DEGRADABLE: Dict[str, str] = {"pallas": "sim", "pallas_sharded": "sim"}


class _ClassState:
    __slots__ = ("state", "consecutive", "trips", "restores",
                 "degraded_batches", "open_until", "probing")

    def __init__(self) -> None:
        self.state = "closed"
        self.consecutive = 0
        self.trips = 0
        self.restores = 0
        self.degraded_batches = 0
        self.open_until = 0.0
        self.probing = False


class CircuitBreaker:
    """Consecutive-failure breaker over the service's batch classes."""

    def __init__(self, threshold: int = 3, cooldown_s: float = 5.0,
                 fallbacks: Optional[Dict[str, str]] = None) -> None:
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.fallbacks = dict(DEGRADABLE if fallbacks is None else fallbacks)
        self._lock = threading.Lock()
        self._classes: Dict[tuple, _ClassState] = {}
        self.trips_total = 0
        self.degraded_total = 0

    def fallback_for(self, backend: str) -> Optional[str]:
        """The degradation target for ``backend`` (None: not degradable)."""
        return self.fallbacks.get(backend)

    def plan(self, key: tuple, backend: str,
             now: float) -> Tuple[Optional[str], bool]:
        """Pre-sweep decision for one batch of class ``key``.

        Returns ``(fallback_or_None, is_probe)``: None means run the
        primary backend (possibly as the half-open probe); a backend
        name means the class is degraded and the sweep must run there.
        """
        if backend not in self.fallbacks:
            return None, False
        with self._lock:
            st = self._classes.get(key)
            if st is None or st.state == "closed":
                return None, False
            if st.state == "open" and now >= st.open_until and not st.probing:
                st.state = "half-open"
                st.probing = True
                return None, True
            st.degraded_batches += 1
            self.degraded_total += 1
            return self.fallbacks[backend], False

    def record_success(self, key: tuple, probe: bool = False) -> bool:
        """A primary-backend sweep succeeded; True when a probe success
        just restored the class to ``closed``."""
        with self._lock:
            st = self._classes.get(key)
            if st is None:
                return False
            st.consecutive = 0
            if probe:
                st.state = "closed"
                st.probing = False
                st.restores += 1
                return True
            return False

    def record_failure(self, key: tuple, now: float,
                       probe: bool = False) -> bool:
        """A primary-backend sweep failed; True when this failure tripped
        (or re-opened) the class."""
        with self._lock:
            st = self._classes.setdefault(key, _ClassState())
            st.consecutive += 1
            if probe:
                # failed probe: straight back to open, fresh cooldown
                st.state = "open"
                st.open_until = now + self.cooldown_s
                st.probing = False
                return True
            if st.state == "closed" and st.consecutive >= self.threshold:
                st.state = "open"
                st.open_until = now + self.cooldown_s
                st.trips += 1
                self.trips_total += 1
                return True
            return False

    def record_degraded(self, key: tuple) -> None:
        """A failed primary sweep was re-run in place on the fallback."""
        with self._lock:
            st = self._classes.setdefault(key, _ClassState())
            st.degraded_batches += 1
            self.degraded_total += 1

    def state_of(self, key: tuple) -> str:
        with self._lock:
            st = self._classes.get(key)
            return st.state if st is not None else "closed"

    def stats(self) -> Dict[str, object]:
        """The ``Service.stats()["breaker"]`` payload: per-class state
        keyed by a short human-readable class tag, plus totals."""
        with self._lock:
            classes = {}
            for key, st in self._classes.items():
                tag = f"{key[2]}:{key[0][:8]}:{key[1][:8]}:n{key[3]}"
                classes[tag] = {
                    "state": st.state,
                    "consecutive_failures": st.consecutive,
                    "trips": st.trips,
                    "restores": st.restores,
                    "degraded_batches": st.degraded_batches,
                }
            return {
                "threshold": self.threshold,
                "cooldown_s": self.cooldown_s,
                "fallbacks": dict(self.fallbacks),
                "trips_total": self.trips_total,
                "degraded_batches_total": self.degraded_total,
                "classes": classes,
            }


__all__ = ("DEGRADABLE", "CircuitBreaker")

"""The service's metrics surface: what ``Service.stats()`` reports.

The recording API is unchanged (``record_batch``, ``record_completed``,
``record_reject``, ``record_error``, ``record_stream_span``,
``snapshot``) but the storage now lives in the process-wide metrics
registry (``repro.obs``): every instance claims a unique ``service``
namespace and registers typed instruments, so ``obs.registry().snapshot()``
shows this service alongside the engine cache, the mapping cache and the
cluster router in one JSON schema.  ``snapshot()`` *reads through* those
instruments and keeps its historical dict shape.

Latency and batch-size samples live in bounded histogram windows so a
long-running service reports recent behavior at constant memory;
counters (completed, samples, rejects by reason, per-tenant totals) are
cumulative.  ``snapshot()`` folds the samples into the serving numbers
that matter: p50/p99 request latency (submit -> resolve), achieved
micro-batch size (mean/max — *the* dynamic-batching health number: 1.0
means the coalescer buys nothing), samples/s two ways (wall-clock
service throughput since start, and engine throughput over sweep wall
time alone), queue depth, and rejects keyed by reason.

Mid-sweep batch errors are attributed per tenant: every tenant row
carries an ``"errors"`` key next to ``"completed"``/``"rejected"``
(``record_error`` takes the failed batch's tenant names, not a bare
count, so a multi-tenant batch failure shows up on every tenant it
actually hit).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, List, Optional

from repro import obs


class ServiceMetrics:
    def __init__(self, window: int = 4096,
                 registry: Optional[obs.MetricsRegistry] = None) -> None:
        reg = registry if registry is not None else obs.registry()
        ns = self._ns = reg.namespace("service")
        self.namespace = ns.prefix
        self._completed = ns.counter("completed")
        self._samples = ns.counter("samples")
        self._batches = ns.counter("batches")
        self._exec_wall = ns.counter("exec_wall_s")
        self._errors = ns.counter("errors")
        self._lat_ms = ns.histogram("latency_ms", window)
        self._batch_sizes = ns.histogram("batch_size", window)
        self._stream_spans = ns.counter("stream.spans")
        self._stream_chunks = ns.counter("stream.chunks")
        self._stream_samples = ns.counter("stream.samples")
        self._stream_wall = ns.counter("stream.wall_s")
        self._overlap = ns.histogram("stream.overlap_frac", window)
        # circuit-breaker activity (repro.ual.service.breaker): trips
        # land here so the registry view shows degradation cluster-wide
        self._breaker_trips = ns.counter("breaker.trips")
        self._degraded_samples = ns.counter("breaker.degraded_samples")
        # per-reason / per-tenant breakdowns stay plain dicts (dynamic
        # key sets; one lock, cheap updates)
        self._lock = threading.Lock()
        self.rejects: Dict[str, int] = {}
        self.tenants: Dict[str, Dict[str, int]] = {}
        self._t0 = time.perf_counter()

    def close(self) -> None:
        """Drop this instance's instruments from the registry (call on
        service shutdown so the registry never grows without bound).
        The instruments themselves stay usable — ``snapshot()`` after
        ``close()`` still works, it just no longer appears in the
        registry view."""
        self._ns.drop()

    def _tenant(self, tenant: str) -> Dict[str, int]:
        return self.tenants.setdefault(
            tenant, {"completed": 0, "rejected": 0, "errors": 0})

    def record_batch(self, size: int, wall_s: float) -> None:
        self._batches.inc()
        self._samples.inc(size)
        self._exec_wall.inc(wall_s)
        self._batch_sizes.observe(size)

    def record_completed(self, tenant: str, latency_s: float) -> None:
        self._completed.inc()
        self._lat_ms.observe(latency_s * 1e3)
        with self._lock:
            self._tenant(tenant)["completed"] += 1

    def record_reject(self, tenant: str, reason: str) -> None:
        with self._lock:
            self.rejects[reason] = self.rejects.get(reason, 0) + 1
            self._tenant(tenant)["rejected"] += 1

    def record_error(self, tenants: Iterable[str]) -> None:
        """One failed batch: ``tenants`` is the tenant name of every
        request that rode it (duplicates count — two failed requests from
        one tenant are two errors)."""
        tenants = list(tenants)
        self._errors.inc(len(tenants))
        with self._lock:
            for t in tenants:
                self._tenant(t)["errors"] += 1

    def record_breaker_trip(self) -> None:
        """The breaker tripped (or re-opened) one class."""
        self._breaker_trips.inc()

    def record_degraded(self, samples: int) -> None:
        """One sweep of ``samples`` requests executed on a fallback
        backend instead of its class's primary."""
        self._degraded_samples.inc(samples)

    def record_stream_span(self, chunks: int, samples: int, wall_s: float,
                           overlap: object = None) -> None:
        """One executed ``submit_stream`` span: its samples and engine
        time count toward the service-wide throughput numbers; the span
        itself is tracked separately (not in the micro-batch-size window
        — a pipelined span is not a coalesced batch)."""
        self._stream_spans.inc()
        self._stream_chunks.inc(chunks)
        self._stream_samples.inc(samples)
        self._stream_wall.inc(wall_s)
        self._samples.inc(samples)
        self._exec_wall.inc(wall_s)
        if overlap is not None:
            self._overlap.observe(float(overlap))

    # -- readout ------------------------------------------------------------
    @property
    def completed(self) -> int:
        return int(self._completed.value)

    @property
    def errors(self) -> int:
        return int(self._errors.value)

    def latency_window_ms(self) -> List[float]:
        """The raw bounded latency window (ms) — what a cluster worker
        ships upstream so the parent can merge *samples* into real
        cluster percentiles instead of taking a max of per-worker p99s."""
        return self._lat_ms.samples()

    def snapshot(self, queue_depth: int = 0) -> Dict[str, object]:
        lat = self._lat_ms.samples()
        sizes = self._batch_sizes.samples()
        overlap = self._overlap.samples()
        samples = self._samples.value
        exec_wall = self._exec_wall.value
        stream_samples = self._stream_samples.value
        stream_wall = self._stream_wall.value
        elapsed = time.perf_counter() - self._t0
        with self._lock:
            rejects = dict(self.rejects)
            tenants = {t: dict(c) for t, c in self.tenants.items()}
        p50 = obs.percentile(lat, 50)
        p99 = obs.percentile(lat, 99)
        return {
            "completed": int(self._completed.value),
            "rejected": sum(rejects.values()),
            "rejects": rejects,
            "errors": int(self._errors.value),
            "queue_depth": queue_depth,
            "batches": int(self._batches.value),
            "p50_ms": round(p50, 3) if p50 is not None else None,
            "p99_ms": round(p99, 3) if p99 is not None else None,
            "mean_batch": (round(sum(sizes) / len(sizes), 2)
                           if sizes else None),
            "max_batch": int(max(sizes)) if sizes else None,
            "samples_per_s": (round(samples / elapsed, 1)
                              if elapsed > 0 else 0.0),
            "exec_samples_per_s": (round(samples / exec_wall, 1)
                                   if exec_wall > 0 else 0.0),
            "uptime_s": round(elapsed, 3),
            "tenants": tenants,
            "stream": {
                "spans": int(self._stream_spans.value),
                "chunks": int(self._stream_chunks.value),
                "samples": int(stream_samples),
                "overlap_frac": (round(sum(overlap) / len(overlap), 4)
                                 if overlap else None),
                "samples_per_s": (round(stream_samples / stream_wall, 1)
                                  if stream_wall > 0 else 0.0),
            },
        }

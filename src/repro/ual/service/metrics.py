"""The service's metrics surface: what ``Service.stats()`` reports.

One lock-guarded accumulator records every request outcome and every
executed micro-batch.  Latency and batch-size samples live in bounded
windows (``deque(maxlen=...)``) so a long-running service reports recent
behavior at constant memory; counters (completed, samples, rejects by
reason, per-tenant totals) are cumulative.

``snapshot()`` folds the raw samples into the serving numbers that
matter: p50/p99 request latency (submit -> resolve), achieved micro-batch
size (mean/max — *the* dynamic-batching health number: 1.0 means the
coalescer buys nothing), samples/s two ways (wall-clock service
throughput since start, and engine throughput over sweep wall time
alone), queue depth, and rejects keyed by reason.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict

import numpy as np


class ServiceMetrics:
    def __init__(self, window: int = 4096) -> None:
        self._lock = threading.Lock()
        self._lat_s: deque = deque(maxlen=window)
        self._batch_sizes: deque = deque(maxlen=window)
        self._t0 = time.perf_counter()
        self.completed = 0          # requests resolved with outputs
        self.samples = 0            # == completed (one sample per request)
        self.batches = 0            # micro-batches executed
        self.exec_wall_s = 0.0      # engine time across all sweeps
        self.errors = 0             # requests whose batch raised mid-sweep
        self.rejects: Dict[str, int] = {}
        self.tenants: Dict[str, Dict[str, int]] = {}
        # streaming (submit_stream spans): cumulative counters plus a
        # bounded window of per-span overlap fractions
        self.stream_spans = 0
        self.stream_chunks = 0
        self.stream_samples = 0
        self.stream_wall_s = 0.0
        self._overlap: deque = deque(maxlen=window)

    def _tenant(self, tenant: str) -> Dict[str, int]:
        return self.tenants.setdefault(tenant,
                                       {"completed": 0, "rejected": 0})

    def record_batch(self, size: int, wall_s: float) -> None:
        with self._lock:
            self.batches += 1
            self.samples += size
            self.exec_wall_s += wall_s
            self._batch_sizes.append(size)

    def record_completed(self, tenant: str, latency_s: float) -> None:
        with self._lock:
            self.completed += 1
            self._lat_s.append(latency_s)
            self._tenant(tenant)["completed"] += 1

    def record_reject(self, tenant: str, reason: str) -> None:
        with self._lock:
            self.rejects[reason] = self.rejects.get(reason, 0) + 1
            self._tenant(tenant)["rejected"] += 1

    def record_error(self, n_requests: int) -> None:
        with self._lock:
            self.errors += n_requests

    def record_stream_span(self, chunks: int, samples: int, wall_s: float,
                           overlap: object = None) -> None:
        """One executed ``submit_stream`` span: its samples and engine
        time count toward the service-wide throughput numbers; the span
        itself is tracked separately (not in the micro-batch-size window
        — a pipelined span is not a coalesced batch)."""
        with self._lock:
            self.stream_spans += 1
            self.stream_chunks += chunks
            self.stream_samples += samples
            self.stream_wall_s += wall_s
            self.samples += samples
            self.exec_wall_s += wall_s
            if overlap is not None:
                self._overlap.append(float(overlap))

    def snapshot(self, queue_depth: int = 0) -> Dict[str, object]:
        with self._lock:
            lat = np.asarray(self._lat_s, dtype=np.float64)
            sizes = np.asarray(self._batch_sizes, dtype=np.float64)
            elapsed = time.perf_counter() - self._t0
            return {
                "completed": self.completed,
                "rejected": sum(self.rejects.values()),
                "rejects": dict(self.rejects),
                "errors": self.errors,
                "queue_depth": queue_depth,
                "batches": self.batches,
                "p50_ms": (round(float(np.percentile(lat, 50)) * 1e3, 3)
                           if lat.size else None),
                "p99_ms": (round(float(np.percentile(lat, 99)) * 1e3, 3)
                           if lat.size else None),
                "mean_batch": (round(float(sizes.mean()), 2)
                               if sizes.size else None),
                "max_batch": int(sizes.max()) if sizes.size else None,
                "samples_per_s": (round(self.samples / elapsed, 1)
                                  if elapsed > 0 else 0.0),
                "exec_samples_per_s": (round(self.samples / self.exec_wall_s,
                                             1)
                                       if self.exec_wall_s > 0 else 0.0),
                "uptime_s": round(elapsed, 3),
                "tenants": {t: dict(c) for t, c in self.tenants.items()},
                "stream": {
                    "spans": self.stream_spans,
                    "chunks": self.stream_chunks,
                    "samples": self.stream_samples,
                    "overlap_frac": (round(float(np.mean(self._overlap)), 4)
                                     if self._overlap else None),
                    "samples_per_s": (round(self.stream_samples
                                            / self.stream_wall_s, 1)
                                      if self.stream_wall_s > 0 else 0.0),
                },
            }

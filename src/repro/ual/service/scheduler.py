"""``Service`` — queue -> coalesce -> batched sweep.

The serving layer the ROADMAP's north star asks for: callers submit
*single-sample* requests and the platform — not each user — assembles the
micro-batches that saturate the vectorized engines.  Three thread roles
share the work:

  * **submit()** (caller threads) — admission control: bound the
    in-flight count (``queue-full`` rejection beats unbounded memory),
    stamp tenant + deadline, hand a ``Response`` future back,
  * **dispatcher** (one thread) — pull admitted requests into the
    ``Coalescer``; dispatch a micro-batch when a compatibility bucket
    fills to ``max_batch`` or its oldest request has waited
    ``max_wait_ms``, whichever first,
  * **workers** (``workers`` threads) — resolve the batch's shared warm
    ``Executable`` (compiled through the mapping cache: a cold tenant
    pays one mapping + one lowering, every later request rides the
    artifact), drop requests whose deadline passed, run ONE
    ``run_batch`` sweep, resolve every future.

Executables are shared across workers — safe because execution info is
returned per call (``Executable.run_batch_with_info``), never read back
through ``last_info``.  ``stats()`` is the observability surface:
p50/p99 latency, achieved batch size, samples/s, queue depth, rejects by
reason, plus the mapping cache's aggregate view.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.analysis.verifier import VerifyError
from repro.ual import faults
from repro.ual.backends import get_backend
from repro.ual.cache import MappingCache, default_cache
from repro.ual.compiler import compile as ual_compile
from repro.ual.engine import default_engine
from repro.ual.executable import Executable
from repro.ual.program import Program
from repro.ual.service.breaker import CircuitBreaker
from repro.ual.service.coalescer import Coalescer
from repro.ual.service.metrics import ServiceMetrics
from repro.ual.service.queue import (AdmissionQueue, Request, RequestTrace,
                                     Response, ServiceRejected,
                                     StreamResponse)
from repro.ual.target import Target

_STOP = object()


class _StreamSpan:
    """A bounded run of one stream's chunks, riding the admission FIFO as
    a single item.  Spans are the anti-monopolization unit: a long
    ``submit_stream`` request is cut into spans of at most ``span``
    chunks, so other tenants' micro-batches interleave between them in
    FIFO order instead of waiting out the whole stream."""

    __slots__ = ("requests", "chunk", "stream")

    def __init__(self, requests: List[Request], chunk: int,
                 stream: StreamResponse) -> None:
        self.requests = requests
        self.chunk = chunk
        self.stream = stream

    @property
    def key(self):
        return self.requests[0].key

#: dispatcher wake-up period while the coalescer is empty (no deadline to
#: honor — this only bounds how fast a shutdown sentinel is noticed)
_IDLE_TICK_S = 0.05


class Service:
    """Dynamic-batching execution service over the UAL.

        svc = ual.Service(max_batch=32, max_wait_ms=5)
        fut = svc.submit(program, target, A=a, B=b, tenant="gemm-app")
        out = fut.result(timeout=30)      # named arrays, like exe.run
        print(svc.stats())                # p50/p99, batch size, samples/s

        sr = svc.submit_stream(program, target, mems, tenant="bulk")
        for outs in sr.chunks(timeout=30):    # chunks drain while later
            consume(outs)                     # ones still compute
        sr.info["overlap_frac"]           # aggregated stream summary
        svc.shutdown()

    ``submit_stream`` is the bulk path: one tenant's chunked request
    pipelined through a single warm trace (the engine's double-buffered
    streaming mode), cut into bounded *spans* that interleave with other
    tenants' micro-batches in the admission FIFO — streaming throughput
    without coalescer monopolization.  Stream activity is reported under
    ``stats()["stream"]``.

    ``max_queue`` bounds admitted-but-unexecuted requests: past it,
    ``submit`` returns an already-rejected future (``queue-full``)
    instead of growing memory.  Deadlines (per request, per tenant via
    ``deadlines_ms``, or service-wide via ``default_deadline_ms``) drop
    requests that aged out before execution (``deadline-exceeded``).

    **Graceful degradation**: micro-batches on degradable backends run
    under a per-class circuit breaker (``repro.ual.service.breaker``).
    After ``breaker_threshold`` consecutive primary-backend exec
    failures a class trips to its bit-exact fallback (``pallas`` ->
    ``sim``: both consume the same lowered artifact); a failed sweep is
    also retried in place on the fallback, so callers see degraded
    latency (``fut.info["degraded_to"]``), not errors.  After
    ``breaker_cooldown_s`` a single half-open probe tries the primary
    again and restores the class on success.  ``stats()["breaker"]``
    reports per-class state; ``breaker_threshold=0`` disables the
    breaker.

    **Replicated mode** (``replicas > 1`` or ``devices=...``): worker
    threads become ``ReplicaSlot``s behind a ``Router``
    (``repro.ual.cluster.replica``) — flush-ready micro-batches go to
    the least-loaded slot (class-affinity tiebreak), an idle slot steals
    the oldest batch from the most-loaded sibling, and the dispatcher
    additionally flushes a *partial* coalescer bucket early when a
    replica idles (after ``max_wait_ms / 4`` of bucket age — batching
    only pays while capacity is busy).  ``devices`` pins slot ``i`` to
    ``devices[i]``; backends advertising ``supports_device`` (pallas)
    then execute each slot's sweeps on its own device through
    device-pinned engines.  ``workers`` is superseded by ``replicas`` in
    this mode (one thread per slot).  ``stats()["router"]`` reports
    per-replica samples/s, routing decisions and steal counts.
    """

    def __init__(self, max_batch: int = 32, max_wait_ms: float = 2.0,
                 max_queue: int = 1024, workers: int = 1,
                 replicas: int = 1, devices: Optional[Sequence] = None,
                 cache: Optional[MappingCache] = None,
                 default_deadline_ms: Optional[float] = None,
                 deadlines_ms: Optional[Dict[str, float]] = None,
                 warmup_buckets: Optional[Sequence[int]] = None,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 5.0,
                 breaker_fallbacks: Optional[Dict[str, str]] = None,
                 start: bool = True) -> None:
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if devices is not None and replicas == 1:
            replicas = len(list(devices))
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.replicas = replicas
        self.default_deadline_ms = default_deadline_ms
        self.deadlines_ms = dict(deadlines_ms or {})
        self.warmup_buckets = warmup_buckets
        self._cache = cache
        #: per-class circuit breaker over degradable backends (pallas ->
        #: sim by default — same lowered artifact, bit-exact fallback);
        #: breaker_threshold=0 disables the breaker entirely
        self._breaker: Optional[CircuitBreaker] = (
            CircuitBreaker(breaker_threshold, breaker_cooldown_s,
                           breaker_fallbacks)
            if breaker_threshold > 0 else None)

        if replicas > 1 or devices is not None:
            from repro.ual.cluster.replica import Router
            self._router: Optional[object] = Router(replicas,
                                                    devices=devices)
            self.n_workers = replicas       # one thread per slot
        else:
            self._router = None
            self.n_workers = workers
        #: minimum bucket age before idle capacity may flush it early
        self._steal_age_s = (max_wait_ms / 1e3) * 0.25

        self._admission = AdmissionQueue()
        self._coalescer = Coalescer(max_batch, max_wait_ms / 1e3)
        self._batches = AdmissionQueue()
        self._metrics = ServiceMetrics()

        self._lock = threading.Lock()
        self._pending = 0            # admitted, not yet handed to a worker
        self._closed = False
        self._started = False
        self._exes: Dict[Tuple[str, str, str, int], Executable] = {}
        self._threads: List[threading.Thread] = []
        if start:
            self.start()

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "Service":
        # threads are created, started AND recorded under the lock:
        # a shutdown() racing this sees either no service at all or the
        # complete thread list, never a half-built one
        with self._lock:
            if self._started or self._closed:
                return self
            self._started = True
            d = threading.Thread(target=self._dispatch_loop,
                                 name="ual-service-dispatch", daemon=True)
            d.start()
            self._threads.append(d)
            for i in range(self.n_workers):
                w = threading.Thread(target=self._worker_loop, args=(i,),
                                     name=f"ual-service-worker-{i}",
                                     daemon=True)
                w.start()
                self._threads.append(w)
            if self._router is not None:
                # replicated mode: the router's per-replica stats join
                # the unified registry view next to this service's
                # instruments (dropped again on shutdown)
                obs.registry().register_source(
                    f"{self._metrics.namespace}.router",
                    self._router.stats, replace=True)
        return self

    def shutdown(self, timeout: Optional[float] = None) -> None:
        """Stop admitting, flush every pending micro-batch, join threads.

        Pending requests on a never-started service are rejected
        (``shutdown``) rather than left unresolved.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            started = self._started
        if not started:
            for item in self._admission.drain():
                reqs = (item.requests if isinstance(item, _StreamSpan)
                        else [item])
                with self._lock:
                    self._pending -= len(reqs)
                for req in reqs:
                    self._finish_rejected(req, "shutdown",
                                          "service stopped before execution")
            self._release_registry()
            return
        # the dispatcher enqueues the worker stop sentinels itself, after
        # its final flush — so flushed batches always precede the
        # sentinels in the batch FIFO even if this join times out early
        self._admission.put(_STOP)
        for t in self._threads:
            t.join(timeout)
        self._release_registry()

    def _release_registry(self) -> None:
        """Drop this service's instruments (and router source) from the
        process-wide registry — ``stats()`` keeps working afterwards, the
        registry just stops listing a dead service."""
        if self._router is not None:
            obs.registry().unregister_source(
                f"{self._metrics.namespace}.router")
        self._metrics.close()

    def __enter__(self) -> "Service":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- admission ------------------------------------------------------------
    def submit(self, program: Program, target: Target,
               mem: Optional[Dict[str, np.ndarray]] = None, *,
               n_iters: Optional[int] = None, tenant: str = "default",
               deadline_ms: Optional[float] = None,
               **named: np.ndarray) -> Response:
        """Admit one single-sample request; returns a ``Response`` future.

        Arrays go in ``mem`` or as keywords (like ``Executable.run``).
        Malformed arrays raise here, immediately — a typo is a caller
        bug, not an overload, and must not poison a micro-batch.
        Overload and shutdown come back as rejected futures.
        """
        arrays = dict(mem or {})
        arrays.update(named)
        program.check_arrays(arrays)
        now = time.perf_counter()
        dl_ms = deadline_ms
        if dl_ms is None:
            dl_ms = self.deadlines_ms.get(tenant, self.default_deadline_ms)
        req = Request(tenant=tenant, program=program, target=target,
                      mem=arrays, n_iters=(n_iters if n_iters is not None
                                           else program.n_iters),
                      t_submit=now,
                      deadline=(now + dl_ms / 1e3 if dl_ms is not None
                                else None))
        tr = obs.tracer()
        if tr.enabled:
            req.trace = RequestTrace(tr.new_trace_id(), now)
        with self._lock:
            if self._closed:
                return self._finish_rejected(req, "shutdown",
                                             "service is shut down")
            if self._pending >= self.max_queue:
                return self._finish_rejected(
                    req, "queue-full",
                    f"{self._pending} requests in flight "
                    f"(max_queue={self.max_queue})")
            self._pending += 1
            # enqueue under the lock: shutdown() sets _closed under this
            # same lock before it sends the dispatcher its stop sentinel,
            # so an admitted request always precedes the sentinel in the
            # FIFO and can never be stranded unresolved by a racing stop
            self._admission.put(req)
        return req.response

    def submit_stream(self, program: Program, target: Target,
                      mems: Sequence[Dict[str, np.ndarray]], *,
                      n_iters: Optional[int] = None,
                      tenant: str = "default",
                      chunk: Optional[int] = None, span: int = 4,
                      deadline_ms: Optional[float] = None
                      ) -> StreamResponse:
        """Admit one chunked request to be *pipelined* through a single
        warm trace; returns a ``StreamResponse`` whose ``chunks()``
        yields results as they drain from the engine.

        ``mems`` is a sequence of named-array dicts (one per sample).
        ``chunk`` bounds samples per pipelined chunk (default, and cap:
        ``max_batch`` — chunks ride the service's warm bucket traces, so
        streaming adds zero new traces).  ``span`` bounds consecutive
        chunks executed per dispatch (default 4): the stream is cut into
        spans that interleave with other tenants' micro-batches in the
        admission FIFO, so one long stream never monopolizes the
        coalescer.  Admission is all-or-nothing: if the whole stream
        does not fit under ``max_queue``, every member is rejected
        ``queue-full`` (a half-admitted stream helps nobody).

        In replicated-router mode chunks are routed as ordinary
        micro-batches (each replica pipelines within its own sweeps), so
        ``StreamResponse.info`` reports ``spans == 0`` there.
        """
        mems = [dict(m) for m in mems]
        for m in mems:
            program.check_arrays(m)
        if span < 1:
            raise ValueError(f"span must be >= 1, got {span}")
        step = self.max_batch if chunk is None else int(chunk)
        step = max(1, min(step, self.max_batch))
        now = time.perf_counter()
        dl_ms = deadline_ms
        if dl_ms is None:
            dl_ms = self.deadlines_ms.get(tenant, self.default_deadline_ms)
        deadline = now + dl_ms / 1e3 if dl_ms is not None else None
        n = n_iters if n_iters is not None else program.n_iters
        reqs = [Request(tenant=tenant, program=program, target=target,
                        mem=m, n_iters=n, t_submit=now, deadline=deadline)
                for m in mems]
        tr = obs.tracer()
        if tr.enabled and reqs:
            # one trace per stream; every member stamps into it so the
            # exported timeline shows the chunk pipeline end to end
            tid = tr.new_trace_id()
            for req in reqs:
                req.trace = RequestTrace(tid, now)
        sr = StreamResponse([r.response for r in reqs], step)
        if not reqs:
            return sr
        with self._lock:
            if self._closed:
                reject = ("shutdown", "service is shut down")
            elif self._pending + len(reqs) > self.max_queue:
                reject = ("queue-full",
                          f"stream of {len(reqs)} does not fit "
                          f"({self._pending} in flight, "
                          f"max_queue={self.max_queue})")
            else:
                reject = None
                self._pending += len(reqs)
                # spans enqueue under the lock for the same
                # shutdown-race reason as submit(); consecutive spans
                # are separate FIFO items, so concurrent submitters
                # interleave between them
                per_span = step * span
                for i in range(0, len(reqs), per_span):
                    self._admission.put(
                        _StreamSpan(reqs[i:i + per_span], step, sr))
        if reject is not None:
            for req in reqs:
                self._finish_rejected(req, *reject)
        return sr

    def _finish_rejected(self, req: Request, reason: str,
                         detail: str) -> Response:
        self._metrics.record_reject(req.tenant, reason)
        if req.trace is not None:
            t = req.trace
            obs.tracer().record(
                "request", t.t_submit, time.perf_counter(), cat="service",
                trace=t.trace_id,
                args={"tenant": req.tenant, "outcome": "rejected",
                      "reason": reason})
        req.response._resolve(exc=ServiceRejected(reason, detail))
        return req.response

    def _finish_trace(self, req: Request, now: float,
                      streamed: bool = False) -> Dict[str, object]:
        """Emit one completed request's span tree from its stamps (see
        ``RequestTrace``) and return the ``fut.info["trace"]`` breakdown.
        Called on the worker thread just before resolving, so
        ``resolve_ms`` covers metrics recording + tree emission and
        ``queue+coalesce+exec`` equals the reported latency exactly.
        The tree is handed to ``record_tree`` as raw tuples — ``Span``
        construction is deferred to the (cold) read side, keeping the
        per-request tracing cost a few microseconds."""
        t = req.trace
        tr = obs.tracer()
        pulled = t.t_pulled if t.t_pulled is not None else t.t_submit
        exec0 = t.t_exec0 if t.t_exec0 is not None else pulled
        exec1 = t.t_exec1 if t.t_exec1 is not None else now
        tid = t.trace_id
        items = (
            ("request", t.t_submit, now, "service",
             {"tenant": req.tenant, "program": req.program.name,
              "streamed": streamed}),
            ("queue", t.t_submit, pulled, "service", None),
            ("coalesce", pulled, exec0, "service", None),
            ("exec", exec0, exec1, "engine", t.exec_args),
            ("resolve", exec1, now, "service", None),
        )
        if t.t_emit is not None:
            # dispatch (batch FIFO / router wait) is the tail slice of
            # the coalesce window — shown as its own child span
            items += (("dispatch", t.t_emit, exec0, "service", None),)
        tr.record_tree(tid, items)
        return {
            "trace_id": tid,
            "queue_ms": round((pulled - t.t_submit) * 1e3, 3),
            "coalesce_ms": round((exec0 - pulled) * 1e3, 3),
            "exec_ms": round((exec1 - exec0) * 1e3, 3),
            "resolve_ms": round((now - exec1) * 1e3, 3),
        }

    # -- dispatcher -----------------------------------------------------------
    def _stamp_pulled(self, item: object) -> None:
        """Dispatcher-side trace stamp: the moment an item left the
        admission FIFO (start of its coalescer wait)."""
        if isinstance(item, _StreamSpan):
            reqs = item.requests
        elif isinstance(item, Request):
            reqs = (item,)
        else:
            return
        if reqs[0].trace is None:
            return
        now = time.perf_counter()
        for req in reqs:
            if req.trace is not None:
                req.trace.t_pulled = now

    def _emit(self, batch: List[Request], *, early: bool = False) -> None:
        """Hand one flush-ready micro-batch to the execution side: the
        shared FIFO in plain mode, the Router in replicated mode."""
        faults.dispatch_delay()      # no-op unless a fault plan is active
        if batch[0].trace is not None:
            now = time.perf_counter()
            for req in batch:
                if req.trace is not None:
                    req.trace.t_emit = now
        if self._router is None:
            self._batches.put(batch)
        else:
            self._router.route(batch[0].key, batch, early=early)

    def _emit_span(self, span: _StreamSpan) -> None:
        """Hand one stream span to the execution side.  Plain mode keeps
        the span whole — a worker pipelines its chunks through the
        engine's double-buffered path.  Router mode splits it into
        chunk-sized micro-batches routed like any other flush (each
        replica's sweeps pipeline internally; cross-chunk double
        buffering does not survive placement on different devices)."""
        if self._router is None:
            self._batches.put(span)
            return
        for i in range(0, len(span.requests), span.chunk):
            batch = span.requests[i:i + span.chunk]
            self._router.route(batch[0].key, batch)

    def _steal_for_idle(self, now: float) -> None:
        """Replicated mode: while there is strictly more idle capacity
        than routed-but-unclaimed work, flush the oldest sufficiently-
        aged partial bucket early — an idle replica beats a fuller
        batch (work stealing between coalescer buckets)."""
        while self._router.idle_slots() > self._router.queued():
            batch = self._coalescer.steal_oldest(now, self._steal_age_s)
            if batch is None:
                return
            self._emit(batch, early=True)

    def _dispatch_loop(self) -> None:
        while True:
            now = time.perf_counter()
            for batch in self._coalescer.pop_expired(now):
                self._emit(batch)
            if self._router is not None:
                self._steal_for_idle(time.perf_counter())
            wait = self._coalescer.next_deadline(time.perf_counter())
            timeout = _IDLE_TICK_S if wait is None else max(wait, 1e-4)
            if self._router is not None and wait is not None:
                # wake early enough to notice an idle replica while a
                # partial bucket is still young (steal granularity)
                timeout = max(min(timeout, max(self._steal_age_s / 2,
                                               1e-3)), 1e-4)
            item = self._admission.get(timeout=timeout)
            if item is _STOP:
                break
            self._stamp_pulled(item)
            if isinstance(item, _StreamSpan):
                self._emit_span(item)
            elif item is not None:
                full = self._coalescer.offer(item)
                if full is not None:
                    self._emit(full)
        # drain: late racers in admission, then every partial bucket
        for item in self._admission.drain():
            if item is _STOP:
                continue
            self._stamp_pulled(item)
            if isinstance(item, _StreamSpan):
                self._emit_span(item)
            else:
                full = self._coalescer.offer(item)
                if full is not None:
                    self._emit(full)
        for batch in self._coalescer.flush_all():
            self._emit(batch)
        if self._router is None:
            for _ in range(self.n_workers):
                self._batches.put(_STOP)
        else:
            self._router.stop()     # pulls drain the queues, then None

    # -- workers --------------------------------------------------------------
    def _worker_loop(self, index: int = 0) -> None:
        if self._router is None:
            while True:
                batch = self._batches.get()
                if batch is _STOP:
                    break
                if isinstance(batch, _StreamSpan):
                    self._run_stream_span(batch)
                else:
                    self._run_batch(batch)
            return
        slot = self._router.slots[index]
        while True:
            item = self._router.pull(index)
            if item is None:
                break
            _key, batch, _stolen = item
            t0 = time.perf_counter()
            n_live = self._run_batch(batch, slot=slot)
            self._router.done(index, n_live, time.perf_counter() - t0)

    def _executable(self, req: Request) -> Executable:
        """The shared warm Executable for a batch key, compiled through
        the mapping cache.  Workers racing on a cold key may each call
        ``compile``, but the cache's per-key compile lock collapses the
        expensive work to one mapping + one lowering (losers get a cache
        hit), so only the cheap Executable wrapper is ever duplicated.

        The first worker to install a tenant class's Executable also
        warms its execution engine (``Executable.warmup``): the pallas
        path pre-traces the batch-bucket ladder once, so the class's
        variable-sized micro-batches never retrace on the serving path.
        """
        key = req.key
        with self._lock:
            exe = self._exes.get(key)
        if exe is None:
            exe = ual_compile(req.program, req.target, cache=self._cache)
            with self._lock:
                installed = self._exes.setdefault(key, exe)
            if installed is exe and exe.success:
                try:
                    exe.warmup(self.warmup_buckets)
                except Exception:
                    pass     # warming is an optimization, never a failure
            exe = installed
        return exe

    def _prepare(self, batch: List[Request]
                 ) -> Tuple[List[Request], Optional[Executable]]:
        """Shared front half of batch and span execution: settle the
        pending count, reject aged-out members, resolve the shared warm
        Executable.  Returns ``(live, exe)``; ``exe`` is None when every
        member has already been resolved (expired / verifier-error /
        compile-failed / compile crash) and there is nothing to run."""
        with self._lock:
            self._pending -= len(batch)
        now = time.perf_counter()
        live = []
        for req in batch:
            if req.expired(now):
                self._finish_rejected(req, "deadline-exceeded",
                                      f"waited "
                                      f"{(now - req.t_submit) * 1e3:.1f}ms")
            else:
                live.append(req)
        if not live:
            return [], None
        try:
            exe = self._executable(live[0])
        except VerifyError as exc:
            # a config that fails static verification is a tenant
            # problem, not a worker crash: reject with the report's
            # one-line summary, keep the worker alive
            for req in live:
                self._finish_rejected(req, "verifier-error",
                                      exc.report.summary())
            return [], None
        except Exception as exc:     # resolve, don't kill the worker
            self._metrics.record_error([req.tenant for req in live])
            for req in live:
                req.response._resolve(exc=exc)
            return [], None
        if not exe.success:
            for req in live:
                self._finish_rejected(
                    req, "compile-failed",
                    f"{req.program.name} does not map onto "
                    f"{req.target.fabric.name}")
            return [], None
        return live, exe

    def _sweep(self, exe: Executable, live: List[Request], backend: str,
               slot=None) -> Tuple[List[Dict[str, np.ndarray]],
                                   Dict[str, object]]:
        """One engine sweep on an explicit backend — the unit the
        circuit breaker retries.  Device placement only rides along on
        backends that support it (a degraded sim sweep must not receive
        the pallas slot device).  The fault-injection hook sits inside
        the caller's ``try`` so an injected failure takes the exact
        path a real engine failure would."""
        kw: Dict[str, object] = {}
        if slot is not None and slot.device is not None:
            if getattr(get_backend(backend), "supports_device", False):
                kw["device"] = slot.device        # per-replica placement
        faults.check_exec(backend)
        return exe.run_batch_with_info(
            [req.mem for req in live], n_iters=live[0].n_iters,
            backend=backend, **kw)

    def _run_batch(self, batch: List[Request], slot=None) -> int:
        """Execute one micro-batch; returns how many requests actually
        rode the sweep (0 when every member was rejected first) so the
        router's per-replica sample counters stay honest.

        Degradable backends (``CircuitBreaker.fallbacks``) run under the
        breaker: an open class sweeps on its fallback outright, a failed
        primary sweep is retried in place on the fallback (the batch
        still resolves with bit-exact outputs — both backends consume
        the same lowered artifact), and only a fallback failure reaches
        the callers as an error."""
        live, exe = self._prepare(batch)
        if exe is None:
            return 0
        t_exec0 = time.perf_counter()
        primary = live[0].target.backend
        brk = self._breaker
        fb: Optional[str] = None
        probe = False
        if brk is not None:
            fb, probe = brk.plan(live[0].key, primary, t_exec0)
        degraded_to: Optional[str] = fb
        try:
            if fb is not None:
                outs, info = self._sweep(exe, live, fb, slot)
            else:
                try:
                    outs, info = self._sweep(exe, live, primary, slot)
                    if brk is not None:
                        brk.record_success(live[0].key, probe=probe)
                except Exception:
                    fallback = (brk.fallback_for(primary)
                                if brk is not None else None)
                    if fallback is None:
                        raise
                    if brk.record_failure(live[0].key, time.perf_counter(),
                                          probe=probe):
                        self._metrics.record_breaker_trip()
                    outs, info = self._sweep(exe, live, fallback, slot)
                    brk.record_degraded(live[0].key)
                    degraded_to = fallback
        except Exception as exc:     # resolve, don't kill the worker
            self._metrics.record_error([req.tenant for req in live])
            for req in live:
                req.response._resolve(exc=exc)
            return len(live)
        if degraded_to is not None:
            self._metrics.record_degraded(len(live))
            info["degraded_to"] = degraded_to
        done = time.perf_counter()
        self._metrics.record_batch(len(live), float(info.get("wall_s", 0.0)))
        sps = info.get("throughput_sps")
        traced = live[0].trace is not None
        if traced:
            exec_args = {k: info[k] for k in
                         ("buckets", "padded", "traced", "wall_s")
                         if k in info}
            exec_args["batch"] = len(live)
            for req in live:
                if req.trace is not None:
                    req.trace.t_exec0 = t_exec0
                    req.trace.t_exec1 = done
                    req.trace.exec_args = exec_args
        for req, out in zip(live, outs):
            latency = done - req.t_submit
            self._metrics.record_completed(req.tenant, latency)
            extra: Dict[str, object] = {}
            if degraded_to is not None:
                extra["degraded_to"] = degraded_to
            if req.trace is not None:
                extra["trace"] = self._finish_trace(req,
                                                    time.perf_counter())
            req.response._resolve(out, latency_ms=round(latency * 1e3, 3),
                                  batch=len(live), throughput_sps=sps,
                                  **extra)
        return len(live)

    def _run_stream_span(self, span: _StreamSpan) -> int:
        """Pipeline one stream span through the engine's double-buffered
        path, resolving each chunk's futures AS IT DRAINS — a consumer
        holding the ``StreamResponse`` sees chunk *i*'s results while
        chunk *i+1* is still computing."""
        live, exe = self._prepare(span.requests)
        if exe is None:
            return 0
        idx = 0
        n_chunks = 0
        t_exec0 = time.perf_counter()
        gen = exe._execute_stream([req.mem for req in live],
                                  live[0].n_iters, None, chunk=span.chunk)
        try:
            while True:
                try:
                    outs, cinfo = next(gen)
                except StopIteration as stop:
                    summary = dict(stop.value or {})
                    break
                done = time.perf_counter()
                members = live[idx:idx + len(outs)]
                idx += len(outs)
                n_chunks += 1
                for req, out in zip(members, outs):
                    latency = done - req.t_submit
                    self._metrics.record_completed(req.tenant, latency)
                    extra: Dict[str, object] = {}
                    if req.trace is not None:
                        req.trace.t_exec0 = t_exec0
                        req.trace.t_exec1 = done
                        req.trace.exec_args = {
                            "chunk": cinfo.get("chunk"),
                            "batch": len(outs), "stream": True}
                        extra["trace"] = self._finish_trace(
                            req, time.perf_counter(), streamed=True)
                    req.response._resolve(out,
                                          latency_ms=round(latency * 1e3, 3),
                                          batch=len(outs), stream=True,
                                          chunk=cinfo.get("chunk"),
                                          **extra)
        except Exception as exc:     # resolve the undrained tail
            self._metrics.record_error([req.tenant for req in live[idx:]])
            for req in live[idx:]:
                req.response._resolve(exc=exc)
            return idx
        self._metrics.record_stream_span(n_chunks, len(live),
                                         float(summary.get("wall_s", 0.0)),
                                         summary.get("overlap_frac"))
        span.stream._merge_span(summary)
        return len(live)

    # -- observability --------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """The serving numbers: p50/p99 latency (ms), achieved batch size
        (mean/max), samples/s, queue depth, rejects by reason, per-tenant
        totals, warm executable count, the mapping cache aggregate, and
        the JIT execution engine aggregate (trace count / hit ratio —
        the trace-once/run-many health of the pallas path)."""
        with self._lock:
            depth = self._pending
            n_exes = len(self._exes)
        snap = self._metrics.snapshot(queue_depth=depth)
        snap["executables"] = n_exes
        cache = self._cache if self._cache is not None else default_cache()
        snap["cache"] = cache.stats()
        snap["engine"] = default_engine().stats()
        if self._breaker is not None:
            snap["breaker"] = self._breaker.stats()
        if self._router is not None:
            snap["router"] = self._router.stats()
        return snap

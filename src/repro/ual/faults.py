"""Deterministic fault injection for the serving stack.

The self-healing layer (worker supervision, transparent retry, the
circuit breaker) is only trustworthy if its failure paths are *exercised
deterministically* — waiting for real crashes proves nothing.  This
module is the substrate: a seedable ``FaultPlan`` describing exactly
which faults fire and when, activated either in-process (``install``)
or via the environment (``REPRO_UAL_FAULTS``) so ``ClusterService``'s
spawned workers honor the plan too — the same propagation pattern as
``REPRO_TRACE``.

Fault vocabulary (``FaultSpec.kind``):

  * ``kill_worker``  — hard-exit (``os._exit``) the matching cluster
    worker process after ``after`` requests have been received there,
    exactly as a real crash would look to the parent's watchdog
    (no cleanup, no goodbye message, in-flight requests stranded).
  * ``exec_fault``   — raise ``InjectedFault`` inside the service
    worker's engine-sweep ``try`` block, ``count`` times after ``after``
    matching sweeps, optionally filtered to one ``backend`` — the lever
    that trips the circuit breaker on demand.
  * ``delay_dispatch`` — sleep ``delay_ms`` in the dispatcher before a
    micro-batch is emitted, ``count`` times (straggler emulation).
  * ``corrupt_cache`` — overwrite bytes of an on-disk artifact-cache
    entry under ``path`` when fired (torn-write emulation; see also
    ``corrupt_cache_entry`` for direct use from tests).

Counters are per-spec and advance in the worker's own serialized event
order, so a plan is deterministic per process regardless of thread
timing: "kill worker 0 after 6 requests" always kills on the 7th
request *received by worker 0*.  ``seed`` keys any future randomized
knobs; the built-in faults are fully counter-driven.

    plan = FaultPlan([FaultSpec("kill_worker", worker=0, after=6)])
    cs = ual.ClusterService(workers=2, worker_env=plan.to_env())

The hook entry points (``on_request`` / ``check_exec`` /
``dispatch_delay``) are no-ops costing one global read when no plan is
active, so the serving hot path pays nothing in production.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Dict, List, Optional

#: environment variable carrying a JSON-serialized plan into spawned
#: worker processes (set via ``FaultPlan.to_env()`` -> ``worker_env``)
FAULTS_ENV = "REPRO_UAL_FAULTS"

#: exit code used by ``kill_worker`` — distinct from Python's own crash
#: codes so a chaos run's logs show which deaths were injected
KILL_EXIT_CODE = 43

_KINDS = ("kill_worker", "exec_fault", "delay_dispatch", "corrupt_cache")


class InjectedFault(RuntimeError):
    """An ``exec_fault`` spec fired: the sweep 'failed' on purpose."""


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: what fires, where, and when.

    ``after`` is how many matching events pass through unharmed before
    the spec arms; ``count`` bounds how many times it fires once armed
    (``kill_worker`` effectively fires once — the process is gone).
    """

    kind: str
    worker: Optional[int] = None     # kill_worker: target worker (None=any)
    after: int = 0
    count: int = 1
    backend: Optional[str] = None    # exec_fault: only this backend
    delay_ms: float = 0.0            # delay_dispatch: sleep length
    path: Optional[str] = None       # corrupt_cache: cache dir to poison

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {_KINDS}")
        if self.after < 0 or self.count < 1:
            raise ValueError(f"need after >= 0 and count >= 1, got "
                             f"after={self.after} count={self.count}")


@dataclass(frozen=True)
class FaultPlan:
    """A seedable, serializable list of ``FaultSpec``s.

    ``to_env()`` returns the environment fragment that activates this
    plan in a spawned process (merge into ``ClusterService``'s
    ``worker_env``); ``from_env()`` is the receiving side, consulted
    lazily by the hook entry points.
    """

    specs: List[FaultSpec]
    seed: int = 0

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "specs": [asdict(s) for s in self.specs]})

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        raw = json.loads(text)
        return cls(specs=[FaultSpec(**s) for s in raw.get("specs", [])],
                   seed=int(raw.get("seed", 0)))

    def to_env(self) -> Dict[str, str]:
        return {FAULTS_ENV: self.to_json()}

    @classmethod
    def from_env(cls, environ: Optional[Dict[str, str]] = None
                 ) -> Optional["FaultPlan"]:
        text = (environ if environ is not None else os.environ).get(
            FAULTS_ENV)
        if not text:
            return None
        return cls.from_json(text)


class FaultInjector:
    """Runtime state of an active plan: per-spec seen/fired counters.

    One injector per process; counters advance in the order the hooks
    are called, which the serving stack keeps serialized per worker
    (requests arrive on one message loop, sweeps on one batch at a
    time), so firings are reproducible.
    """

    def __init__(self, plan: FaultPlan,
                 worker_index: Optional[int] = None) -> None:
        self.plan = plan
        self.worker_index = worker_index
        self._lock = threading.Lock()
        self._seen = [0] * len(plan.specs)
        self._fired = [0] * len(plan.specs)
        self.log: List[Dict[str, object]] = []

    def _arm(self, idx: int, spec: FaultSpec) -> bool:
        """Count one matching event against ``spec``; True if it fires."""
        with self._lock:
            self._seen[idx] += 1
            if (self._seen[idx] > spec.after
                    and self._fired[idx] < spec.count):
                self._fired[idx] += 1
                self.log.append({"kind": spec.kind, "event": self._seen[idx],
                                 "firing": self._fired[idx]})
                return True
        return False

    # -- hook bodies ---------------------------------------------------------
    def on_request(self) -> None:
        """Cluster-worker hook, once per received request."""
        for idx, spec in enumerate(self.plan.specs):
            if spec.kind == "kill_worker":
                if (spec.worker is not None
                        and spec.worker != self.worker_index):
                    continue
                if self._arm(idx, spec):
                    # a real crash: no cleanup, no flush, no goodbye
                    os._exit(KILL_EXIT_CODE)
            elif spec.kind == "corrupt_cache":
                if self._arm(idx, spec) and spec.path:
                    corrupt_cache_entry(spec.path)

    def check_exec(self, backend: str) -> None:
        """Service-worker hook, inside the engine-sweep ``try`` block."""
        for idx, spec in enumerate(self.plan.specs):
            if spec.kind != "exec_fault":
                continue
            if spec.backend is not None and spec.backend != backend:
                continue
            if self._arm(idx, spec):
                raise InjectedFault(
                    f"injected exec fault on backend {backend!r} "
                    f"(firing {self._fired[idx]}/{spec.count})")

    def dispatch_delay(self) -> float:
        """Dispatcher hook: seconds to stall before emitting a batch."""
        total = 0.0
        for idx, spec in enumerate(self.plan.specs):
            if spec.kind != "delay_dispatch":
                continue
            if self._arm(idx, spec):
                total += spec.delay_ms / 1e3
        return total


# -- process-wide active injector -------------------------------------------
_state_lock = threading.Lock()
_injector: Optional[FaultInjector] = None
_env_checked = False


def install(plan: FaultPlan,
            worker_index: Optional[int] = None) -> FaultInjector:
    """Activate ``plan`` in this process (tests / in-process services)."""
    global _injector, _env_checked
    with _state_lock:
        _injector = FaultInjector(plan, worker_index)
        _env_checked = True
        return _injector


def clear() -> None:
    """Deactivate fault injection in this process."""
    global _injector, _env_checked
    with _state_lock:
        _injector = None
        _env_checked = True


def active() -> Optional[FaultInjector]:
    """The process's active injector, loading ``REPRO_UAL_FAULTS`` from
    the environment on first call (spawned workers inherit the plan this
    way); None when no plan is active."""
    global _injector, _env_checked
    if _env_checked:
        return _injector
    with _state_lock:
        if not _env_checked:
            plan = FaultPlan.from_env()
            if plan is not None:
                _injector = FaultInjector(plan)
            _env_checked = True
    return _injector


def set_worker_index(widx: int) -> None:
    """Bind the env-loaded injector to a cluster worker index so
    ``kill_worker`` specs with ``worker=`` match (called by the cluster
    worker main before its message loop)."""
    inj = active()
    if inj is not None:
        inj.worker_index = widx


# -- module-level hook entry points (no-ops when inactive) -------------------
def on_request() -> None:
    inj = active()
    if inj is not None:
        inj.on_request()


def check_exec(backend: str) -> None:
    inj = active()
    if inj is not None:
        inj.check_exec(backend)


def dispatch_delay() -> None:
    inj = active()
    if inj is not None:
        d = inj.dispatch_delay()
        if d > 0:
            time.sleep(d)


# -- cache corruption (torn-write emulation) ---------------------------------
def corrupt_cache_entry(disk_dir, *, which: str = "mapping",
                        index: int = 0,
                        mode: str = "truncate") -> Optional[Path]:
    """Deterministically corrupt one on-disk artifact-cache entry.

    Picks the ``index``-th (sorted) ``.pkl`` entry of the given layer
    (``"mapping"`` or ``"lowered"``) under ``disk_dir`` and either
    truncates it mid-payload (``mode="truncate"`` — a torn write from a
    killed process) or flips bytes in place (``mode="flip"`` — silent
    media corruption).  Returns the path it poisoned, or None when the
    layer has no entries.  The cache's checksummed read path must treat
    the result as a miss and quarantine the file.
    """
    d = Path(disk_dir)
    if not d.is_dir():
        return None
    names = sorted(p for p in d.glob("*.pkl"))
    if which == "lowered":
        names = [p for p in names if p.name.endswith("_low.pkl")]
    else:
        names = [p for p in names if not p.name.endswith("_low.pkl")]
    if index >= len(names):
        return None
    path = names[index]
    blob = path.read_bytes()
    if mode == "truncate":
        cut = max(1, len(blob) // 2)
        path.write_bytes(blob[:cut])
    else:
        mid = len(blob) // 2
        mangled = bytes((b ^ 0xFF) for b in blob[mid:mid + 8])
        path.write_bytes(blob[:mid] + mangled + blob[mid + 8:])
    return path


__all__ = ("FAULTS_ENV", "KILL_EXIT_CODE", "FaultInjector", "FaultPlan",
           "FaultSpec", "InjectedFault", "active", "check_exec", "clear",
           "corrupt_cache_entry", "dispatch_delay", "install",
           "on_request", "set_worker_index")

"""Pluggable execution backends for the unified abstraction layer.

A backend turns ``(Program, MapResult, named arrays)`` into named output
arrays.  Three ship with the repo:

  * ``interp``  — the DFG interpreter oracle (no mapping required; the
    reference semantics every other backend must match bit-exactly),
  * ``sim``     — the cycle-accurate simulator executing the mapped
    machine configuration,
  * ``pallas``  — the Pallas ``cgra_exec`` TPU kernel executing the same
    configuration (batched; interpret-mode on CPU).

Third parties extend the layer with ``register_backend("mine", MyBackend())``
— see ROADMAP.md for a worked example.  Backends are resolved by name at
``compile()`` time; unknown names raise with the list of registered ones.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.dfg import interpret
from repro.core.mapper import MapResult
from repro.ual.program import Program

Mem = Dict[str, np.ndarray]
Info = Dict[str, object]


class Backend:
    """Base class: subclass and override ``execute`` (and optionally
    ``execute_batch`` when the device can batch natively)."""

    #: whether ``compile()`` must produce a machine configuration first
    requires_config: bool = True

    def execute(self, program: Program, result: Optional[MapResult],
                mem: Mem, n_iters: int) -> Tuple[Mem, Info]:
        raise NotImplementedError

    def execute_batch(self, program: Program, result: Optional[MapResult],
                      mems: List[Mem], n_iters: int
                      ) -> Tuple[List[Mem], Info]:
        outs = []
        info: Info = {}
        for m in mems:
            out, info = self.execute(program, result, m, n_iters)
            outs.append(out)
        return outs, info


class InterpBackend(Backend):
    """DFG-interpreter oracle: executes the *pre-layout* DFG directly."""

    requires_config = False

    def execute(self, program, result, mem, n_iters):
        program.check_arrays(mem)
        return interpret(program.dfg, mem, n_iters), {}


class SimBackend(Backend):
    """Cycle-accurate simulation of the mapped configuration."""

    def execute(self, program, result, mem, n_iters):
        from repro.core.simulator import simulate
        flat = program.flatten(mem)
        out, stats = simulate(result.config, flat, n_iters)
        return program.unflatten(out), {"sim_stats": stats}


class PallasBackend(Backend):
    """Pallas ``cgra_exec`` TPU kernel (interpret-mode on CPU)."""

    def __init__(self, lanes: int = 128, interpret: bool = True):
        self.lanes = lanes
        self.interpret = interpret

    def _run(self, program, result, flats: np.ndarray, n_iters: int):
        from repro.kernels.cgra_exec.ops import cgra_exec_op
        return cgra_exec_op(result.config, flats, n_iters,
                            lanes=self.lanes, interpret=self.interpret)

    def execute(self, program, result, mem, n_iters):
        flat = program.flatten(mem)
        out = self._run(program, result, flat[None], n_iters)[0]
        return program.unflatten(out), {}

    def execute_batch(self, program, result, mems, n_iters):
        flats = np.stack([program.flatten(m) for m in mems])
        outs = self._run(program, result, flats, n_iters)
        return [program.unflatten(o) for o in outs], {"batched": True}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Backend] = {}


def register_backend(name: str, backend: Backend,
                     overwrite: bool = False) -> None:
    """Register an execution backend under ``name``.

    Registering an existing name raises unless ``overwrite=True`` — silent
    replacement is how two plugins stomp each other.
    """
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered; "
                         f"pass overwrite=True to replace it")
    if not isinstance(backend, Backend):
        raise TypeError(f"backend must be a ual.backends.Backend, "
                        f"got {type(backend).__name__}")
    _BACKENDS[name] = backend


def get_backend(name: str) -> Backend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}")
    return _BACKENDS[name]


def list_backends() -> List[str]:
    return sorted(_BACKENDS)


register_backend("interp", InterpBackend())
register_backend("sim", SimBackend())
register_backend("pallas", PallasBackend())

"""Pluggable execution backends for the unified abstraction layer.

A backend turns ``(Program, MapResult, named arrays)`` into named output
arrays.  Three ship with the repo:

  * ``interp``  — the DFG interpreter oracle (no mapping required; the
    reference semantics every other backend must match bit-exactly),
  * ``sim``     — the vectorized, natively-batched simulator executing
    the lowered configuration tables (``core.simulator.simulate_batch``),
  * ``pallas``  — the Pallas ``cgra_exec`` TPU kernel executing the same
    tables (batched; interpret-mode on CPU) through the persistent JIT
    engine (``repro.ual.engine``): trace-once/run-many with batch-bucket
    padding, tables device-resident per engine, ``n_iters`` traced.

``sim`` and ``pallas`` both consume the shared **lowered artifact**
(``core.lowering.LinkedConfig``) produced once by the compile pipeline's
lowering pass: backends that set ``consumes_lowered = True`` receive it
via the ``lowered`` keyword — the tables are program-independent (pure
function of the machine configuration), so custom device backends can
execute them directly instead of re-deriving routing from the raw config.

Third parties extend the layer with ``register_backend("mine", MyBackend())``
— see ROADMAP.md for a worked example.  Backends are resolved by name at
``compile()`` time; unknown names raise with the list of registered ones.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.dfg import interpret
from repro.core.mapper import MapResult
from repro.ual.program import Program

Mem = Dict[str, np.ndarray]
Info = Dict[str, object]


class Backend:
    """Base class: subclass and override ``execute`` (and optionally
    ``execute_batch`` when the device can batch natively)."""

    #: whether ``compile()`` must produce a machine configuration first
    requires_config: bool = True
    #: backends that execute the lowered dense tables set this to True and
    #: accept a ``lowered=`` keyword (a ``core.lowering.LinkedConfig``) in
    #: ``execute``/``execute_batch``; backends that interpret the raw
    #: config (or need no config at all) leave it False and keep the plain
    #: four-argument signature
    consumes_lowered: bool = False
    #: backends that can pin one call to one jax device accept a
    #: ``device=`` keyword in ``execute``/``execute_batch`` — the
    #: serving cluster's replica router uses this to run per-device
    #: replicas; leave False to never receive the keyword
    supports_device: bool = False
    #: natively-batched backends that can skip re-flattening when the
    #: caller already holds the (B, total_words) image accept a
    #: ``flats=`` keyword in ``execute_batch`` — ``Executable.validate``
    #: uses this to flatten its test vectors ONCE per multi-backend sweep
    accepts_flats: bool = False

    def execute(self, program: Program, result: Optional[MapResult],
                mem: Mem, n_iters: int, **kw) -> Tuple[Mem, Info]:
        raise NotImplementedError

    def execute_batch(self, program: Program, result: Optional[MapResult],
                      mems: List[Mem], n_iters: int, **kw
                      ) -> Tuple[List[Mem], Info]:
        outs = []
        info: Info = {}
        for m in mems:
            out, info = self.execute(program, result, m, n_iters, **kw)
            outs.append(out)
        return outs, info

    def execute_stream(self, program: Program, result: Optional[MapResult],
                       mems: Iterable[Mem], n_iters: int, *,
                       chunk: Optional[int] = None, **kw
                       ) -> Iterator[Tuple[List[Mem], Info]]:
        """Streaming execution: yield ``(out_dicts, chunk_info)`` per
        chunk of ``chunk`` samples as results drain; the generator's
        return value is the stream summary (must carry ``overlap_frac``
        and ``stream_chunks``).

        This default chunks the input through ``execute_batch`` — chunked
        delivery, but NO transfer/compute overlap (``overlap_frac`` 0.0).
        Backends with an asynchronous device path (pallas) override it
        with a genuinely pipelined implementation.
        """
        step = max(1, int(chunk) if chunk else 32)
        n_chunks = 0
        n_samples = 0
        group: List[Mem] = []
        for m in mems:
            group.append(m)
            if len(group) >= step:
                outs, info = self.execute_batch(program, result, group,
                                                n_iters, **kw)
                yield outs, {"chunk": n_chunks, "samples": len(outs),
                             **info}
                n_chunks += 1
                n_samples += len(outs)
                group = []
        if group:
            outs, info = self.execute_batch(program, result, group,
                                            n_iters, **kw)
            yield outs, {"chunk": n_chunks, "samples": len(outs), **info}
            n_chunks += 1
            n_samples += len(outs)
        return {"stream_chunks": n_chunks, "samples": n_samples,
                "overlap_frac": 0.0, "streamed": "chunked-sync"}


class InterpBackend(Backend):
    """DFG-interpreter oracle: executes the *pre-layout* DFG directly."""

    requires_config = False

    def execute(self, program, result, mem, n_iters):
        program.check_arrays(mem)
        return interpret(program.dfg, mem, n_iters), {}


def _ensure_lowered(result, lowered):
    """The shared artifact, or (for callers bypassing the pipeline) the
    per-process fingerprint memo — no path lowers one config twice."""
    if lowered is not None:
        return lowered
    from repro.kernels.cgra_exec.ops import _memoized_link
    return _memoized_link(result.config)


class SimBackend(Backend):
    """Vectorized, natively-batched simulation of the lowered tables.

    Consumes the shared lowered artifact; a single ``execute_batch`` call
    steps the whole batch through the fabric simultaneously (leading
    batch axis in the engine state).  The scalar reference engine remains
    available as ``core.simulator.simulate_reference``.
    """

    consumes_lowered = True
    accepts_flats = True

    def execute(self, program, result, mem, n_iters, lowered=None):
        from repro.core.simulator import simulate_batch
        flat = program.flatten(mem)
        out, stats = simulate_batch(_ensure_lowered(result, lowered),
                                    flat[None], n_iters)
        return program.unflatten(out[0]), {"sim_stats": stats,
                                           "engine": "vectorized"}

    def execute_batch(self, program, result, mems, n_iters, lowered=None,
                      flats=None):
        from repro.core.simulator import simulate_batch
        if flats is None:
            flats = program.flatten_batch(mems)
        outs, stats = simulate_batch(_ensure_lowered(result, lowered),
                                     flats, n_iters)
        return (program.unflatten_batch(outs),
                {"sim_stats": stats, "engine": "vectorized", "batched": True})


class PallasBackend(Backend):
    """Pallas ``cgra_exec`` TPU kernel (interpret-mode on CPU), executed
    through the persistent JIT engine (``repro.ual.engine``): the linked
    tables live on device per engine, ``n_iters`` is traced, and batch
    sizes are padded up the bucket ladder so repeat traffic hits warm
    traces — trace once, run many.

    Two multi-device modes (the serving cluster's substrates):

      * default (``sharded=False``): accepts a per-call ``device=``
        keyword (``supports_device``) routing the sweep through a
        device-pinned replica engine — N calls on N devices run truly
        concurrent replicas;
      * ``sharded=True`` (registered as ``"pallas_sharded"``): every
        sweep shard_maps the batch axis over ALL host devices through
        one ``ShardedKernelEngine`` — one trace, N devices, per-device
        bucket padding.
    """

    consumes_lowered = True
    accepts_flats = True

    def __init__(self, lanes: int = 128, interpret: bool = True,
                 engine=None, sharded: bool = False):
        self.lanes = lanes
        self.interpret = interpret
        self._engine = engine        # None -> the process-wide engine cache
        self.sharded = sharded
        # a sharded sweep spans every device; pinning it to one is a
        # contradiction, so the router never offers the keyword
        self.supports_device = not sharded

    @property
    def engine(self):
        if self._engine is not None:
            return self._engine
        from repro.ual.engine import default_engine
        return default_engine()

    def execute(self, program, result, mem, n_iters, lowered=None,
                device=None):
        outs, info = self.execute_batch(program, result, [mem], n_iters,
                                        lowered=lowered, device=device)
        return outs[0], info

    def _engine_for(self, linked, device=None):
        """The (cached) engine executing ``linked`` under this backend's
        opts — sharded or single-device, per the registration."""
        if self.sharded:
            return self.engine.sharded_engine_for(linked, lanes=self.lanes,
                                                  interpret=self.interpret)
        return self.engine.engine_for(linked, lanes=self.lanes,
                                      interpret=self.interpret,
                                      device=device)

    def execute_batch(self, program, result, mems, n_iters, lowered=None,
                      device=None, flats=None):
        if flats is None:
            flats = program.flatten_batch(mems)
        linked = _ensure_lowered(result, lowered)
        if self.sharded:
            out, info = self.engine.sharded_run(linked, flats, n_iters,
                                                lanes=self.lanes,
                                                interpret=self.interpret)
        else:
            out, info = self.engine.run(linked, flats, n_iters,
                                        lanes=self.lanes,
                                        interpret=self.interpret,
                                        device=device)
        info["batched"] = True
        return program.unflatten_batch(out), info

    def execute_stream(self, program, result, mems, n_iters, *,
                       chunk=None, lowered=None, device=None):
        """Genuinely pipelined streaming: chunks flow through the
        persistent engine's double-buffered ``run_stream`` — while chunk
        *i* computes on device, the host flattens/uploads chunk *i+1*
        and unflattens chunk *i-1*'s drained rows.  Same bucket-ladder
        traces as ``execute_batch``; the summary carries the engine's
        measured ``overlap_frac``."""
        linked = _ensure_lowered(result, lowered)
        eng = self._engine_for(linked, device=device)
        step = (max(1, min(int(chunk), eng._capacity())) if chunk
                else eng._capacity())

        def blocks():
            group = []
            for m in mems:
                group.append(m)
                if len(group) >= step:
                    yield program.flatten_batch(group)
                    group = []
            if group:
                yield program.flatten_batch(group)

        gen = eng.run_stream(blocks(), n_iters, chunk=step)
        while True:
            try:
                out, cinfo = next(gen)
            except StopIteration as stop:
                summary = dict(stop.value or {})
                summary["batched"] = True
                return summary
            yield program.unflatten_batch(out), cinfo

    def warmup(self, program, result, lowered=None, buckets=None,
               device=None):
        """Pre-trace the bucket ladder for this program's scratchpad width
        (``n_iters`` is traced, so one trace per bucket covers every trip
        count).  Returns the engine's stats."""
        linked = _ensure_lowered(result, lowered)
        eng = self._engine_for(linked, device=device)
        return eng.warmup(program.layout.total_words, buckets)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BACKENDS: Dict[str, Backend] = {}


def register_backend(name: str, backend: Backend,
                     overwrite: bool = False) -> None:
    """Register an execution backend under ``name``.

    Registering an existing name raises unless ``overwrite=True`` — silent
    replacement is how two plugins stomp each other.
    """
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered; "
                         f"pass overwrite=True to replace it")
    if not isinstance(backend, Backend):
        raise TypeError(f"backend must be a ual.backends.Backend, "
                        f"got {type(backend).__name__}")
    _BACKENDS[name] = backend


def get_backend(name: str) -> Backend:
    if name not in _BACKENDS:
        raise KeyError(f"unknown backend {name!r}; "
                       f"registered: {sorted(_BACKENDS)}")
    return _BACKENDS[name]


def list_backends() -> List[str]:
    return sorted(_BACKENDS)


register_backend("interp", InterpBackend())
register_backend("sim", SimBackend())
register_backend("pallas", PallasBackend())
register_backend("pallas_sharded", PallasBackend(sharded=True))

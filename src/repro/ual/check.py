"""``python -m repro.ual.check`` — compile-time config verification CLI.

Compiles kernels through the UAL pipeline with the verify pass in
*collect* mode (``default_pipeline(strict_verify=False)``), renders the
full ``CheckReport`` for every config — including ones whose errors
would abort a strict ``ual.compile()`` — and exits non-zero when any
error-severity finding (or, with ``--fail-on-warning``, any warning)
survives.  The diagnostic-code reference lives in
``docs/diagnostics.md``.

    # one kernel on the default fabrics
    python -m repro.ual.check gemm

    # the CI verifier gate: every smoke-suite config
    python -m repro.ual.check --smoke-suite

    # several kernels on named fabrics, JSON artifact for tooling
    python -m repro.ual.check gemm fft --fabric hycube n2n \
        --json artifacts/check.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

#: the configs ``benchmarks/run.py --smoke`` compiles — the CLI's
#: ``--smoke-suite`` verifies exactly this set (spatial carries no
#: machine configuration and is reported as skipped)
SMOKE_SUITE: Tuple[Tuple[str, Dict[str, object]], ...] = (
    ("hycube", {"rows": 4, "cols": 4}),
    ("n2n", {"rows": 4, "cols": 4}),
    ("pace", {}),
    ("spatial", {"rows": 4, "cols": 4}),
)

DEFAULT_FABRICS = ("hycube", "n2n")


def _targets(args) -> List[Tuple[str, Dict[str, object]]]:
    if args.smoke_suite:
        return list(SMOKE_SUITE)
    names = args.fabric or list(DEFAULT_FABRICS)
    sized = {"hycube": {"rows": 4, "cols": 4}, "n2n": {"rows": 4, "cols": 4},
             "spatial": {"rows": 4, "cols": 4}}
    return [(n, dict(sized.get(n, {}))) for n in names]


def check_configs(kernels, fabrics, cache=None) -> Tuple[List[Dict], int, int]:
    """Compile every (kernel, fabric) pair and verify it; returns
    (per-config JSON payloads, total errors, total warnings)."""
    from repro import ual
    from repro.ual.pipeline import default_pipeline

    payloads: List[Dict] = []
    n_err = n_warn = 0
    for fab_name, kwargs in fabrics:
        spatial_like = fab_name == "spatial"
        target = ual.Target.from_name(
            fab_name, backend="interp" if spatial_like else "sim", **kwargs)
        for kernel in kernels:
            program = ual.Program.from_kernel(
                kernel, n_banks=max(1, target.fabric.n_mem_ports))
            label = f"{kernel} @ {target.fabric.name}"
            exe = ual.compile(program, target, cache=cache,
                              pipeline=default_pipeline(strict_verify=False))
            if not exe.success:
                print(f"verify {label}: SKIPPED (mapping failed)")
                payloads.append({"name": label, "skipped": "mapping failed"})
                continue
            rep = exe.check_report
            if rep is None:
                print(f"verify {label}: SKIPPED (no machine configuration)")
                payloads.append({"name": label,
                                 "skipped": "no machine configuration"})
                continue
            print(rep.render())
            c = rep.counts()
            n_err += c["errors"]
            n_warn += c["warnings"]
            payloads.append(rep.to_json())
    return payloads, n_err, n_warn


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.ual.check",
        description="statically verify mapped CGRA configurations "
                    "(see docs/diagnostics.md for the code reference)")
    ap.add_argument("kernels", nargs="*", default=None,
                    help="kernel-library names to compile (default: gemm)")
    ap.add_argument("--fabric", nargs="+", default=None,
                    help=f"registered fabric names (default: "
                         f"{' '.join(DEFAULT_FABRICS)})")
    ap.add_argument("--smoke-suite", action="store_true",
                    help="verify exactly the configs the --smoke bench "
                         "compiles (the CI verifier gate)")
    ap.add_argument("--fail-on-warning", action="store_true",
                    help="exit non-zero on warnings too, not just errors")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the reports as a JSON artifact")
    args = ap.parse_args(argv)

    kernels = args.kernels or ["gemm"]
    payloads, n_err, n_warn = check_configs(kernels, _targets(args))

    verdict = "FAIL" if (n_err or (args.fail_on_warning and n_warn)) else "ok"
    print(f"\ncheck: {len(payloads)} config(s), {n_err} error(s), "
          f"{n_warn} warning(s) -> {verdict}")
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump({"configs": payloads, "errors": n_err,
                       "warnings": n_warn}, f, indent=1)
        print(f"wrote {args.json}")
    return 1 if verdict == "FAIL" else 0


if __name__ == "__main__":
    sys.exit(main())

"""Mapping cache: memoizes compile artifacts per ``(program, target)`` pair.

Modulo mapping dominates the toolchain's wall time (seconds to minutes per
kernel, with restarts), yet the suite compiles the same kernels onto the
same fabrics over and over.  The cache keys on
``(program.digest, target.digest)`` — both stable content hashes — and
keeps results in two layers:

  * an in-process dict (free hits within one run),
  * an on-disk pickle directory (hits across processes: test runs,
    benchmark re-runs, CI re-tries).

Two artifact kinds live side by side under the same key:

  * the ``MapResult`` (placements + machine configuration) from the
    mapping pass, and
  * the **lowered artifact** (``core.lowering.LinkedConfig`` dense
    tables) from the lowering pass — lower once, run many: a warm
    compile re-lowers nothing, and every backend executing the same
    configuration shares one set of tables.

Hit/miss/store counters are exposed for tests to assert cache behavior:
``cache.stats`` holds the raw ``CacheStats`` counters, and *calling* it —
``cache.stats()`` — returns the aggregate view (hit/miss ratios plus
on-disk entry counts for both the mapping and lowered tables).

The cache is thread-safe: one lock guards the in-process layers and the
counters, and ``lock_key(key)`` hands out a per-key compile lock so the
pipeline can double-check under it — two threads compiling the same
``(program, target)`` digest pair pay exactly one mapper run and one
lowering (the execution service leans on this when a cold tenant's first
requests arrive on several workers at once).

Disk entries are self-verifying: every file carries a magic tag and a
SHA-256 checksum over the pickled payload, written atomically with it.
A reader that finds a torn, truncated or bit-flipped entry (disk died
mid-write, an operator truncated the file, a fault-injection run
corrupted it on purpose) treats it as a miss, *quarantines* the file by
renaming it to ``<name>.corrupt`` — so the poisoned bytes can never be
re-read, but stay on disk for post-mortem — and recompiles.  Quarantine
counts surface per layer in the aggregate stats view.

The disk layer is additionally safe under multi-PROCESS use (the
``ClusterService`` worker pool shares one directory):

  * writes publish atomically — pickle to a per-writer tmp file, then
    ``os.replace`` into place — so a reader never sees a torn entry,
  * a concurrent writer winning the race is tolerated: if our own
    publish fails but the final path exists, someone else stored an
    equivalent artifact and we read it back instead of erroring,
  * ``process_lock_key(key)`` hands out a cross-process analogue of
    ``lock_key``: an ``fcntl.flock``-backed lock on a per-key ``.lock``
    file in the disk dir.  The pipeline's mapping pass takes it for cold
    compiles (and keeps it through lowering), so N worker *processes*
    racing on one cold tenant pay exactly one mapping + one lowering
    cluster-wide — the losers block, then read the winner's entry off
    disk.  Diskless caches get a no-op lock (thread-level protection
    still applies).

The disk layer defaults to ``$REPRO_UAL_CACHE`` or ``artifacts/ual_cache``
next to the repo; pass ``MappingCache(disk_dir=None)`` for a purely
in-process cache.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

from repro.core.lowering import LOWERING_VERSION, LinkedConfig
from repro.core.mapper import MAPPER_VERSION, MapResult

#: bump to invalidate on-disk entries when the MapResult/MachineConfig
#: pickle format changes; mapper *behavior* changes are covered separately
#: by core.mapper.MAPPER_VERSION (also folded into the entry name)
#: (v2: entries carry a magic tag + SHA-256 payload checksum)
CACHE_VERSION = 2

#: on-disk entry envelope: MAGIC + 16-byte checksum prefix + pickle blob
_MAGIC = b"UALC\x02"
_CSUM_LEN = 16


def _pack_entry(payload: object) -> bytes:
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    return _MAGIC + hashlib.sha256(blob).digest()[:_CSUM_LEN] + blob


def _unpack_entry(raw: bytes) -> object:
    """Verify the envelope and unpickle; raises ``ValueError`` on a bad
    magic/length/checksum (torn write, truncation, bit flip) so the
    caller can quarantine the file instead of feeding pickle garbage."""
    hdr = len(_MAGIC) + _CSUM_LEN
    if len(raw) < hdr or not raw.startswith(_MAGIC):
        raise ValueError("bad cache entry header")
    csum, blob = raw[len(_MAGIC):hdr], raw[hdr:]
    if hashlib.sha256(blob).digest()[:_CSUM_LEN] != csum:
        raise ValueError("cache entry checksum mismatch")
    return pickle.loads(blob)


def default_cache_dir() -> Path:
    env = os.environ.get("REPRO_UAL_CACHE")
    if env:
        return Path(env)
    # src/repro/ual/cache.py -> repo root / artifacts / ual_cache, but only
    # when we actually live in a source checkout; for an installed package
    # parents[3] is the Python prefix, which must not be written to
    root = Path(__file__).resolve().parents[3]
    if (root / "pyproject.toml").exists() or (root / ".git").exists():
        return root / "artifacts" / "ual_cache"
    xdg = os.environ.get("XDG_CACHE_HOME", str(Path.home() / ".cache"))
    return Path(xdg) / "repro_ual"


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    stores: int = 0
    disk_hits: int = 0
    # -- lowered-artifact layer (counted separately: a compile can hit the
    # mapping entry while still lowering cold, and tests assert each) ------
    lowered_hits: int = 0
    lowered_misses: int = 0
    lowered_stores: int = 0
    lowered_disk_hits: int = 0
    #: corrupt disk entries detected and renamed aside (both layers)
    quarantined: int = 0
    #: probe for on-disk entry counts, wired up by the owning
    #: ``MappingCache`` so the aggregate view can report them; a bare
    #: ``CacheStats`` (no owner) reports zero disk entries
    _disk_counts: Optional[Callable[[], Tuple[int, int]]] = field(
        default=None, repr=False, compare=False)

    def reset(self) -> None:
        self.hits = self.misses = self.stores = self.disk_hits = 0
        self.lowered_hits = self.lowered_misses = 0
        self.lowered_stores = self.lowered_disk_hits = 0
        self.quarantined = 0

    @staticmethod
    def _layer(hits: int, misses: int, stores: int, disk_hits: int,
               disk_entries: int) -> Dict[str, object]:
        total = hits + misses
        return {"hits": hits, "misses": misses, "stores": stores,
                "disk_hits": disk_hits, "lookups": total,
                "hit_ratio": round(hits / total, 4) if total else None,
                "disk_entries": disk_entries}

    def __call__(self) -> Dict[str, Dict[str, object]]:
        """Aggregate view (this is what ``MappingCache.stats()`` returns):
        per-layer hit/miss ratios and on-disk entry counts for both the
        mapping and lowered tables."""
        m_disk, l_disk = self._disk_counts() if self._disk_counts else (0, 0)
        return {
            "mapping": self._layer(self.hits, self.misses, self.stores,
                                   self.disk_hits, m_disk),
            "lowered": self._layer(self.lowered_hits, self.lowered_misses,
                                   self.lowered_stores,
                                   self.lowered_disk_hits, l_disk),
            "quarantined": self.quarantined,
        }


class _KeyFileLock:
    """Cross-process exclusive lock on one cache key, backed by
    ``fcntl.flock`` on a per-key ``.lock`` file in the cache's disk dir.

    Same acquire/release shape as ``threading.Lock`` so the pipeline can
    hold it across passes the way it holds the thread-level key lock.
    The lock file itself is never deleted (deleting a file other
    processes may be flocking reintroduces the race the lock exists to
    close); flock state dies with the fd, so a crashed holder never
    wedges the key.  Not reentrant — one acquire per compile.
    """

    def __init__(self, path: Path) -> None:
        self._path = path
        self._fd: Optional[int] = None

    def acquire(self) -> None:
        import fcntl
        self._path.parent.mkdir(parents=True, exist_ok=True)
        fd = os.open(self._path, os.O_CREAT | os.O_RDWR, 0o644)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX)
        except OSError:
            os.close(fd)
            raise
        self._fd = fd

    def release(self) -> None:
        import fcntl
        fd, self._fd = self._fd, None
        if fd is not None:
            fcntl.flock(fd, fcntl.LOCK_UN)
            os.close(fd)

    def __enter__(self) -> "_KeyFileLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@dataclass
class MappingCache:
    disk_dir: Optional[Path] = field(default_factory=default_cache_dir)
    stats: CacheStats = field(default_factory=CacheStats)
    _mem: Dict[Tuple[str, str], MapResult] = field(default_factory=dict)
    _mem_lowered: Dict[Tuple[str, str],
                       Tuple[str, LinkedConfig]] = field(
        default_factory=dict)
    _lock: object = field(default_factory=threading.RLock, repr=False,
                          compare=False)
    _key_locks: Dict[Tuple[str, str], object] = field(default_factory=dict,
                                                      repr=False,
                                                      compare=False)

    def __post_init__(self) -> None:
        if self.disk_dir is not None:
            self.disk_dir = Path(self.disk_dir)
        self.stats._disk_counts = self._disk_entry_counts

    def _path(self, key: Tuple[str, str]) -> Path:
        pdig, tdig = key
        return (self.disk_dir /
                f"v{CACHE_VERSION}m{MAPPER_VERSION}_"
                f"{pdig[:20]}_{tdig[:20]}.pkl")

    def _lowered_path(self, key: Tuple[str, str]) -> Path:
        pdig, tdig = key
        return (self.disk_dir /
                f"v{CACHE_VERSION}m{MAPPER_VERSION}l{LOWERING_VERSION}_"
                f"{pdig[:20]}_{tdig[:20]}_low.pkl")

    def _read_entry(self, path: Path) -> Optional[object]:
        """Read + verify one disk entry; a torn/corrupt/stale file is
        quarantined (renamed to ``<name>.corrupt``) and reported as a
        miss — never an exception, never silently re-readable.  Caller
        holds ``self._lock``."""
        try:
            raw = path.read_bytes()
        except OSError:
            return None  # vanished/unreadable: plain miss
        try:
            return _unpack_entry(raw)
        except (ValueError, pickle.UnpicklingError, EOFError,
                AttributeError, ImportError, TypeError, IndexError):
            self.stats.quarantined += 1
            try:
                os.replace(path, path.with_name(path.name + ".corrupt"))
            except OSError:
                pass  # raced with another reader's quarantine: fine
            return None

    def _load(self, key: Tuple[str, str]
              ) -> Tuple[Optional[MapResult], bool]:
        """Memory-then-disk lookup, no counters; returns
        ``(result, from_disk)``.  Caller holds ``self._lock``."""
        if key in self._mem:
            return self._mem[key], False
        if self.disk_dir is not None:
            path = self._path(key)
            if path.exists():
                result = self._read_entry(path)
                if result is not None:
                    self._mem[key] = result
                    return result, True
        return None, False

    def get(self, key: Tuple[str, str]) -> Optional[MapResult]:
        with self._lock:
            result, from_disk = self._load(key)
            if result is None:
                self.stats.misses += 1
                return None
            self.stats.hits += 1
            if from_disk:
                self.stats.disk_hits += 1
            return result

    def peek(self, key: Tuple[str, str]) -> Optional[MapResult]:
        """``get`` without touching the hit/miss counters — the
        double-checked re-read under ``lock_key``, where a hit means
        "another thread just mapped this" rather than a warm compile."""
        with self._lock:
            return self._load(key)[0]

    def contains(self, key: Tuple[str, str]) -> bool:
        """Whether ``get(key)`` would hit (either layer), without touching
        the hit/miss counters — a peek for schedulers (``compile_many``)
        deciding what still needs to be mapped."""
        with self._lock:
            if key in self._mem:
                return True
            return self.disk_dir is not None and self._path(key).exists()

    def _write_atomic(self, path: Path, payload: object) -> None:
        """Publish ``payload`` at ``path`` atomically (tmp + os.replace),
        wrapped in the checksummed entry envelope.

        Runs OUTSIDE the cache lock — a slow disk store must not stall
        unrelated lookups.  Failures are tolerated when the final path
        exists (a concurrent writer won the race and published an
        equivalent artifact; the caller's in-memory copy is already
        installed); a failure with no entry on disk propagates — that is
        a real I/O problem, not a race."""
        self.disk_dir.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(
            f".tmp.{os.getpid()}.{threading.get_ident()}")
        try:
            tmp.write_bytes(_pack_entry(payload))
            os.replace(tmp, path)  # atomic: racers never read torn files
        except OSError:
            try:
                tmp.unlink()
            except OSError:
                pass
            if not path.exists():
                raise

    def lock_key(self, key: Tuple[str, str]) -> object:
        """The per-key compile lock: the pipeline's mapping and lowering
        passes serialize cold compiles of one digest pair under it
        (miss -> acquire -> ``peek`` again -> compute), so concurrent
        threads pay exactly one mapper run and one lowering per key."""
        with self._lock:
            lock = self._key_locks.get(key)
            if lock is None:
                lock = self._key_locks[key] = threading.Lock()
            return lock

    def process_lock_key(self, key: Tuple[str, str]
                         ) -> Optional[_KeyFileLock]:
        """Cross-PROCESS analogue of ``lock_key``: an un-acquired
        ``fcntl.flock``-backed lock on this key's ``.lock`` file, or
        None when there is no disk layer to coordinate over (or no
        ``fcntl`` on this platform).  The pipeline's mapping pass holds
        it across cold mapping + lowering so N processes sharing the
        disk dir pay exactly one of each per key — losers block, then
        read the winner's entry off disk."""
        if self.disk_dir is None:
            return None
        try:
            import fcntl                               # noqa: F401
        except ImportError:                            # pragma: no cover
            return None
        return _KeyFileLock(self._path(key).with_suffix(".lock"))

    def put(self, key: Tuple[str, str], result: MapResult, *,
            memory_only: bool = False) -> None:
        with self._lock:
            self._mem[key] = result
            self.stats.stores += 1
        if memory_only or self.disk_dir is None:
            return
        self._write_atomic(self._path(key), result)

    # -- lowered-artifact layer (same two-layer contract, same key) ---------
    # Entries are stored WITH the fingerprint of the configuration they
    # were lowered from: the wall-clock-budgeted mapper can produce
    # different configs for the same key (another process, a re-map after
    # a lost mapping pickle), and a mapping/lowered pair on disk may be
    # written by two racing compiles — a fingerprint mismatch is a miss,
    # never a silently-wrong artifact.
    def _load_lowered(self, key: Tuple[str, str], fingerprint: str
                      ) -> Tuple[Optional[LinkedConfig], bool]:
        """Memory-then-disk lowered lookup, no counters; returns
        ``(linked, from_disk)``.  Caller holds ``self._lock``."""
        entry = self._mem_lowered.get(key)
        if entry is not None:
            fp, linked = entry
            if fp == fingerprint:
                return linked, False
        elif self.disk_dir is not None:
            path = self._lowered_path(key)
            if path.exists():
                entry = self._read_entry(path)
                if (isinstance(entry, tuple) and len(entry) == 2
                        and entry[0] == fingerprint):
                    fp, linked = entry
                    self._mem_lowered[key] = (fp, linked)
                    return linked, True
        return None, False

    def get_lowered(self, key: Tuple[str, str],
                    fingerprint: str) -> Optional[LinkedConfig]:
        with self._lock:
            linked, from_disk = self._load_lowered(key, fingerprint)
            if linked is None:
                self.stats.lowered_misses += 1
                return None
            self.stats.lowered_hits += 1
            if from_disk:
                self.stats.lowered_disk_hits += 1
            return linked

    def peek_lowered(self, key: Tuple[str, str],
                     fingerprint: str) -> Optional[LinkedConfig]:
        """``get_lowered`` without counters (see ``peek``)."""
        with self._lock:
            return self._load_lowered(key, fingerprint)[0]

    def put_lowered(self, key: Tuple[str, str], linked: LinkedConfig,
                    fingerprint: str, *, memory_only: bool = False) -> None:
        with self._lock:
            self._mem_lowered[key] = (fingerprint, linked)
            self.stats.lowered_stores += 1
        if memory_only or self.disk_dir is None:
            return
        self._write_atomic(self._lowered_path(key), (fingerprint, linked))

    # -- aggregate view ------------------------------------------------------
    def _disk_entry_counts(self) -> Tuple[int, int]:
        """(mapping, lowered) entry counts on disk; (0, 0) when diskless."""
        if self.disk_dir is None or not Path(self.disk_dir).is_dir():
            return (0, 0)
        names = [p.name for p in Path(self.disk_dir).glob("*.pkl")]
        lowered = sum(1 for n in names if n.endswith("_low.pkl"))
        return (len(names) - lowered, lowered)

    def clear_memory(self) -> None:
        """Drop the in-process layer (disk entries survive) — lets tests
        exercise the cross-process path without spawning a process."""
        with self._lock:
            self._mem.clear()
            self._mem_lowered.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)


_default: Optional[MappingCache] = None


def default_cache() -> MappingCache:
    """The process-wide cache ``compile()`` uses when none is passed.
    Its aggregate stats join the metrics registry as the
    ``mapping_cache`` source (reads through this accessor, so swapping
    the default cache needs no re-registration)."""
    global _default
    if _default is None:
        from repro import obs
        _default = MappingCache()
        obs.registry().register_source(
            "mapping_cache", lambda: default_cache().stats(), replace=True)
    return _default


def set_default_cache(cache: Optional[MappingCache]) -> MappingCache:
    """Swap the process-wide cache (e.g. a tmp-dir cache in tests);
    returns the previous one so callers can restore it."""
    global _default
    prev = default_cache()
    _default = cache
    return prev

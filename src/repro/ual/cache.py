"""Mapping cache: memoizes compile artifacts per ``(program, target)`` pair.

Modulo mapping dominates the toolchain's wall time (seconds to minutes per
kernel, with restarts), yet the suite compiles the same kernels onto the
same fabrics over and over.  The cache keys on
``(program.digest, target.digest)`` — both stable content hashes — and
keeps results in two layers:

  * an in-process dict (free hits within one run),
  * an on-disk pickle directory (hits across processes: test runs,
    benchmark re-runs, CI re-tries).

Two artifact kinds live side by side under the same key:

  * the ``MapResult`` (placements + machine configuration) from the
    mapping pass, and
  * the **lowered artifact** (``core.lowering.LinkedConfig`` dense
    tables) from the lowering pass — lower once, run many: a warm
    compile re-lowers nothing, and every backend executing the same
    configuration shares one set of tables.

Hit/miss/store counters are exposed for tests to assert cache behavior.
The disk layer defaults to ``$REPRO_UAL_CACHE`` or ``artifacts/ual_cache``
next to the repo; pass ``MappingCache(disk_dir=None)`` for a purely
in-process cache.
"""
from __future__ import annotations

import os
import pickle
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Optional, Tuple

from repro.core.lowering import LOWERING_VERSION, LinkedConfig
from repro.core.mapper import MAPPER_VERSION, MapResult

#: bump to invalidate on-disk entries when the MapResult/MachineConfig
#: pickle format changes; mapper *behavior* changes are covered separately
#: by core.mapper.MAPPER_VERSION (also folded into the entry name)
CACHE_VERSION = 1


def default_cache_dir() -> Path:
    env = os.environ.get("REPRO_UAL_CACHE")
    if env:
        return Path(env)
    # src/repro/ual/cache.py -> repo root / artifacts / ual_cache, but only
    # when we actually live in a source checkout; for an installed package
    # parents[3] is the Python prefix, which must not be written to
    root = Path(__file__).resolve().parents[3]
    if (root / "pyproject.toml").exists() or (root / ".git").exists():
        return root / "artifacts" / "ual_cache"
    xdg = os.environ.get("XDG_CACHE_HOME", str(Path.home() / ".cache"))
    return Path(xdg) / "repro_ual"


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    stores: int = 0
    disk_hits: int = 0
    # -- lowered-artifact layer (counted separately: a compile can hit the
    # mapping entry while still lowering cold, and tests assert each) ------
    lowered_hits: int = 0
    lowered_misses: int = 0
    lowered_stores: int = 0
    lowered_disk_hits: int = 0

    def reset(self) -> None:
        self.hits = self.misses = self.stores = self.disk_hits = 0
        self.lowered_hits = self.lowered_misses = 0
        self.lowered_stores = self.lowered_disk_hits = 0


@dataclass
class MappingCache:
    disk_dir: Optional[Path] = field(default_factory=default_cache_dir)
    stats: CacheStats = field(default_factory=CacheStats)
    _mem: Dict[Tuple[str, str], MapResult] = field(default_factory=dict)
    _mem_lowered: Dict[Tuple[str, str],
                       Tuple[str, LinkedConfig]] = field(
        default_factory=dict)

    def __post_init__(self) -> None:
        if self.disk_dir is not None:
            self.disk_dir = Path(self.disk_dir)

    def _path(self, key: Tuple[str, str]) -> Path:
        pdig, tdig = key
        return (self.disk_dir /
                f"v{CACHE_VERSION}m{MAPPER_VERSION}_"
                f"{pdig[:20]}_{tdig[:20]}.pkl")

    def _lowered_path(self, key: Tuple[str, str]) -> Path:
        pdig, tdig = key
        return (self.disk_dir /
                f"v{CACHE_VERSION}m{MAPPER_VERSION}l{LOWERING_VERSION}_"
                f"{pdig[:20]}_{tdig[:20]}_low.pkl")

    def get(self, key: Tuple[str, str]) -> Optional[MapResult]:
        if key in self._mem:
            self.stats.hits += 1
            return self._mem[key]
        if self.disk_dir is not None:
            path = self._path(key)
            if path.exists():
                try:
                    with path.open("rb") as f:
                        result = pickle.load(f)
                except (OSError, pickle.UnpicklingError, EOFError,
                        AttributeError, ImportError):
                    pass  # stale/corrupt entry: treat as a miss
                else:
                    self._mem[key] = result
                    self.stats.hits += 1
                    self.stats.disk_hits += 1
                    return result
        self.stats.misses += 1
        return None

    def contains(self, key: Tuple[str, str]) -> bool:
        """Whether ``get(key)`` would hit (either layer), without touching
        the hit/miss counters — a peek for schedulers (``compile_many``)
        deciding what still needs to be mapped."""
        if key in self._mem:
            return True
        return self.disk_dir is not None and self._path(key).exists()

    def put(self, key: Tuple[str, str], result: MapResult, *,
            memory_only: bool = False) -> None:
        self._mem[key] = result
        self.stats.stores += 1
        if memory_only or self.disk_dir is None:
            return
        self.disk_dir.mkdir(parents=True, exist_ok=True)
        path = self._path(key)
        tmp = path.with_suffix(f".tmp.{os.getpid()}")
        with tmp.open("wb") as f:
            pickle.dump(result, f, protocol=pickle.HIGHEST_PROTOCOL)
        tmp.replace(path)  # atomic: concurrent compiles never read torn files

    # -- lowered-artifact layer (same two-layer contract, same key) ---------
    # Entries are stored WITH the fingerprint of the configuration they
    # were lowered from: the wall-clock-budgeted mapper can produce
    # different configs for the same key (another process, a re-map after
    # a lost mapping pickle), and a mapping/lowered pair on disk may be
    # written by two racing compiles — a fingerprint mismatch is a miss,
    # never a silently-wrong artifact.
    def get_lowered(self, key: Tuple[str, str],
                    fingerprint: str) -> Optional[LinkedConfig]:
        entry = self._mem_lowered.get(key)
        if entry is not None:
            fp, linked = entry
            if fp == fingerprint:
                self.stats.lowered_hits += 1
                return linked
        elif self.disk_dir is not None:
            path = self._lowered_path(key)
            if path.exists():
                try:
                    with path.open("rb") as f:
                        fp, linked = pickle.load(f)
                except (OSError, pickle.UnpicklingError, EOFError,
                        AttributeError, ImportError, TypeError, ValueError):
                    pass  # stale/corrupt entry: treat as a miss
                else:
                    if fp == fingerprint:
                        self._mem_lowered[key] = (fp, linked)
                        self.stats.lowered_hits += 1
                        self.stats.lowered_disk_hits += 1
                        return linked
        self.stats.lowered_misses += 1
        return None

    def put_lowered(self, key: Tuple[str, str], linked: LinkedConfig,
                    fingerprint: str, *, memory_only: bool = False) -> None:
        self._mem_lowered[key] = (fingerprint, linked)
        self.stats.lowered_stores += 1
        if memory_only or self.disk_dir is None:
            return
        self.disk_dir.mkdir(parents=True, exist_ok=True)
        path = self._lowered_path(key)
        tmp = path.with_suffix(f".tmp.{os.getpid()}")
        with tmp.open("wb") as f:
            pickle.dump((fingerprint, linked), f,
                        protocol=pickle.HIGHEST_PROTOCOL)
        tmp.replace(path)  # atomic: concurrent compiles never read torn files

    def clear_memory(self) -> None:
        """Drop the in-process layer (disk entries survive) — lets tests
        exercise the cross-process path without spawning a process."""
        self._mem.clear()
        self._mem_lowered.clear()

    def __len__(self) -> int:
        return len(self._mem)


_default: Optional[MappingCache] = None


def default_cache() -> MappingCache:
    """The process-wide cache ``compile()`` uses when none is passed."""
    global _default
    if _default is None:
        _default = MappingCache()
    return _default


def set_default_cache(cache: Optional[MappingCache]) -> MappingCache:
    """Swap the process-wide cache (e.g. a tmp-dir cache in tests);
    returns the previous one so callers can restore it."""
    global _default
    prev = default_cache()
    _default = cache
    return prev

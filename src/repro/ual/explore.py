"""Parallel design-space exploration over the UAL compile pipeline.

The paper positions the toolchain as the substrate for DSE (REVAMP-style
sweeps of fabric variants); this module is the front-end:

  * ``compile_many(pairs, workers=N)`` — compile a grid of
    ``(Program, Target)`` pairs, fanning the *unique cold* mapping
    problems over a process pool.  Identical ``(program.digest,
    target.digest)`` pairs map exactly once, and pairs already in the
    mapping cache (in-process or on disk) never enter the pool at all —
    the sweep pays exactly one modulo mapping per unique design point.
  * ``explore(program, space, workers=N)`` — sweep fabric builders ×
    mapper strategies × knobs, and return a Pareto report over
    (II, mapper wall-time, GOPS/W via the PACE-calibrated
    ``core.energy`` model).

Worker payloads are ``(laid DFG, fabric, mapper knobs)`` — deliberately
not the full ``Program``/``Target`` (whose ``make_mem``/``label_fn``
hooks may be unpicklable lambdas).  Targets that cannot be fanned out
(spatial fabrics, mapping-free backends, ``label_fn`` carriers) compile
serially in the parent, through the same pipeline.  The pool uses the
``fork`` start method where available so strategies registered at
runtime (``ual.register_strategy``) are visible in the workers.
"""
from __future__ import annotations

import itertools
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import (Dict, Iterable, List, Optional, Sequence, Tuple, Union)

from repro.core.adl import Fabric
from repro.core.energy import point_efficiency_gops_w
from repro.core.mapper import MapResult, map_dfg
from repro.ual.backends import get_backend
from repro.ual.cache import MappingCache, default_cache
from repro.ual.compiler import compile as _compile
from repro.ual.executable import Executable
from repro.ual.program import Program
from repro.ual.target import FABRICS, Target

Pair = Tuple[Program, Target]


def _map_worker(payload) -> MapResult:
    """Process-pool entry: one cold modulo mapping (module-level so it
    pickles under every start method)."""
    laid, fabric, knobs = payload
    return map_dfg(laid, fabric, **knobs)


def _pool(workers: int) -> ProcessPoolExecutor:
    if "fork" in multiprocessing.get_all_start_methods():
        ctx = multiprocessing.get_context("fork")
        return ProcessPoolExecutor(max_workers=workers, mp_context=ctx)
    return ProcessPoolExecutor(max_workers=workers)


def compile_many(pairs: Iterable[Pair], workers: Optional[int] = None,
                 *, cache: Optional[MappingCache] = None,
                 use_cache: bool = True) -> List[Executable]:
    """Compile every ``(program, target)`` pair; returns executables in
    input order.

    Cache-aware dedup before any work is scheduled: pairs whose
    ``(program.digest, target.digest)`` is already cached are served warm
    and never enter the pool; the remaining *unique* cold keys map exactly
    once each, in parallel across ``workers`` processes (default: the CPU
    count).  With ``use_cache=False`` every pair compiles cold and
    serially — there is no dedup identity to share results through.
    """
    pairs = list(pairs)
    c = cache if cache is not None else default_cache()
    cold: Dict[Tuple[str, str], List[int]] = {}
    for i, (program, target) in enumerate(pairs):
        backend = get_backend(target.backend)   # fail fast on unknown names
        fan_out = (target.fabric.temporal and backend.requires_config
                   and use_cache and target.label_fn is None)
        if fan_out and not c.contains((program.digest, target.digest)):
            cold.setdefault((program.digest, target.digest), []).append(i)

    pool_results: Dict[Tuple[str, str], MapResult] = {}
    if cold:
        items = []
        for key, idxs in cold.items():
            program, target = pairs[idxs[0]]
            items.append((key, (program.laid, target.fabric,
                                dict(ii_max=target.ii_max, seed=target.seed,
                                     strategy=target.strategy,
                                     max_restarts=target.max_restarts,
                                     time_budget_s=target.time_budget_s))))
        n = max(1, min(workers or os.cpu_count() or 1, len(items)))
        if n == 1:
            results = [_map_worker(p) for _, p in items]
        else:
            with _pool(n) as pool:
                results = list(pool.map(_map_worker,
                                        [p for _, p in items]))
        for (key, _), result in zip(items, results):
            # same persistence contract as the mapping pass: failures are
            # memoized in-process only, never pinned on disk
            c.put(key, result, memory_only=not result.success)
            pool_results[key] = result

    exes = [_compile(program, target, cache=c if use_cache else None,
                     use_cache=use_cache)
            for program, target in pairs]

    # the first pair of each pool-mapped key did pay the mapping (in a
    # worker) — attribute the true cost instead of the warm-hit it saw
    for key, idxs in cold.items():
        result = pool_results[key]
        info = exes[idxs[0]].compile_info
        info.cache_hit = False
        info.mapper_restarts = result.restarts
        for rec in info.passes:
            if rec.name == "mapping":
                # keep wall_s >= sum(pass times): swap the warm-lookup time
                # for the worker's true mapping time in both places
                info.wall_s += result.wall_s - rec.wall_s
                rec.wall_s = result.wall_s
                rec.stats = dict(rec.stats, cache="pool",
                                 restarts=result.restarts)
    return exes


# ---------------------------------------------------------------------------
# explore(): sweep a design space, report the Pareto frontier
# ---------------------------------------------------------------------------

FabricSpec = Union[str, Tuple[str, Dict[str, object]], Fabric]


@dataclass(eq=False)                 # identity eq: points wrap executables
class DesignPoint:
    """One swept configuration with its measured/modelled objectives."""

    fabric: str
    strategy: str
    knobs: Dict[str, object]
    success: bool
    II: Optional[int]
    mii: Optional[int]
    mapper_wall_s: float         # cost of the mapping itself (cached or not)
    restarts: int
    gops_w: Optional[float]      # PACE-calibrated model at the point's util
    cache_hit: bool
    pass_times: Dict[str, float]
    executable: Executable = field(repr=False)

    def row(self) -> list:
        return [self.fabric, self.strategy,
                " ".join(f"{k}={v}" for k, v in self.knobs.items()) or "-",
                self.II if self.success else "FAIL",
                f"{self.mapper_wall_s:.2f}s",
                f"{self.gops_w:.0f}" if self.gops_w is not None else "-",
                "warm" if self.cache_hit else "cold"]


@dataclass
class ExploreReport:
    """``explore()``'s result: every point, the Pareto subset, sweep stats."""

    program: str
    points: List[DesignPoint]
    pareto: List[DesignPoint]
    wall_s: float
    n_mapped: int                # modulo mappings actually performed
    n_warm: int                  # points served from the cache

    def render(self) -> str:
        if not self.points:
            return "explore: no design points"
        rows = [p.row() + ["*" if p in self.pareto else ""]
                for p in self.points]
        head = ["fabric", "strategy", "knobs", "II", "map", "GOPS/W",
                "cache", "pareto"]
        widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
                  for i, h in enumerate(head)]

        def line(vals):
            return "  ".join(str(v).rjust(w) for v, w in zip(vals, widths))

        table = "\n".join([line(head), line(["-" * w for w in widths])]
                          + [line(r) for r in rows])
        return (table
                + f"\n{len(self.pareto)} Pareto-optimal point(s); "
                  f"{self.n_mapped} mapping(s) paid for "
                  f"{len(self.points)} point(s) in {self.wall_s:.1f}s")

    def to_json(self) -> dict:
        return {
            "program": self.program,
            "wall_s": self.wall_s,
            "n_mapped": self.n_mapped,
            "n_warm": self.n_warm,
            "points": [{
                "fabric": p.fabric, "strategy": p.strategy,
                "knobs": {k: str(v) for k, v in p.knobs.items()},
                "success": p.success, "II": p.II, "mii": p.mii,
                "mapper_wall_s": p.mapper_wall_s, "restarts": p.restarts,
                "gops_w": p.gops_w, "cache_hit": p.cache_hit,
                "pass_times": p.pass_times,
                "pareto": p in self.pareto,
            } for p in self.points],
        }


def _resolve_fabric(spec: FabricSpec) -> Fabric:
    if isinstance(spec, Fabric):
        return spec
    if isinstance(spec, str):
        name, kwargs = spec, {}
    else:
        name, kwargs = spec
    if name not in FABRICS:
        raise KeyError(f"unknown fabric {name!r}; "
                       f"registered: {sorted(FABRICS)}")
    return FABRICS[name](**kwargs)


def space_targets(space: Dict[str, Sequence]) -> List[Tuple[Target, Dict]]:
    """Cartesian product of a design space into concrete Targets.

    ``space`` axes: ``fabric`` (required — names, ``(name, kwargs)`` pairs
    or ``Fabric`` instances), ``strategy`` (default ``("adaptive",)``),
    ``backend`` (default ``"sim"``), plus any mapper-knob field of
    ``Target`` (``seed``, ``ii_max``, ``max_restarts``, ``time_budget_s``).
    """
    space = dict(space)
    fabrics = space.pop("fabric", None)
    if not fabrics:
        raise ValueError("space needs a non-empty 'fabric' axis")
    strategies = space.pop("strategy", ("adaptive",))
    if isinstance(strategies, str):
        strategies = (strategies,)
    backends = space.pop("backend", ("sim",))
    if isinstance(backends, str):
        backends = (backends,)
    knob_names = {f.name for f in Target.__dataclass_fields__.values()
                  if f.name not in ("fabric", "backend", "strategy",
                                    "label_fn")}
    bad = set(space) - knob_names
    if bad:
        raise ValueError(f"unknown space axes {sorted(bad)}; "
                         f"knob axes: {sorted(knob_names)}")
    axes = list(space)
    out = []
    for spec in fabrics:
        fabric = _resolve_fabric(spec)
        for strat, backend, *vals in itertools.product(
                strategies, backends, *space.values()):
            knobs = dict(zip(axes, vals))
            out.append((Target(fabric, backend=backend, strategy=strat,
                               **knobs), knobs))
    if not out:
        raise ValueError("design space is empty: every axis needs at "
                         "least one value")
    return out


def _dominates(a: DesignPoint, b: DesignPoint) -> bool:
    ge = (a.II <= b.II and a.mapper_wall_s <= b.mapper_wall_s
          and (a.gops_w or 0.0) >= (b.gops_w or 0.0))
    gt = (a.II < b.II or a.mapper_wall_s < b.mapper_wall_s
          or (a.gops_w or 0.0) > (b.gops_w or 0.0))
    return ge and gt


def explore(program: Program, space: Dict[str, Sequence], *,
            workers: Optional[int] = None,
            cache: Optional[MappingCache] = None,
            use_cache: bool = True, vdd: float = 0.6) -> ExploreReport:
    """Sweep ``program`` over a fabric × strategy × knob design space.

    Compiles every point through ``compile_many`` (parallel, deduped,
    cache-aware — each unique digest pair maps exactly once) and scores it
    on (II, mapper wall-time, GOPS/W at ``vdd``); the report carries every
    point's per-pass timings and the Pareto-optimal subset
    (min II, min mapping time, max GOPS/W)::

        report = ual.explore(program, {
            "fabric": [("hycube", dict(rows=4, cols=4)),
                       ("n2n", dict(rows=4, cols=4)), "pace"],
            "strategy": ["adaptive", "sa"],
            "seed": [0, 1],
        }, workers=4)
        print(report.render())
    """
    t0 = time.perf_counter()
    targets = space_targets(space)
    exes = compile_many([(program, t) for t, _ in targets], workers=workers,
                        cache=cache, use_cache=use_cache)
    n_ops = len(program.laid.nodes)
    points = []
    for (target, knobs), exe in zip(targets, exes):
        r = exe.map_result
        ok = exe.success and r is not None
        ii = r.II if ok else None
        wall = (r.wall_s if r is not None and r.wall_s > 0
                else exe.compile_info.pass_times.get("mapping", 0.0))
        points.append(DesignPoint(
            fabric=target.fabric.name, strategy=target.strategy,
            knobs=knobs, success=ok, II=ii,
            mii=r.mii if r is not None else None,
            mapper_wall_s=wall,
            restarts=r.restarts if r is not None else 0,
            gops_w=(point_efficiency_gops_w(n_ops, ii, target.fabric.n_pes,
                                            vdd=vdd) if ok else None),
            cache_hit=exe.compile_info.cache_hit,
            pass_times=exe.compile_info.pass_times,
            executable=exe))
    feasible = [p for p in points if p.success]
    pareto = [p for p in feasible
              if not any(_dominates(q, p) for q in feasible)]
    n_mapped = sum(1 for p in points
                   if p.success and not p.cache_hit
                   and p.executable.target.fabric.temporal)
    return ExploreReport(program.name, points, pareto,
                         time.perf_counter() - t0, n_mapped,
                         sum(1 for p in points if p.cache_hit))

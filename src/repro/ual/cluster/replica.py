"""ReplicaPool + Router: who runs the next micro-batch, and where.

A ``ReplicaSlot`` is one execution replica — a worker thread, optionally
pinned to one jax device (``slot.device``), executing micro-batches
against the per-class warm Executables the ``Service`` shares across
slots (the *engines* under them are per-device: ``engine_for(device=)``
keys the trace cache on placement, so replicas never contend on one
device's queue).

The ``Router`` makes two decisions:

  * **route** (dispatcher side) — a flush-ready micro-batch goes to the
    least-loaded slot (queued + in-flight); among equally-loaded slots,
    one that has already executed this compatibility class wins
    (*affinity*: its engine is warm for the class), counted separately
    in ``decisions`` so tests can see both policies fire.
  * **pull** (worker side) — a slot takes its own queue first; when
    empty it **steals the oldest batch from the most-loaded sibling**
    (work conservation: an idle replica never watches a busy one's
    backlog grow).  Steals are counted per slot and globally.

The router is also the idle signal for the *coalescer-side* stealing in
``Service``: when ``idle_slots() > 0`` the dispatcher may flush a
partial bucket early (``Coalescer.steal_oldest``) instead of letting
idle capacity wait out ``max_wait_ms`` — that count lives in
``early_flushes``.

``stats()`` is the per-replica view the cluster front-end merges:
batches / samples / busy seconds / steals per slot, plus the decision
counters.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional, Sequence


class ReplicaSlot:
    """One replica's routing state (guarded by the Router's lock)."""

    def __init__(self, index: int, device=None) -> None:
        self.index = index
        self.device = device              # jax device, or None (default)
        self.queue: deque = deque()       # routed (key, batch) pairs
        self.in_flight = 0                # batches being executed now
        self.batches = 0                  # completed batches
        self.samples = 0                  # completed samples
        self.busy_s = 0.0                 # wall seconds inside sweeps
        self.steals = 0                   # batches this slot stole
        self.warm: set = set()            # class keys this slot has run

    def load(self) -> int:
        """Routing load: queued + executing batches."""
        return len(self.queue) + self.in_flight

    def stats(self) -> Dict[str, object]:
        busy = self.busy_s
        return {
            "device": (str(self.device) if self.device is not None
                       else None),
            "batches": self.batches,
            "samples": self.samples,
            "busy_s": round(busy, 4),
            "samples_per_s": (round(self.samples / busy, 1) if busy > 0
                              else 0.0),
            "steals": self.steals,
            "queued": len(self.queue),
            "in_flight": self.in_flight,
            "warm_classes": len(self.warm),
        }


class Router:
    """Least-loaded dispatch + idle work stealing over N replica slots."""

    def __init__(self, slots: int, devices: Optional[Sequence] = None
                 ) -> None:
        if slots < 1:
            raise ValueError(f"need at least 1 replica slot, got {slots}")
        devs = list(devices) if devices else [None] * slots
        if devices and len(devs) < slots:
            raise ValueError(f"{slots} slots but only {len(devs)} devices")
        self.slots = [ReplicaSlot(i, devs[i] if devices else None)
                      for i in range(slots)]
        self._cond = threading.Condition()
        self._stopped = False
        self.decisions: Dict[str, int] = {"affinity": 0, "least_loaded": 0}
        self.steals = 0
        self.early_flushes = 0

    # -- dispatcher side ------------------------------------------------------
    def route(self, key, batch, *, early: bool = False) -> int:
        """Assign a flush-ready micro-batch to a slot; returns its index.

        Least-loaded wins; among ties, a slot already warm for ``key``
        (affinity).  ``early=True`` marks a coalescer-side early flush
        (idle capacity stole a partial bucket from the clock)."""
        with self._cond:
            min_load = min(s.load() for s in self.slots)
            cands = [s for s in self.slots if s.load() == min_load]
            warm = [s for s in cands if key in s.warm]
            if warm:
                slot = warm[0]
                self.decisions["affinity"] += 1
            else:
                slot = cands[0]
                self.decisions["least_loaded"] += 1
            if early:
                self.early_flushes += 1
            slot.queue.append((key, batch))
            self._cond.notify_all()
            return slot.index

    def idle_slots(self) -> int:
        """Slots with nothing queued and nothing executing — the
        dispatcher's signal that a partial bucket may flush early."""
        with self._cond:
            return sum(1 for s in self.slots if s.load() == 0)

    def queued(self) -> int:
        with self._cond:
            return sum(len(s.queue) for s in self.slots)

    # -- worker side ----------------------------------------------------------
    def pull(self, index: int, timeout: Optional[float] = None):
        """Next ``(key, batch, stolen)`` for slot ``index``; None on
        timeout, or on stop once every queue has drained.

        Own queue first; otherwise steal the OLDEST batch from the
        most-loaded sibling — FIFO across the pool, so stealing reduces
        tail latency instead of reordering it."""
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        slot = self.slots[index]
        with self._cond:
            while True:
                if slot.queue:
                    key, batch = slot.queue.popleft()
                    slot.in_flight += 1
                    slot.warm.add(key)
                    return key, batch, False
                victim = max(
                    (s for s in self.slots if s.queue),
                    key=lambda s: len(s.queue), default=None)
                if victim is not None:
                    key, batch = victim.queue.popleft()
                    slot.in_flight += 1
                    slot.warm.add(key)
                    slot.steals += 1
                    self.steals += 1
                    return key, batch, True
                if self._stopped:
                    return None
                if deadline is None:
                    self._cond.wait()
                else:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        return None
                    self._cond.wait(remaining)

    def done(self, index: int, n_samples: int, busy_s: float) -> None:
        """A slot finished a batch; updates load + throughput counters."""
        with self._cond:
            slot = self.slots[index]
            slot.in_flight -= 1
            slot.batches += 1
            slot.samples += n_samples
            slot.busy_s += busy_s
            self._cond.notify_all()

    # -- lifecycle ------------------------------------------------------------
    def stop(self) -> None:
        """No more routes are coming: pulls drain remaining queues, then
        return None (workers exit)."""
        with self._cond:
            self._stopped = True
            self._cond.notify_all()

    # -- observability --------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        with self._cond:
            return {
                "replicas": len(self.slots),
                "decisions": dict(self.decisions),
                "steals": self.steals,
                "early_flushes": self.early_flushes,
                "slots": [s.stats() for s in self.slots],
            }


__all__ = ("ReplicaSlot", "Router")

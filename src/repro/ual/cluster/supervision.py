"""Worker supervision policy: respawn budgets, backoff, uptime.

The cluster watchdog used to only *evict* dead workers — every crash
permanently shrank the pool.  This module is the parent-side policy
state behind the healing watchdog: a ``RestartPolicy`` (how many
respawns a worker slot gets, how long to back off between them) and a
``WorkerState`` per slot (deaths, restarts, due times, recovery
timing).  It is the serving-side sibling of the training stack's
checkpoint/restart supervisor (``repro.runtime.fault_tolerance``):
same philosophy — bounded restarts, failures as recorded events — but
for stateless pure-compute workers there is no checkpoint to restore;
a respawned worker rejoins warm off the shared artifact cache.

All mutation happens under the owning ``ClusterService``'s lock; this
module holds no locks of its own.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class RestartPolicy:
    """How a cluster heals dead workers.

    Each worker slot gets ``max_restarts`` respawns over the cluster's
    lifetime; the i-th respawn waits ``backoff_base_s * factor**i``
    (capped at ``backoff_max_s``) after the death is detected, so a
    crash-looping worker consumes its budget slowly instead of spinning.
    ``max_restarts=0`` restores the old evict-only behavior.
    """

    max_restarts: int = 3
    backoff_base_s: float = 0.25
    backoff_factor: float = 2.0
    backoff_max_s: float = 10.0

    def backoff_s(self, restarts: int) -> float:
        """Delay before the (restarts+1)-th respawn of a worker."""
        return min(self.backoff_base_s * (self.backoff_factor ** restarts),
                   self.backoff_max_s)

    def snapshot(self) -> Dict[str, object]:
        return {"max_restarts": self.max_restarts,
                "backoff_base_s": self.backoff_base_s,
                "backoff_factor": self.backoff_factor,
                "backoff_max_s": self.backoff_max_s}


@dataclass
class WorkerState:
    """Supervision record for one worker slot (guarded by the cluster
    lock).  The watchdog drives the lifecycle:

        record_death -> (backoff elapses) -> respawning=True ->
        process spawned -> record_respawned -> worker 'ready' ->
        record_ready

    ``respawning`` marks a spawn in progress so ``shutdown()`` can wait
    for it and reap the new process instead of leaking it (the
    shutdown/respawn race).
    """

    started_at: Optional[float] = None    # last (re)spawn, perf_counter
    ready_at: Optional[float] = None      # last 'ready' handshake
    died_at: Optional[float] = None       # last detected death
    deaths: int = 0
    restarts: int = 0
    last_backoff_s: float = 0.0
    next_respawn_at: Optional[float] = None
    respawning: bool = False
    exhausted: bool = False               # restart budget spent
    last_recovery_s: Optional[float] = None

    def record_death(self, now: float,
                     policy: RestartPolicy) -> Optional[float]:
        """One detected death; schedules the respawn and returns its
        backoff, or None (and marks the slot exhausted) when the budget
        is spent."""
        self.deaths += 1
        self.died_at = now
        if self.restarts >= policy.max_restarts:
            self.exhausted = True
            self.next_respawn_at = None
            return None
        self.last_backoff_s = policy.backoff_s(self.restarts)
        self.next_respawn_at = now + self.last_backoff_s
        return self.last_backoff_s

    def record_respawned(self, now: float) -> None:
        """The replacement process has been spawned and installed."""
        self.restarts += 1
        self.started_at = now
        self.next_respawn_at = None
        self.respawning = False

    def record_ready(self, now: float) -> None:
        """The worker's 'ready' handshake arrived (initial or respawn).
        Recovery time is death-detection -> ready, the number the chaos
        bench bounds."""
        self.ready_at = now
        if self.died_at is not None:
            self.last_recovery_s = now - self.died_at

    def snapshot(self, now: Optional[float] = None,
                 alive: bool = False) -> Dict[str, object]:
        if now is None:
            now = time.perf_counter()
        return {
            "alive": alive,
            "deaths": self.deaths,
            "restarts": self.restarts,
            "uptime_s": (round(now - self.ready_at, 3)
                         if alive and self.ready_at is not None else None),
            "last_backoff_s": round(self.last_backoff_s, 3),
            "respawn_due_in_s": (round(max(0.0, self.next_respawn_at - now),
                                       3)
                                 if self.next_respawn_at is not None
                                 else None),
            "respawning": self.respawning,
            "exhausted": self.exhausted,
            "last_recovery_s": (round(self.last_recovery_s, 3)
                                if self.last_recovery_s is not None
                                else None),
        }


__all__ = ("RestartPolicy", "WorkerState")

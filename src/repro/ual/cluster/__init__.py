"""``repro.ual.cluster`` — sharded serving: replicas, routing, processes.

Three layers, smallest first:

  * ``replica`` — ``ReplicaSlot`` + ``Router``: least-loaded dispatch
    with class-affinity tiebreak and idle work stealing across an
    in-process pool of worker threads (used by ``Service(replicas=N)``).
  * ``ShardedKernelEngine`` (in ``repro.ual.engine``) — one jit trace
    shard_mapped over the batch axis of every local device.
  * ``service`` — ``ClusterService``: N worker processes behind one
    front-end, sharing the on-disk artifact cache and merging their
    ``stats()`` into a single cluster view.
"""
from repro.ual.cluster.replica import ReplicaSlot, Router
from repro.ual.cluster.service import ClusterService
from repro.ual.cluster.supervision import RestartPolicy, WorkerState

__all__ = ("ClusterService", "ReplicaSlot", "RestartPolicy", "Router",
           "WorkerState")

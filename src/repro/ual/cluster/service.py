"""``ClusterService`` — the multi-process serving front-end.

One parent process routes single-sample requests to N **worker
processes**, each running a full in-process ``Service`` (queue ->
coalesce -> batched sweep, optionally replicated over devices).  The
pieces:

  * **front-end routing** (``submit``) — least-loaded worker by
    in-flight count; among ties, a worker that has already registered
    the request's compatibility class wins (its Executable and engine
    traces are warm).  Same policy as the in-process ``Router``, one
    level up.
  * **lazy class registration** — the first request of a class on a
    worker ships the ``Program`` (with its unpicklable ``make_mem``
    generator stripped — the digest ignores it) and ``Target`` once;
    later requests send only arrays.
  * **shared artifact cache** — every worker opens the same on-disk
    ``MappingCache`` directory.  With the cache's cross-process per-key
    locks, a cold tenant pays ONE mapping + lowering cluster-wide; the
    other workers block briefly and load the artifact.
  * **collector thread** (parent) — drains the workers' outbox and
    resolves the parent-side ``Response`` futures, so ``submit`` callers
    use the exact same future API as the in-process service.
  * **watchdog thread** (parent) — the self-healing loop.  A dead
    worker's in-flight requests are **transparently re-dispatched** to
    live workers (safe: pure compute keyed on content digests, so a
    duplicate execution is idempotent) — bounded by ``max_retries`` and
    never past the request's deadline, with each hop visible as a
    ``retry`` obs span and counted in ``fut.info["retries"]``.  The
    worker itself is **respawned** under the ``RestartPolicy``
    (exponential backoff, bounded restart budget) and rejoins the
    routing set warm: its compatibility classes are re-registered and
    the artifacts re-load from the shared disk cache, no re-mapping.
    Only when the retry budget is exhausted (or no worker is live) does
    a caller see a ``worker-died`` verdict — every submitted future
    resolves or carries a verdict, none is ever lost or stuck.
    ``stats()["supervision"]`` reports deaths/restarts/backoff/uptime
    per worker.
  * **merged stats** (``stats()``) — one cluster view: aggregate
    completed / samples-per-second / rejects, conservative p50/p99
    (worst worker), front-end routing decisions, plus each worker's full
    ``Service.stats()`` snapshot (including its replica router, when
    replicated) under ``per_worker``.

Workers are started with the ``spawn`` method: forking after jax has
initialized deadlocks, and spawn keeps each worker's jax runtime (and
any ``XLA_FLAGS`` device forcing in ``worker_env``) independent of the
parent's.
"""
from __future__ import annotations

import dataclasses
import itertools
import multiprocessing as mp
import os
import queue as queue_mod
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.ual.cluster.supervision import RestartPolicy, WorkerState

#: how often the watchdog polls worker liveness
_WATCH_TICK_S = 0.2


def merge_latency(snaps: Dict[int, Dict[str, object]]) -> Dict[str, object]:
    """Merge per-worker latency into cluster percentiles.

    Each snapshot may carry a raw ``latency_window_ms`` sample list
    (shipped by workers; POPPED here so it does not bloat the
    ``per_worker`` view).  Cluster ``p50_ms``/``p99_ms`` are computed
    over the concatenated samples — real percentiles of the merged
    distribution — while ``worst_worker_p99_ms`` keeps the old
    conservative max-of-workers number for soak-gate continuity.
    Workers that shipped no window (older snapshot shape) fall back to
    their pre-computed percentiles via the max path only.
    """
    samples: List[float] = []
    for s in snaps.values():
        samples.extend(s.pop("latency_window_ms", None) or [])
    p50s = [s["p50_ms"] for s in snaps.values()
            if s.get("p50_ms") is not None]
    p99s = [s["p99_ms"] for s in snaps.values()
            if s.get("p99_ms") is not None]
    p50 = obs.percentile(samples, 50)
    p99 = obs.percentile(samples, 99)
    return {
        "p50_ms": (round(p50, 3) if p50 is not None
                   else (max(p50s) if p50s else None)),
        "p99_ms": (round(p99, 3) if p99 is not None
                   else (max(p99s) if p99s else None)),
        "worst_worker_p99_ms": max(p99s) if p99s else None,
        "latency_samples_merged": len(samples),
    }


def _worker_main(widx: int, inbox, outbox, cfg: Dict[str, object]) -> None:
    """One worker process: env -> Service -> message loop.

    Module-level (spawn target must be importable), and ALL repro/jax
    imports happen here, after ``cfg["env"]`` lands in ``os.environ`` —
    so per-worker ``XLA_FLAGS`` (e.g. ``forced_device_env``) are set
    before jax ever loads in this process.
    """
    os.environ.update(cfg.get("env") or {})
    from repro import obs
    from repro.ual import faults
    from repro.ual.cache import MappingCache
    from repro.ual.service import Service, ServiceRejected

    # fault plans ride the env (REPRO_UAL_FAULTS) exactly like tracing;
    # binding the worker index arms worker-targeted kill specs
    faults.set_worker_index(widx)

    cache = (MappingCache(disk_dir=cfg["cache_dir"])
             if cfg.get("cache_dir") else None)
    svc = Service(max_batch=cfg["max_batch"],
                  max_wait_ms=cfg["max_wait_ms"],
                  max_queue=cfg["max_queue"],
                  workers=cfg["threads"],
                  replicas=cfg.get("replicas", 1),
                  warmup_buckets=cfg.get("warmup_buckets"),
                  cache=cache)
    classes: Dict[tuple, tuple] = {}

    def _forward(req_id: int):
        """Resolution callback: ship the local future's outcome home."""
        def cb(resp):
            exc = resp.exception(timeout=0)
            if exc is None:
                outbox.put(("done", req_id, widx, resp.result(0),
                            dict(resp.info)))
            elif isinstance(exc, ServiceRejected):
                outbox.put(("rej", req_id, widx, exc.reason, str(exc)))
            else:
                outbox.put(("err", req_id, widx,
                            f"{type(exc).__name__}: {exc}"))
        return cb

    outbox.put(("ready", widx))
    try:
        while True:
            msg = inbox.get()
            kind = msg[0]
            if kind == "stop":
                break
            if kind == "reg":
                _, class_id, program, target = msg
                classes[class_id] = (program, target)
            elif kind == "req":
                (_, req_id, class_id, mem, n_iters, tenant,
                 deadline_ms) = msg
                # armed kill_worker specs fire here, BEFORE submit: the
                # triggering request dies in flight with the process,
                # exactly the crash shape the parent's retry path heals
                faults.on_request()
                program, target = classes[class_id]
                resp = svc.submit(program, target, mem, n_iters=n_iters,
                                  tenant=tenant, deadline_ms=deadline_ms)
                resp.add_done_callback(_forward(req_id))
            elif kind == "stats":
                snap = svc.stats()
                # ship the raw latency window so the parent can merge
                # SAMPLES into real cluster percentiles (not max-of-p99)
                snap["latency_window_ms"] = \
                    svc._metrics.latency_window_ms()
                # spans ship BEFORE the stats reply: the shared outbox is
                # FIFO per worker, so once the parent's stats() collects
                # every reply, every span batch has been ingested too
                tr = obs.tracer()
                spans = tr.drain()
                if spans:
                    outbox.put(("spans", widx, spans, tr.epoch))
                outbox.put(("stats", widx, snap))
    finally:
        svc.shutdown(timeout=60.0)
        tr = obs.tracer()
        spans = tr.drain()
        if spans:
            try:
                outbox.put(("spans", widx, spans, tr.epoch))
            except (OSError, ValueError):
                pass
        outbox.put(("stopped", widx))


@dataclasses.dataclass
class _Flight:
    """Parent-side record of one in-flight request.  Retains the full
    submission payload (arrays, class, trip count, deadline) so the
    watchdog can re-dispatch it to a live worker if the one it rode
    dies — the transparent-retry path."""

    resp: object                      # parent-side Response future
    widx: int                         # worker currently carrying it
    tenant: str
    class_id: Tuple[str, str, str, int]
    arrays: Dict[str, np.ndarray]
    n_iters: int
    deadline: Optional[float]         # absolute parent perf_counter
    retries: int = 0


class ClusterService:
    """Sharded serving cluster: N worker processes, one front-end.

        cs = ual.ClusterService(workers=4, max_batch=32, max_wait_ms=2)
        fut = cs.submit(program, target, A=a, B=b, tenant="gemm-app")
        out = fut.result(timeout=60)      # same future API as Service
        print(cs.stats()["samples_per_s"], cs.stats()["workers"])
        cs.shutdown()

    ``worker_threads`` / ``replicas`` / ``warmup_buckets`` configure
    each worker's inner ``Service``; ``worker_env`` is merged into each
    worker's environment before jax loads there (device forcing goes
    here — see ``launch.mesh.forced_device_env``; fault plans via
    ``FaultPlan.to_env()``).  ``cache_dir`` is the shared on-disk
    artifact cache (defaults to the user-level cache directory); pass
    an empty string to disable disk sharing.

    ``restart_policy`` governs how dead workers are respawned
    (``RestartPolicy(max_restarts=0)`` restores evict-only);
    ``max_retries`` bounds how many times one in-flight request may be
    re-dispatched after worker deaths before its caller sees a
    ``worker-died`` verdict.
    """

    def __init__(self, workers: int = 2, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 1024,
                 worker_threads: int = 1, replicas: int = 1,
                 warmup_buckets: Optional[Sequence[int]] = None,
                 cache_dir: Optional[str] = None,
                 worker_env: Optional[Dict[str, str]] = None,
                 trace: bool = False,
                 restart_policy: Optional[RestartPolicy] = None,
                 max_retries: int = 2,
                 start: bool = True,
                 start_timeout_s: float = 180.0) -> None:
        if workers < 1:
            raise ValueError(f"need at least 1 worker, got {workers}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.n_workers = workers
        self.max_queue = max_queue
        self.start_timeout_s = start_timeout_s
        if cache_dir is None:
            from repro.ual.cache import default_cache_dir
            cache_dir = str(default_cache_dir())
        env = dict(worker_env or {})
        # trace=True (or a tracing parent) turns tracing on INSIDE the
        # spawned workers via the env; their span batches ride the
        # result pipe home and land in the parent tracer with one track
        # per worker (see export_chrome)
        if trace or obs.tracer().enabled:
            env.setdefault(obs.TRACE_ENV, "1")
        self._cfg = {
            "max_batch": max_batch, "max_wait_ms": max_wait_ms,
            "max_queue": max_queue, "threads": worker_threads,
            "replicas": replicas,
            "warmup_buckets": (tuple(warmup_buckets)
                               if warmup_buckets is not None else None),
            "cache_dir": cache_dir or None,
            "env": env,
        }

        self.restart_policy = (restart_policy if restart_policy is not None
                               else RestartPolicy())
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.max_retries = max_retries

        self._lock = threading.Lock()
        self._stats_cond = threading.Condition(self._lock)
        self._respawn_cond = threading.Condition(self._lock)
        self._closed = False
        self._started = False
        self._req_ids = itertools.count()
        self._inflight: Dict[int, _Flight] = {}
        self._load: List[int] = [0] * workers          # in-flight per worker
        self._registered: List[set] = [set() for _ in range(workers)]
        self._alive: List[bool] = [False] * workers
        self._sup: List[WorkerState] = [WorkerState() for _ in range(workers)]
        #: class_id -> (wire-ready Program, Target): what a respawned
        #: worker needs to re-register its classes (warm rejoin)
        self._class_info: Dict[Tuple[str, str, str, int],
                               Tuple[object, object]] = {}
        self.decisions: Dict[str, int] = {"affinity": 0, "least_loaded": 0,
                                          "retry": 0}
        self._stats_buf: Dict[int, Dict[str, object]] = {}
        self._stats_want: set = set()

        self._procs: List[mp.process.BaseProcess] = []
        self._inboxes: List[object] = []
        self._result_qs: List[object] = []
        self._threads: List[threading.Thread] = []
        self._ready = threading.Event()
        self._n_ready = 0
        self._n_stopped = 0
        self._watchdog_errors = 0
        self._watchdog_last_error = ""
        if start:
            self.start()

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> "ClusterService":
        with self._lock:
            if self._started or self._closed:
                return self
            self._started = True
        ctx = mp.get_context("spawn")
        for i in range(self.n_workers):
            # One result queue PER worker: a worker hard-killed mid-write
            # can tear the message stream, and on a shared pipe that
            # desyncs every other worker's completions too.  Isolated
            # pipes contain the damage to the dead worker, and once the
            # parent drops its write end (on "ready") a hard death reads
            # as a clean EOF instead of a stuck partial message.
            inbox, outq = ctx.Queue(), ctx.Queue()
            p = ctx.Process(target=_worker_main,
                            args=(i, inbox, outq, self._cfg),
                            name=f"ual-cluster-worker-{i}", daemon=True)
            p.start()
            self._sup[i].started_at = time.perf_counter()
            self._inboxes.append(inbox)
            self._result_qs.append(outq)
            self._procs.append(p)
        for i, outq in enumerate(self._result_qs):
            t = threading.Thread(target=self._collector_loop,
                                 args=(i, outq),
                                 name=f"ual-cluster-collect-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._watchdog_loop,
                             name="ual-cluster-watch", daemon=True)
        t.start()
        self._threads.append(t)
        if not self._ready.wait(self.start_timeout_s):
            self.shutdown(timeout=10.0)
            raise RuntimeError(
                f"cluster start timed out: {self._n_ready}/{self.n_workers} "
                f"workers ready within {self.start_timeout_s}s")
        return self

    def shutdown(self, timeout: Optional[float] = 120.0) -> None:
        """Stop admitting, let every worker flush, join, reject leftovers.

        Safe against an in-progress respawn: ``_closed`` is set first
        (no NEW respawn can start), then any spawn already underway is
        waited out — the watchdog either installs the replacement here
        (so the stop/join sweep below covers it) or, seeing ``_closed``,
        reaps it as an orphan itself.  Either way no worker process
        leaks and the watchdog stays joinable."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            started = self._started
        if not started:
            return
        with self._respawn_cond:
            deadline0 = time.perf_counter() + 15.0
            while any(st.respawning for st in self._sup):
                rem = deadline0 - time.perf_counter()
                if rem <= 0 or not self._respawn_cond.wait(rem):
                    break
        for i, inbox in enumerate(self._inboxes):
            try:
                inbox.put(("stop",))
            except (OSError, ValueError):
                pass
        deadline = (time.perf_counter() + timeout
                    if timeout is not None else None)
        for p in self._procs:
            rem = (max(0.0, deadline - time.perf_counter())
                   if deadline is not None else None)
            p.join(rem)
            if p.is_alive():
                p.terminate()
        # collectors/watchdog see _closed + dead procs and exit; give
        # the collectors a moment to drain late completions before
        # rejecting (snapshot under the lock: _respawn appends threads)
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(5.0)
        with self._lock:
            leftovers = list(self._inflight.values())
            self._inflight.clear()
        from repro.ual.service import ServiceRejected
        for fl in leftovers:
            fl.resp._resolve(exc=ServiceRejected(
                "shutdown", "cluster stopped before the response arrived"),
                retries=fl.retries)

    def __enter__(self) -> "ClusterService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- admission + routing --------------------------------------------------
    def submit(self, program, target,
               mem: Optional[Dict[str, np.ndarray]] = None, *,
               n_iters: Optional[int] = None, tenant: str = "default",
               deadline_ms: Optional[float] = None,
               **named: np.ndarray):
        """Admit one request; returns a ``Response`` future (same API as
        ``Service.submit``).  Routing: least-loaded worker, class-warm
        affinity tiebreak."""
        from repro.ual.service import ServiceRejected
        from repro.ual.service.queue import Response

        arrays = dict(mem or {})
        arrays.update(named)
        program.check_arrays(arrays)
        n = n_iters if n_iters is not None else program.n_iters
        class_id = (program.digest, target.digest, target.backend, n)
        resp = Response()
        now = time.perf_counter()
        deadline = (now + deadline_ms / 1e3 if deadline_ms is not None
                    else None)

        def _reject(reason: str, detail: str):
            resp._resolve(exc=ServiceRejected(reason, detail))
            return resp

        with self._lock:
            if self._closed:
                return _reject("shutdown", "cluster service is shut down")
            live = [i for i in range(self.n_workers) if self._alive[i]]
            if not live:
                return _reject("worker-died", "no live workers")
            if len(self._inflight) >= self.max_queue:
                return _reject("queue-full",
                               f"{len(self._inflight)} requests in flight "
                               f"(max_queue={self.max_queue})")
            min_load = min(self._load[i] for i in live)
            cands = [i for i in live if self._load[i] == min_load]
            warm = [i for i in cands if class_id in self._registered[i]]
            if warm:
                widx = warm[0]
                self.decisions["affinity"] += 1
            else:
                widx = cands[0]
                self.decisions["least_loaded"] += 1
            req_id = next(self._req_ids)
            self._inflight[req_id] = _Flight(
                resp=resp, widx=widx, tenant=tenant, class_id=class_id,
                arrays=arrays, n_iters=n, deadline=deadline)
            self._load[widx] += 1
            need_reg = class_id not in self._registered[widx]
            if need_reg:
                self._registered[widx].add(class_id)
            if class_id not in self._class_info:
                # make_mem is a convenience closure (often a lambda):
                # strip it for the wire — digest ignores it, workers
                # never call it.  Kept for the lifetime of the cluster
                # so respawned workers re-register their classes warm.
                self._class_info[class_id] = (
                    dataclasses.replace(program, make_mem=None), target)
            wire = self._class_info[class_id]
            inbox = self._inboxes[widx]
        if need_reg:
            inbox.put(("reg", class_id, wire[0], wire[1]))
        inbox.put(("req", req_id, class_id, arrays, n, tenant, deadline_ms))
        return resp

    # -- parent-side threads --------------------------------------------------
    def _settle(self, req_id: int) -> Optional[_Flight]:
        """Remove a finished request from the routing table.  Returns
        None for unknown ids — including a late duplicate completion of
        a request that was already retried and resolved elsewhere (the
        first resolution wins; re-execution is idempotent)."""
        with self._lock:
            fl = self._inflight.pop(req_id, None)
            if fl is not None:
                self._load[fl.widx] -= 1
            return fl

    def _collector_loop(self, widx: int, outq) -> None:
        """Drain ONE worker's result queue (one thread per worker).

        The queue has a single writer (its worker), so a torn message —
        the worker hard-killed mid-``put`` — can only mean that worker
        is dead: the loop exits and leaves the death to the watchdog.
        It never touches the other workers' streams.  A respawned
        worker gets a fresh queue and a fresh collector thread."""
        from repro.ual.service import ServiceRejected
        while True:
            try:
                msg = outq.get(timeout=0.1)
            except queue_mod.Empty:
                with self._lock:
                    closed = self._closed
                if closed:
                    p = (self._procs[widx]
                         if widx < len(self._procs) else None)
                    if p is None or not p.is_alive():
                        return
                continue
            except (EOFError, OSError, ValueError):
                return          # pipe EOF / queue closed: worker is gone
            except Exception:
                return          # torn message from a mid-write death
            kind = msg[0]
            if kind == "ready":
                with self._lock:
                    self._alive[msg[1]] = True
                    self._sup[msg[1]].record_ready(time.perf_counter())
                    self._n_ready += 1
                    ready = self._n_ready >= self.n_workers
                if ready:
                    self._ready.set()
                # Drop the parent's copy of the write end: from here the
                # worker is the pipe's only writer, so a hard death EOFs
                # the stream instead of leaving this thread blocked on a
                # partial message.  (Deferred to "ready" so the fd has
                # been materialised in the child before we close ours.)
                try:
                    outq._writer.close()
                except (AttributeError, OSError):
                    pass
            elif kind == "done":
                _, req_id, widx, out, info = msg
                fl = self._settle(req_id)
                if fl is not None:
                    info["worker"] = widx
                    info["retries"] = fl.retries
                    fl.resp._resolve(out, **info)
            elif kind == "rej":
                _, req_id, widx, reason, detail = msg
                fl = self._settle(req_id)
                if fl is not None:
                    fl.resp._resolve(
                        exc=ServiceRejected(reason, detail),
                        retries=fl.retries)
            elif kind == "err":
                _, req_id, widx, text = msg
                fl = self._settle(req_id)
                if fl is not None:
                    fl.resp._resolve(exc=RuntimeError(
                        f"worker {widx}: {text}"), retries=fl.retries)
            elif kind == "spans":
                _, widx, spans, epoch = msg
                obs.tracer().ingest(spans, epoch=epoch,
                                    track_prefix=f"worker{widx}")
            elif kind == "stats":
                with self._stats_cond:
                    self._stats_buf[msg[1]] = msg[2]
                    self._stats_want.discard(msg[1])
                    self._stats_cond.notify_all()
            elif kind == "stopped":
                with self._lock:
                    self._alive[msg[1]] = False
                    self._n_stopped += 1
                return          # "stopped" is the worker's last message

    def _watchdog_loop(self) -> None:
        """The self-healing loop: detect deaths, re-dispatch orphaned
        in-flight requests to live workers, respawn dead workers under
        the restart policy.  No future is ever lost — an orphan either
        rides a retry hop or resolves with a verdict."""
        while True:
            with self._lock:
                if self._closed:
                    return
            time.sleep(_WATCH_TICK_S)
            try:
                self._watch_tick()
            except Exception as e:  # noqa: BLE001
                # The supervision thread must outlive any single bad
                # tick: if it died, orphaned futures would never resolve
                # and dead workers would never respawn.  Count the error
                # (surfaced in stats()["supervision"]) and keep going.
                with self._lock:
                    self._watchdog_errors += 1
                    self._watchdog_last_error = f"{type(e).__name__}: {e}"

    def _watch_tick(self) -> None:
        now = time.perf_counter()
        dead: List[int] = []
        orphans: List[Tuple[int, _Flight]] = []
        with self._lock:
            for i, p in enumerate(self._procs):
                if self._alive[i] and not p.is_alive():
                    self._alive[i] = False
                    self._sup[i].record_death(now, self.restart_policy)
                    dead.append(i)
            if dead:
                doomed = set(dead)
                orphans = [(rid, fl) for rid, fl
                           in self._inflight.items()
                           if fl.widx in doomed]
                for rid, fl in orphans:
                    del self._inflight[rid]
                    self._load[fl.widx] -= 1
        if dead:
            with self._stats_cond:
                if self._stats_want & set(dead):
                    self._stats_want -= set(dead)
                    self._stats_cond.notify_all()
            for rid, fl in orphans:
                self._retry_or_reject(rid, fl, now)
        self._maybe_respawn(time.perf_counter())

    def _retry_or_reject(self, rid: int, fl: _Flight, now: float) -> None:
        """One orphaned request: re-dispatch to a live worker (same
        routing policy as ``submit``) unless the retry budget or the
        deadline says otherwise."""
        from repro.ual.service import ServiceRejected
        dead_widx = fl.widx
        if fl.deadline is not None and now > fl.deadline:
            fl.resp._resolve(exc=ServiceRejected(
                "deadline-exceeded",
                f"worker {dead_widx} died in flight and the deadline "
                f"passed (after {fl.retries} retries)"),
                retries=fl.retries)
            return
        if fl.retries >= self.max_retries:
            fl.resp._resolve(exc=ServiceRejected(
                "worker-died",
                f"worker {dead_widx} exited with the request in flight; "
                f"retry budget ({self.max_retries}) exhausted"),
                retries=fl.retries)
            return
        with self._lock:
            live = ([] if self._closed else
                    [i for i in range(self.n_workers) if self._alive[i]])
            if live:
                min_load = min(self._load[i] for i in live)
                cands = [i for i in live if self._load[i] == min_load]
                warm = [i for i in cands
                        if fl.class_id in self._registered[i]]
                widx = warm[0] if warm else cands[0]
                fl.retries += 1
                fl.widx = widx
                self._inflight[rid] = fl
                self._load[widx] += 1
                self.decisions["retry"] += 1
                need_reg = fl.class_id not in self._registered[widx]
                if need_reg:
                    self._registered[widx].add(fl.class_id)
                wire = self._class_info[fl.class_id]
                inbox = self._inboxes[widx]
        if not live:
            fl.resp._resolve(exc=ServiceRejected(
                "worker-died",
                f"worker {dead_widx} exited with the request in flight; "
                f"no live worker to retry on"), retries=fl.retries)
            return
        tr = obs.tracer()
        if tr.enabled:
            tr.record("retry", now, time.perf_counter(), cat="cluster",
                      args={"req": rid, "from": dead_widx, "to": widx,
                            "attempt": fl.retries, "tenant": fl.tenant})
        rem_ms = ((fl.deadline - now) * 1e3 if fl.deadline is not None
                  else None)
        try:
            if need_reg:
                inbox.put(("reg", fl.class_id, wire[0], wire[1]))
            inbox.put(("req", rid, fl.class_id, fl.arrays, fl.n_iters,
                       fl.tenant, rem_ms))
        except (OSError, ValueError):
            # target worker's queue is gone (it died too); the next
            # watchdog tick will orphan this flight again and re-route
            pass

    def _maybe_respawn(self, now: float) -> None:
        """Respawn every dead worker whose backoff has elapsed."""
        due: List[int] = []
        with self._lock:
            if self._closed:
                return
            for i, st in enumerate(self._sup):
                if (not self._alive[i] and not st.respawning
                        and not st.exhausted
                        and st.next_respawn_at is not None
                        and now >= st.next_respawn_at):
                    st.respawning = True
                    due.append(i)
        for i in due:
            self._respawn(i)

    def _respawn(self, widx: int) -> None:
        """Spawn the replacement for one dead worker and install it.

        Raced by ``shutdown()``: if ``_closed`` flipped while the
        process was spawning, the replacement is reaped here instead of
        installed — never leaked.  On install, the worker's previous
        compatibility classes are re-registered so it rejoins the
        routing set warm (artifacts re-load from the shared disk cache;
        no re-mapping, no cold routing misses)."""
        st = self._sup[widx]
        ctx = mp.get_context("spawn")
        inbox, outq = ctx.Queue(), ctx.Queue()
        p = ctx.Process(target=_worker_main,
                        args=(widx, inbox, outq, self._cfg),
                        name=f"ual-cluster-worker-{widx}", daemon=True)
        p.start()
        with self._lock:
            aborted = self._closed
            if not aborted:
                old = self._procs[widx]
                self._procs[widx] = p
                self._inboxes[widx] = inbox
                self._result_qs[widx] = outq
                st.record_respawned(time.perf_counter())
                classes = [(cid, self._class_info[cid])
                           for cid in self._registered[widx]]
            st.respawning = False
            self._respawn_cond.notify_all()
        if aborted:
            try:
                inbox.put(("stop",))
            except (OSError, ValueError):
                pass
            p.join(5.0)
            if p.is_alive():
                p.terminate()
                p.join(5.0)
            return
        # The predecessor's collector thread winds down on its own (EOF
        # on the dead worker's private pipe); the replacement gets a
        # fresh queue + thread so a torn stream can never be inherited.
        t = threading.Thread(target=self._collector_loop,
                             args=(widx, outq),
                             name=f"ual-cluster-collect-{widx}r",
                             daemon=True)
        t.start()
        with self._lock:
            self._threads.append(t)
        old.join(0.1)                   # reap the dead predecessor
        for cid, (prog, targ) in classes:
            try:
                inbox.put(("reg", cid, prog, targ))
            except (OSError, ValueError):
                break

    # -- observability --------------------------------------------------------
    def queue_depth(self) -> int:
        """Requests admitted but not yet resolved, cluster-wide — the
        number the ``max_queue`` bound rejects against.  Cheap (one lock,
        no worker round-trip), so load generators can sample it hot."""
        with self._lock:
            return len(self._inflight)

    def stats(self, timeout: float = 30.0) -> Dict[str, object]:
        """One merged cluster view + each worker's full snapshot.

        Aggregates are sums (completed / rejects / samples-per-second /
        queue depth); latency percentiles are REAL cluster percentiles —
        workers ship their raw latency windows and the parent merges the
        samples (``merge_latency``) — with ``worst_worker_p99_ms``
        keeping the old conservative worst-replica number.  ``routing``
        is the front-end's decision counters; per-worker replica routers
        (when ``replicas > 1``) appear inside each ``per_worker``
        snapshot and their steal counts are summed into
        ``router_steals``.
        """
        with self._lock:
            live = [i for i in range(self.n_workers) if self._alive[i]]
        with self._stats_cond:
            self._stats_buf = {}
            self._stats_want = set(live)
        for i in live:
            try:
                self._inboxes[i].put(("stats",))
            except (OSError, ValueError):
                with self._stats_cond:
                    self._stats_want.discard(i)
        deadline = time.perf_counter() + timeout
        with self._stats_cond:
            while self._stats_want:
                rem = deadline - time.perf_counter()
                if rem <= 0 or not self._stats_cond.wait(rem):
                    break
            snaps = dict(self._stats_buf)
        with self._lock:
            now = time.perf_counter()
            merged: Dict[str, object] = {
                "cluster": True,
                "workers": len(live),
                "inflight": len(self._inflight),
                "routing": {"decisions": dict(self.decisions),
                            "load": list(self._load)},
                "supervision": {
                    "policy": self.restart_policy.snapshot(),
                    "max_retries": self.max_retries,
                    "restarts_total": sum(st.restarts for st in self._sup),
                    "deaths_total": sum(st.deaths for st in self._sup),
                    "retries_total": self.decisions.get("retry", 0),
                    "watchdog_errors": self._watchdog_errors,
                    "watchdog_last_error": self._watchdog_last_error,
                    "workers": {i: st.snapshot(now, self._alive[i])
                                for i, st in enumerate(self._sup)},
                },
            }
        rejects: Dict[str, int] = {}
        steals = 0
        for s in snaps.values():
            for reason, n in s.get("rejects", {}).items():
                rejects[reason] = rejects.get(reason, 0) + n
            steals += s.get("router", {}).get("steals", 0)
        latency = merge_latency(snaps)   # pops the shipped sample windows
        merged.update({
            "completed": sum(s.get("completed", 0) for s in snaps.values()),
            "rejected": sum(s.get("rejected", 0) for s in snaps.values()),
            "rejects": rejects,
            "errors": sum(s.get("errors", 0) for s in snaps.values()),
            "queue_depth": sum(s.get("queue_depth", 0)
                               for s in snaps.values()),
            "samples_per_s": round(sum(s.get("samples_per_s", 0.0)
                                       for s in snaps.values()), 1),
            "exec_samples_per_s": round(
                sum(s.get("exec_samples_per_s", 0.0)
                    for s in snaps.values()), 1),
            **latency,
            "router_steals": steals,
            "per_worker": {i: snaps[i] for i in sorted(snaps)},
        })
        return merged

    def export_chrome(self, path, timeout: float = 30.0):
        """Write the cluster-wide timeline as Chrome-trace JSON: one
        track group per worker process (``worker0/...``) plus the
        parent's own spans.  Triggers a stats round first so every
        worker ships its buffered span batch before the export."""
        self.stats(timeout=timeout)
        return obs.tracer().export_chrome(path)


__all__ = ("ClusterService", "merge_latency")

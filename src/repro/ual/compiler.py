"""``compile(program, target) -> Executable`` — the toolchain entry point.

One call replaces the hand-wired seven-step ritual
(``DFGBuilder -> plan_layout -> apply_layout -> map_dfg -> flat_memory ->
simulate -> unflatten_memory``) every consumer used to repeat.  It drives
the staged pass pipeline in ``ual.pipeline``
(layout -> MII bounds -> mapping strategy -> lowering -> validation
binding), so:

  * temporal fabrics go through a registered ``MapperStrategy``
    (``adaptive``/``sa`` built-in, ``ual.register_strategy`` to extend),
    memoized in the mapping cache keyed on
    ``(program.digest, target.digest)`` — a second compile of an identical
    pair pays zero mapper restarts,
  * spatial fabrics (no time multiplexing) go through the analytic
    ``spatial_ii`` model,
  * mapping-free backends (``interp``) skip mapping entirely,
  * successful mappings are lowered once to the dense linked tables
    (``core.lowering.LinkedConfig``) that the ``sim`` and ``pallas``
    engines both execute — memoized next to the ``MapResult`` under the
    same key, so a warm compile re-lowers nothing,
  * every lowered configuration is statically verified
    (``repro.analysis.verifier``: port oversubscription, unresolved
    wire chains, table integrity, ...) — error findings abort the
    compile with a rendered ``VerifyError``; warnings ride along on
    ``Executable.check_report``,
  * every pass reports name / wall-time / stats into
    ``CompileInfo.passes`` for tooling and the DSE front-end.

The low-level functions remain importable from ``repro.core`` — this is a
new stable surface, not a break.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.mapper import get_strategy
from repro.ual.backends import get_backend
from repro.ual.cache import MappingCache
from repro.ual.executable import CompileInfo, Executable
from repro.ual.pipeline import CompileContext, Pipeline, default_pipeline
from repro.ual.program import Program
from repro.ual.target import Target


def compile(program: Program, target: Target, *,
            cache: Optional[MappingCache] = None,
            use_cache: bool = True,
            pipeline: Optional[Pipeline] = None) -> Executable:
    """Run ``program`` through the compile pipeline for ``target``.

    ``cache=None`` uses the process-wide default (in-process dict backed by
    an on-disk pickle directory); ``use_cache=False`` forces a cold map and
    does not store the result.  Targets carrying a ``label_fn`` always
    compile cold: the hook is unhashable, so caching it would serve stale
    placements.  ``pipeline`` swaps the default pass list for a custom one
    (extra analysis passes, alternative mapping passes).
    """
    from repro import obs
    t0 = time.perf_counter()
    backend = get_backend(target.backend)   # fail fast on unknown names
    if target.fabric.temporal and backend.requires_config:
        get_strategy(target.strategy)       # ...and unknown strategies
    ctx = CompileContext(program, target, cache=cache, use_cache=use_cache,
                         backend=backend)
    with obs.tracer().span(f"compile:{program.name}", cat="compile",
                           args={"fabric": target.fabric.name,
                                 "backend": target.backend}):
        (pipeline if pipeline is not None else default_pipeline()).run(ctx)
    info = CompileInfo(cache_hit=ctx.cache_hit,
                       mapper_restarts=ctx.restarts_paid,
                       wall_s=time.perf_counter() - t0, key=ctx.key,
                       passes=list(ctx.records))
    return Executable(program, target, ctx.result, info,
                      spatial_subgraphs=ctx.spatial_subgraphs,
                      lowered=ctx.lowered, check_report=ctx.check_report)

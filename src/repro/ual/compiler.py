"""``compile(program, target) -> Executable`` — the toolchain entry point.

One call replaces the hand-wired seven-step ritual
(``DFGBuilder -> plan_layout -> apply_layout -> map_dfg -> flat_memory ->
simulate -> unflatten_memory``) every consumer used to repeat:

  * temporal fabrics go through the modulo-scheduling mapper, memoized in
    the mapping cache keyed on ``(program.digest, target.digest)`` — a
    second compile of an identical pair pays zero mapper restarts,
  * spatial fabrics (no time multiplexing) go through the analytic
    ``spatial_ii`` model,
  * mapping-free backends (``interp``) skip mapping entirely.

The low-level functions remain importable from ``repro.core`` — this is a
new stable surface, not a break.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.mapper import MapResult, map_dfg, rec_mii, spatial_ii
from repro.ual.backends import get_backend
from repro.ual.cache import MappingCache, default_cache
from repro.ual.executable import CompileInfo, Executable
from repro.ual.program import Program
from repro.ual.target import Target


def compile(program: Program, target: Target, *,
            cache: Optional[MappingCache] = None,
            use_cache: bool = True) -> Executable:
    """Map ``program`` onto ``target`` (cached) and bind its backend.

    ``cache=None`` uses the process-wide default (in-process dict backed by
    an on-disk pickle directory); ``use_cache=False`` forces a cold map and
    does not store the result.  Targets carrying a ``label_fn`` always
    compile cold: the hook is unhashable, so caching it would serve stale
    placements.
    """
    t0 = time.time()
    backend = get_backend(target.backend)     # fail fast on unknown names
    if not backend.requires_config and target.fabric.temporal:
        return Executable(program, target, None,
                          CompileInfo(wall_s=time.time() - t0))

    if not target.fabric.temporal:
        ii, n_parts = spatial_ii(program.laid, target.fabric)
        result = MapResult(True, ii, rec_mii(program.laid),
                           strategy="spatial")
        return Executable(program, target, result,
                          CompileInfo(wall_s=time.time() - t0),
                          spatial_subgraphs=n_parts)

    key = (program.digest, target.digest)
    cacheable = use_cache and target.label_fn is None
    if cacheable:
        c = cache if cache is not None else default_cache()
        result = c.get(key)
        if result is not None:
            return Executable(program, target, result,
                              CompileInfo(cache_hit=True, mapper_restarts=0,
                                          wall_s=time.time() - t0, key=key))
    result = map_dfg(program.laid, target.fabric, ii_max=target.ii_max,
                     seed=target.seed, strategy=target.strategy,
                     max_restarts=target.max_restarts,
                     label_fn=target.label_fn,
                     time_budget_s=target.time_budget_s)
    if cacheable:
        # failures are cached too — re-paying the full restart schedule on
        # every compile of a known-unmappable pair would defeat the cache
        # where mapping is most expensive — but only in-process: the time
        # budget makes failure wall-clock dependent, so a failure observed
        # on a loaded machine must not be pinned on disk
        c.put(key, result, memory_only=not result.success)
    return Executable(program, target, result,
                      CompileInfo(cache_hit=False,
                                  mapper_restarts=result.restarts,
                                  wall_s=time.time() - t0, key=key))

"""Unified abstraction layer (UAL): the repo's stable public API.

The paper closes with a call for "a unified abstraction layer for CGRAs
and spatial accelerators, one that decouples hardware specialization from
software development".  This package is that layer::

    from repro import ual

    program = ual.Program.from_builder(b, n_iters=16)   # what to run
    target = ual.Target.from_name("hycube", rows=4, cols=4)  # where
    exe = ual.compile(program, target)                  # cached mapping
    out = exe.run(a=a, b=b)                             # dict in/out
    report = exe.validate(backends=("sim", "pallas"))   # vs the oracle

Vocabulary:

  * ``Program``  — DFG + scratchpad layout + named I/O spec, content-hashed,
  * ``Target``   — fabric + mapper strategy + backend name,
  * ``compile``  — modulo mapping, memoized across processes by
    ``(program.digest, target.digest)``,
  * ``Executable`` — ``run``/``run_batch``/``validate`` on any backend.

Extension points: ``register_backend`` (interp/sim/pallas ship built-in)
and ``register_fabric`` (hycube/n2n/pace/spatial/tpu_pod ship built-in).
"""
from repro.ual.backends import (Backend, get_backend, list_backends,
                                register_backend)
from repro.ual.cache import (CACHE_VERSION, CacheStats, MappingCache,
                             default_cache, default_cache_dir,
                             set_default_cache)
from repro.ual.compiler import compile
from repro.ual.executable import CompileInfo, Executable
from repro.ual.program import Program
from repro.ual.target import FABRICS, Target, register_fabric

__all__ = [
    "Backend", "CACHE_VERSION", "CacheStats", "CompileInfo", "Executable",
    "FABRICS", "MappingCache", "Program", "Target", "compile",
    "default_cache", "default_cache_dir", "get_backend", "list_backends",
    "register_backend", "register_fabric", "set_default_cache",
]

"""Unified abstraction layer (UAL): the repo's stable public API.

The paper closes with a call for "a unified abstraction layer for CGRAs
and spatial accelerators, one that decouples hardware specialization from
software development".  This package is that layer::

    from repro import ual

    program = ual.Program.from_builder(b, n_iters=16)   # what to run
    target = ual.Target.from_name("hycube", rows=4, cols=4)  # where
    exe = ual.compile(program, target)                  # cached pipeline
    out = exe.run(a=a, b=b)                             # dict in/out
    report = exe.validate(backends=("sim", "pallas"))   # vs the oracle

    sweep = ual.explore(program, {                      # parallel DSE
        "fabric": ["pace", ("hycube", dict(rows=4, cols=4))],
        "strategy": ["adaptive", "sa"],
    }, workers=4)
    print(sweep.render())                               # Pareto report

Vocabulary:

  * ``Program``  — DFG + scratchpad layout + named I/O spec, content-hashed,
  * ``Target``   — fabric + mapper strategy + backend name,
  * ``compile``  — the staged pass pipeline (layout -> MII bounds ->
    mapping strategy -> lowering -> verify -> validation binding;
    per-pass timings in ``CompileInfo.passes``), memoized across
    processes by ``(program.digest, target.digest)`` — both the mapping
    and the lowered dense tables (``LinkedConfig``), so warm compiles
    neither re-map nor re-lower,
  * ``verify``/``CheckReport`` — the compile-time config verifier
    (``repro.analysis.verifier``): static diagnostics (``UAL001``...)
    over the lowered tables; error findings abort ``compile()`` with a
    rendered ``VerifyError``, warnings ride on
    ``Executable.check_report``; ``python -m repro.ual.check`` is the
    CLI (code reference: ``docs/diagnostics.md``),
  * ``Executable`` — ``run``/``run_batch``/``validate`` on any backend;
    ``run_batch`` is natively batched on ``sim`` and ``pallas`` and
    reports throughput (``last_info["throughput_sps"]``),
  * ``compile_many``/``explore`` — grid compilation over a process pool
    with cache-aware dedup, and the Pareto DSE front-end on top of it,
  * ``CompiledKernelCache``/``default_engine`` — the persistent JIT
    execution engine behind the ``pallas`` backend
    (``repro.ual.engine``): linked tables device-resident per engine,
    ``n_iters`` traced, batch sizes padded up a bucket ladder — trace
    once, run many (``Executable.warmup(buckets=...)`` pre-traces the
    ladder),
  * ``Service``  — the dynamic-batching execution service
    (``repro.ual.service``): single-sample requests are queued, coalesced
    into micro-batches per ``(program.digest, target.digest)`` class and
    executed as one ``run_batch`` sweep on shared warm Executables;
    ``submit`` returns a ``Response`` future, overload and expired
    deadlines come back as ``ServiceRejected`` verdicts, and
    ``Service.stats()`` reports p50/p99 latency, achieved batch size,
    samples/s, queue depth and rejects; ``submit_stream`` is the bulk
    path — one chunked request pipelined through a warm trace
    (``StreamResponse``), stream stats under ``stats()["stream"]``.

Extension points, all the same shape (named registry, duplicate names
raise without ``overwrite=True``): ``register_backend``
(interp/sim/pallas built-in), ``register_fabric``
(hycube/n2n/pace/spatial/tpu_pod built-in) and ``register_strategy``
(adaptive/sa built-in); enumerate with ``list_backends()`` /
``list_fabrics()`` / ``list_strategies()``.
"""
from repro.analysis.verifier import (CheckReport, Diagnostic, VerifyError,
                                     verify)
from repro.core.lowering import LinkedConfig, link_config
from repro.core.mapper import (MapperStrategy, list_strategies,
                               register_strategy)
from repro.ual.backends import (Backend, get_backend, list_backends,
                                register_backend)
from repro.ual.cache import (CACHE_VERSION, CacheStats, MappingCache,
                             default_cache, default_cache_dir,
                             set_default_cache)
from repro.ual.cluster import ClusterService, RestartPolicy, Router
from repro.ual.compiler import compile
from repro.ual.faults import FaultPlan, FaultSpec, InjectedFault
from repro.ual.engine import (CompiledKernelCache, KernelEngine,
                              ShardedKernelEngine, bucket_ladder,
                              default_engine, set_default_engine)
from repro.ual.executable import CompileInfo, Executable, PassRecord
from repro.ual.explore import (DesignPoint, ExploreReport, compile_many,
                               explore)
from repro.ual.pipeline import (CompileContext, CompilePass, Pipeline,
                                VerifyPass, default_pipeline)
from repro.ual.program import Program
from repro.ual.service import (Response, Service, ServiceRejected,
                               StreamResponse)
from repro.ual.service.breaker import CircuitBreaker
from repro.ual.target import (FABRICS, Target, list_fabrics, register_fabric)

__all__ = [
    "Backend", "CACHE_VERSION", "CacheStats", "CheckReport",
    "CircuitBreaker", "ClusterService", "CompileContext", "CompileInfo",
    "CompiledKernelCache", "CompilePass", "DesignPoint", "Diagnostic",
    "Executable", "ExploreReport", "FABRICS", "FaultPlan", "FaultSpec",
    "InjectedFault", "KernelEngine", "LinkedConfig", "MapperStrategy",
    "MappingCache", "PassRecord", "Pipeline", "Program", "Response",
    "RestartPolicy", "Router", "Service", "ServiceRejected",
    "ShardedKernelEngine", "StreamResponse", "Target",
    "VerifyError", "VerifyPass",
    "bucket_ladder", "compile", "compile_many", "default_cache",
    "default_cache_dir", "default_engine", "default_pipeline", "explore",
    "get_backend", "link_config", "list_backends", "list_fabrics",
    "list_strategies", "register_backend", "register_fabric",
    "register_strategy", "set_default_cache", "set_default_engine",
    "verify",
]

"""``Target`` — where (and how) a Program runs.

A Target names a fabric (the elaborated ADL topology), a mapper strategy
with its quality knobs, and an execution backend.  Fabrics come from a
registry keyed by the ADL builder names (``hycube``/``n2n``/``pace``/
``spatial``/``tpu_pod``); backends come from the pluggable registry in
``ual.backends``.

``Target.digest`` hashes only what the *mapper* consumes — the fabric
topology and the mapping knobs — deliberately excluding the backend, so a
Program compiled once is served from the cache for every backend that
executes the same machine configuration (interp / sim / pallas parity
costs one mapping, not three).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, fields, replace
from functools import cached_property
from typing import Callable, Dict, Optional

from repro.core.adl import FABRIC_BUILDERS, Fabric

FABRICS: Dict[str, Callable[..., Fabric]] = dict(FABRIC_BUILDERS)


def register_fabric(name: str, builder: Callable[..., Fabric],
                    overwrite: bool = False) -> None:
    """Register a fabric builder under ``name``.

    Registering an existing name raises unless ``overwrite=True`` — silent
    replacement is how two plugins stomp each other.  ``builder`` is any
    callable returning a ``Fabric`` (``Target.from_name`` forwards its
    non-knob keyword arguments to it); the ADL builders
    ``hycube``/``n2n``/``pace``/``spatial``/``tpu_pod`` ship built-in.
    """
    if name in FABRICS and not overwrite:
        raise ValueError(f"fabric {name!r} already registered; "
                         f"pass overwrite=True to replace it")
    if not callable(builder):
        raise TypeError(f"builder must be callable, "
                        f"got {type(builder).__name__}")
    FABRICS[name] = builder


def list_fabrics() -> list:
    """Sorted names of all registered fabric builders."""
    return sorted(FABRICS)


@dataclass(frozen=True)
class Target:
    fabric: Fabric
    backend: str = "sim"
    # -- mapper knobs (all hashed into .digest) -------------------------------
    strategy: str = "adaptive"
    ii_max: int = 48
    seed: int = 0
    max_restarts: int = 8
    time_budget_s: Optional[float] = 90.0
    label_fn: Optional[Callable] = field(default=None, compare=False)

    @property
    def name(self) -> str:
        return f"{self.fabric.name}/{self.backend}"

    @cached_property
    def digest(self) -> str:
        """Stable SHA-256 over the mapping-relevant configuration.

        Excludes ``backend`` (the bitstream is backend-independent) and
        ``label_fn`` (unhashable; callers supplying one should bypass or
        scope their own cache).
        """
        blob = "|".join([
            self.fabric.to_json(), self.strategy, str(self.ii_max),
            str(self.seed), str(self.max_restarts),
            str(self.time_budget_s),
        ])
        return hashlib.sha256(blob.encode()).hexdigest()

    def with_backend(self, backend: str) -> "Target":
        return replace(self, backend=backend)

    @staticmethod
    def from_name(fabric: str, *, backend: str = "sim",
                  **kwargs) -> "Target":
        """Build a Target from a registered fabric name, e.g.::

            Target.from_name("hycube", rows=4, cols=4, max_hops=4,
                             backend="pallas", seed=3)

        Keyword names matching Target fields (``seed``, ``max_restarts``,
        ``ii_max``, ``strategy``, ``time_budget_s``, ``label_fn``) set the
        mapper knobs; everything else goes to the fabric builder.  Knob
        defaults therefore live in exactly one place — the dataclass.
        """
        if fabric not in FABRICS:
            raise KeyError(f"unknown fabric {fabric!r}; "
                           f"registered: {sorted(FABRICS)}")
        knob_names = {f.name for f in fields(Target)} - {"fabric", "backend"}
        knobs = {k: v for k, v in kwargs.items() if k in knob_names}
        fabric_kwargs = {k: v for k, v in kwargs.items()
                         if k not in knob_names}
        return Target(FABRICS[fabric](**fabric_kwargs), backend=backend,
                      **knobs)

"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="rwkv6",
    n_layers=24, d_model=2048, n_heads=32, d_ff=7168, vocab=65536,
    mlp_act="silu",
)

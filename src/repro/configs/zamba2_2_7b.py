"""Zamba2-2.7B: Mamba2 backbone + shared attention block (hybrid).
[arXiv:2411.15242; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="zamba2",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, ssm_state=64, ssm_conv=4, ssm_head_dim=64,
    shared_attn_every=6, mlp_act="silu",
)

"""Assigned input shapes (4 per architecture; 40 cells total).

``long_500k`` needs sub-quadratic attention: it runs only for the SSM /
hybrid families (rwkv6, zamba2); pure/windowed-attention archs retain
quadratic *global* layers and are skipped (DESIGN.md §Arch-applicability).
Encoder-only archs (hubert) have no decode step, so decode shapes skip.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("rwkv6", "zamba2")


def cell_skip_reason(family: str, shape: str) -> Optional[str]:
    """None if the (arch-family, shape) cell runs; else the skip reason."""
    if family == "hubert" and shape in ("decode_32k", "long_500k"):
        return "encoder-only: no decode step"
    if shape == "long_500k" and family not in SUBQUADRATIC_FAMILIES:
        return "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return None


def all_cells(arch_names, arch_families) -> list:
    """[(arch, shape, skip_reason)] over the full 40-cell grid."""
    cells = []
    for a in arch_names:
        fam = arch_families[a]
        for s in SHAPES:
            cells.append((a, s, cell_skip_reason(fam, s)))
    return cells

"""Gemma-3 27B: 5:1 local:global attention, 128k context, qk-norm.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144, qk_norm=True,
    sliding_window=1024, global_every=6, rope_theta=1_000_000.0,
    mlp_act="silu",
)

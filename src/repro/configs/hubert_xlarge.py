"""HuBERT X-Large: encoder-only audio transformer (stub frame frontend).
[arXiv:2106.07447; unverified]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="hubert",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab=504, causal=False, frontend="audio", mlp_act="gelu",
    tie_embeddings=False,
)

"""Snowflake Arctic (480B-class): 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, n_experts=128, n_shared_experts=0, top_k=2,
    expert_d_ff=4864, dense_residual=True, mlp_act="silu",
)

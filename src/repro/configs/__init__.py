"""Architecture registry: ``--arch <id>`` resolves through ``get_config``.

Also provides ``smoke_config`` — a reduced same-family config for CPU
smoke tests (the full configs are only ever lowered via the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.common import ModelConfig

from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.gemma3_27b import CONFIG as _gemma3
from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.h2o_danube3_4b import CONFIG as _danube3
from repro.configs.h2o_danube_1_8b import CONFIG as _danube18
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.zamba2_2_7b import CONFIG as _zamba2

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        _deepseek, _arctic, _gemma3, _qwen3, _danube3, _danube18,
        _hubert, _paligemma, _rwkv6, _zamba2,
    )
}

FAMILIES = {name: c.family for name, c in ARCHS.items()}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: tiny layers/width/experts/vocab."""
    c = get_config(name)
    kw = dict(
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=256,
        head_dim=16, rope_theta=10000.0,
    )
    if c.n_kv_heads:
        kw["n_kv_heads"] = min(c.n_kv_heads, 2)
    if c.family == "moe":
        kw.update(n_experts=8, top_k=min(c.top_k, 2),
                  n_shared_experts=min(c.n_shared_experts, 1),
                  expert_d_ff=32,
                  capacity_factor=8.0)   # ~dropless so decode == forward
    if c.family == "rwkv6":
        kw.update(n_heads=4, d_model=64)          # head size 16
    if c.family == "zamba2":
        kw.update(n_layers=4, shared_attn_every=2, ssm_state=16,
                  ssm_head_dim=16, n_kv_heads=4)
    if c.sliding_window:
        kw["sliding_window"] = 8
    if c.global_every:
        kw["global_every"] = 2
    if c.n_prefix_tokens:
        kw["n_prefix_tokens"] = 4
    return dataclasses.replace(c, **kw)

"""PaliGemma-3B: SigLIP patch embeddings (stub) + Gemma MQA backbone.
[arXiv:2407.07726; hf]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="paligemma",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216, frontend="image", n_prefix_tokens=256,
    mlp_act="silu",
)

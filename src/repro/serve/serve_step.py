"""Serving steps: batched prefill and single-token decode (pjit-ed).

`decode_32k`/`long_500k` cells lower `decode_step` with a ShapeDtypeStruct
KV cache of the full context length; the cache sharding policy lives in
`repro.sharding.specs.cache_specs` (batch over DP, kv-heads over TP when
divisible, else sequence-sharded flash-decode).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.lm import decode_step, forward
from repro.sharding.ctx import activation_sharding, make_rules
from repro.sharding.specs import (batch_specs, cache_specs, dp_axes,
                                  param_specs, sanitize_specs, to_shardings)


def _sanitized_param_specs(cfg: ModelConfig, mesh: Mesh):
    from repro.models.common import init_params
    abstract = jax.eval_shape(lambda k: init_params(k, cfg),
                              jax.random.PRNGKey(0))
    return sanitize_specs(param_specs(cfg, mesh), abstract, mesh)


def prefill_fn(cfg: ModelConfig):
    def prefill(params, batch):
        if cfg.family == "hubert":
            logits, _ = forward(params, cfg, features=batch["features"],
                                feat_mask=batch.get("mask"))
        else:
            logits, _ = forward(params, cfg, batch["tokens"],
                                img_embeds=batch.get("img_embeds"))
        # serving returns last-position logits per request
        return logits[:, -1, :]
    return prefill


def decode_fn(cfg: ModelConfig):
    def decode(params, cache, token):
        logits, cache = decode_step(params, cfg, cache, token)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True)
        return next_tok.astype(jnp.int32), logits, cache
    return decode


def make_sharded_prefill(cfg: ModelConfig, mesh: Mesh, global_batch: int):
    p_specs = _sanitized_param_specs(cfg, mesh)
    b_specs = batch_specs(cfg, mesh, global_batch, "prefill")
    dp_size = 1
    for a in (dp_axes(mesh, cfg.shard_strategy) or ()):
        dp_size *= mesh.shape[a]
    rules = make_rules(mesh, batch_sharded=(global_batch % dp_size == 0
                                            and global_batch >= dp_size),
                       strategy=cfg.shard_strategy)
    inner = prefill_fn(cfg)

    def fn(params, batch):
        with activation_sharding(rules):
            return inner(params, batch)
    return jax.jit(fn,
                   in_shardings=(to_shardings(p_specs, mesh),
                                 to_shardings(b_specs, mesh)),
                   ), (p_specs, b_specs)


def make_sharded_decode(cfg: ModelConfig, mesh: Mesh, batch: int):
    p_specs = _sanitized_param_specs(cfg, mesh)
    c_specs = cache_specs(cfg, mesh, batch)
    tok_spec = P(dp_axes(mesh, cfg.shard_strategy) if batch > 1 else None,
                 None)
    dp_size = 1
    for a in (dp_axes(mesh, cfg.shard_strategy) or ()):
        dp_size *= mesh.shape[a]
    rules = make_rules(mesh, batch_sharded=(batch % dp_size == 0
                                            and batch >= dp_size),
                       strategy=cfg.shard_strategy)
    inner_d = decode_fn(cfg)

    def fn(params, cache, token):
        with activation_sharding(rules):
            return inner_d(params, cache, token)
    in_sh = (to_shardings(p_specs, mesh), to_shardings(c_specs, mesh),
             NamedSharding(mesh, tok_spec))
    out_sh = (NamedSharding(mesh, tok_spec), None,
              to_shardings(c_specs, mesh))
    # donate the cache: without aliasing XLA copies the full KV cache every
    # decode step (measured: 73 full-cache touches/step on qwen3 decode_32k
    # vs ~5 with donation — see EXPERIMENTS §Perf decode addendum)
    return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(1,)), \
        (p_specs, c_specs, tok_spec)

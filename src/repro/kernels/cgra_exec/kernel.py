"""Pallas TPU kernel: execute a linked CGRA configuration over a batch.

TPU adaptation of the paper's execution substrate (DESIGN.md §2).  The
fabric's PE array is small (16–64 PEs) and its cycle loop is sequential,
so a 1:1 port would waste the TPU.  Instead:

  * the BATCH of independent executions (test vectors / workload
    instances) is vectorized across VPU lanes — each lane is one CGRA
    instance, the per-cycle PE update is a (P, lanes) elementwise block;
  * the configuration memory (the paper's CM, 52% of CGRA power because
    it is read every cycle) is the linked table image, resident in VMEM
    for the whole kernel — the "CM stays on-chip" analogue;
  * HyCUBE's single-cycle multi-hop routes were resolved at link time
    (kernels/cgra_exec/linking.py), so operand fetch is a static one-hot
    gather over the PE state — compiler-scheduled routing with zero
    dynamic-routing hardware, exactly the paper's bet;
  * the scratchpad lives in VMEM as an (M, lanes) block; LOAD/STORE are
    data-dependent per lane and become one-hot compare/select reductions
    (TPU has no per-lane gather; this is the idiomatic replacement).

Grid: (batch_tiles,) — each grid step simulates ``total_cycles`` of the
whole fabric for one batch tile via ``fori_loop`` carrying (O, R, mem).

``n_iters`` is a *traced* scalar (a ``(1, 1)`` int32 operand, read inside
the kernel): the cycle count becomes a dynamic ``fori_loop`` bound and
per-PE firing is masked on the traced iteration count, so ONE trace of the
kernel serves every iteration count — the property the persistent JIT
engine (``repro.ual.engine``) builds its trace-once/run-many cache on.
``make_cgra_call`` is the shared constructor of the ``pallas_call``; both
the one-shot ``cgra_exec`` wrapper and the engine go through it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lowering import (K_CONST, K_NONE, K_O, K_R, K_RESULT,
                                 LinkedConfig)
from repro.core.machine import OPC

I32 = jnp.int32


def _sel_rows(idx, table):
    """table[idx] for idx (P,) int32 over table (N, B) — one-hot gather.

    TPU-friendly: avoids dynamic per-row gathers; (P, N) one-hot times
    (N, B) state collapses to compare/multiply/sum on the VPU.
    """
    N = table.shape[0]
    oh = (idx[:, None] == jax.lax.broadcasted_iota(I32, (1, N), 1)).astype(I32)
    return jnp.sum(oh[:, :, None] * table[None, :, :], axis=1)


def _alu(opc, v0, v1, v2, const, use_const_mask):
    """Vectorized ALU: all opcodes computed, selected by ``opc`` (P, 1)."""
    sh5 = jnp.bitwise_and(v1, 31)
    def cmp(c):
        return c.astype(I32)
    cases = {
        "ADD": v0 + v1, "SUB": v0 - v1, "MUL": v0 * v1,
        "SHL": jax.lax.shift_left(v0, sh5),
        "SHR": jax.lax.shift_right_arithmetic(v0, sh5),
        "AND": v0 & v1, "OR": v0 | v1, "XOR": v0 ^ v1,
        "MIN": jnp.minimum(v0, v1), "MAX": jnp.maximum(v0, v1),
        "ABS": jnp.abs(v0),
        "CMPLT": cmp(v0 < v1), "CMPGT": cmp(v0 > v1),
        "CMPEQ": cmp(v0 == v1), "CMPNE": cmp(v0 != v1),
        "CMPLE": cmp(v0 <= v1), "CMPGE": cmp(v0 >= v1),
        "SELECT": jnp.where(v0 != 0, v1, v2),
        "MOVC": jnp.broadcast_to(const, v0.shape),
        "ROUTE": v0,
    }
    out = jnp.zeros_like(v0)
    for name, val in cases.items():
        out = jnp.where(opc == OPC[name], val, out)
    return out


def _cgra_kernel(niter_ref, scalar_ref, ops_ref, regw_ref, mem_in_ref,
                 mem_out_ref, *, II: int, n_pes: int, n_regs: int, mem_pes,
                 t_max: int):
    P, R = n_pes, n_regs
    n_iters = niter_ref[0, 0]           # traced: one trace, any trip count
    total_cycles = t_max + (n_iters + 1) * II + 2
    scalar = scalar_ref[...]            # (S, P, 4)
    optab = ops_ref[...]                # (S, P, 3, 5)
    rwtab = regw_ref[...]               # (S, P, R, 3)
    mem0 = mem_in_ref[...]              # (M, B)
    M, B = mem0.shape

    def cycle(t, carry):
        out_latch, Rf, mem = carry      # (P,B), (P*R,B), (M,B)
        s = t % II
        sc = jax.lax.dynamic_index_in_dim(scalar, s, 0, keepdims=False)
        op = jax.lax.dynamic_index_in_dim(optab, s, 0, keepdims=False)
        rw = jax.lax.dynamic_index_in_dim(rwtab, s, 0, keepdims=False)
        opc, const, use_c, t0 = sc[:, 0], sc[:, 1], sc[:, 2], sc[:, 3]
        it = jnp.where(t0 >= 0, (t - t0) // II, 0)            # (P,)
        fired = (opc != OPC["NOP"]) & (t0 >= 0) & (t >= t0) & (it < n_iters)
        cvec = jnp.broadcast_to(const[:, None], (P, B))

        # ---- operand fetch: static gathers over previous-cycle state -----
        def operand(k):
            kind, pe, reg = op[:, k, 0], op[:, k, 1], op[:, k, 2]
            dist, init = op[:, k, 3], op[:, k, 4]
            v = jnp.where((kind == K_O)[:, None],
                          _sel_rows(pe, out_latch), 0)
            v = jnp.where((kind == K_R)[:, None],
                          _sel_rows(pe * R + reg, Rf), v)
            v = jnp.where((kind == K_CONST)[:, None], cvec, v)
            use_init = (dist > 0) & (it < dist)
            v = jnp.where(use_init[:, None],
                          jnp.broadcast_to(init[:, None], (P, B)), v)
            return kind, v

        k0, v0 = operand(0)
        k1, v1 = operand(1)
        k2, v2 = operand(2)
        # the immediate is a *trailing* ALU operand when use_const is set
        n_ops = ((k0 != K_NONE).astype(I32) + (k1 != K_NONE).astype(I32)
                 + (k2 != K_NONE).astype(I32))
        uc = use_c != 0
        v0 = jnp.where(((k0 == K_NONE) & uc & (n_ops == 0))[:, None], cvec, v0)
        v1 = jnp.where(((k1 == K_NONE) & uc & (n_ops == 1))[:, None], cvec, v1)
        v2 = jnp.where(((k2 == K_NONE) & uc & (n_ops == 2))[:, None], cvec, v2)

        result = _alu(opc[:, None], v0, v1, v2, const[:, None], uc)

        # ---- memory ops: sequential over LSU-capable PEs (port order) ----
        iota_m = jax.lax.broadcasted_iota(I32, (M, 1), 0)
        for mp in mem_pes:
            is_ld = fired[mp] & (opc[mp] == OPC["LOAD"])
            is_st = fired[mp] & (opc[mp] == OPC["STORE"])
            has_idx = op[mp, 0, 0] != K_NONE
            l_addr = jnp.where(has_idx, v0[mp], 0) + const[mp]        # (B,)
            lval = jnp.sum(jnp.where(iota_m == l_addr[None, :], mem, 0),
                           axis=0)
            has2 = op[mp, 1, 0] != K_NONE
            s_addr = jnp.where(has2, v0[mp] + const[mp], const[mp])
            s_val = jnp.where(has2, v1[mp], v0[mp])
            addr = jnp.where(is_st, s_addr, l_addr)
            mem = jnp.where(is_st & (iota_m == addr[None, :]),
                            s_val[None, :], mem)
            row = jnp.where(is_ld, lval, jnp.where(is_st, s_val, result[mp]))
            result = jnp.where(
                (jax.lax.broadcasted_iota(I32, (P, 1), 0) == mp), row[None, :],
                result)

        # ---- end of cycle: register writes, then output latches -----------
        rwk = rw[:, :, 0].reshape(P * R)
        rwp = rw[:, :, 1].reshape(P * R)
        rwr = rw[:, :, 2].reshape(P * R)
        from_o = _sel_rows(rwp, out_latch)
        from_r = _sel_rows(rwp * R + rwr, Rf)
        from_res = _sel_rows(rwp, result)
        fired_src = _sel_rows(rwp, fired.astype(I32)[:, None]
                              * jnp.ones((P, B), I32))
        Rf_new = jnp.where((rwk == K_O)[:, None], from_o, Rf)
        Rf_new = jnp.where((rwk == K_R)[:, None], from_r, Rf_new)
        Rf_new = jnp.where(((rwk == K_RESULT)[:, None]) & (fired_src != 0),
                           from_res, Rf_new)
        O_new = jnp.where(fired[:, None], result, out_latch)
        return O_new, Rf_new, mem

    O0 = jnp.zeros((P, B), I32)
    R0 = jnp.zeros((P * R, B), I32)
    _, _, mem = jax.lax.fori_loop(0, total_cycles, cycle, (O0, R0, mem0))
    mem_out_ref[...] = mem


def make_cgra_call(linked: LinkedConfig, *, M: int, bB: int,
                   n_tiles: int = 1, interpret: bool = False):
    """Build the ``pallas_call`` executing ``linked`` over ``n_tiles``
    batch tiles of ``bB`` lanes each.

    Returns a callable ``(niter, scalar, ops, regw, memT) -> memT'`` where
    ``niter`` is a (1, 1) int32 array (the traced trip count), the tables
    are the dense linked images and ``memT`` is the (M, n_tiles * bB)
    transposed scratchpad block.  Everything *shape-like* (tile geometry,
    table dims, the schedule's ``t0_max``) is static; the trip count is
    not — one trace serves every ``n_iters``.
    """
    kernel = functools.partial(
        _cgra_kernel, II=linked.II, n_pes=linked.n_pes,
        n_regs=linked.n_regs, mem_pes=linked.mem_pes, t_max=linked.t0_max)
    S, P, R = linked.II, linked.n_pes, linked.n_regs
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((S, P, 4), lambda i: (0, 0, 0)),
            pl.BlockSpec((S, P, 3, 5), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((S, P, R, 3), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((M, bB), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((M, bB), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((M, n_tiles * bB), I32),
        interpret=interpret,
    )


def cgra_exec(linked: LinkedConfig, mem: jax.Array, n_iters, *,
              lanes: int = 128, interpret: bool = False) -> jax.Array:
    """Execute ``linked`` for ``n_iters`` iterations over mem (B, M) int32.

    Returns the final scratchpad images, (B, M) int32.  One-shot wrapper:
    builds the ``pallas_call`` per invocation — steady-state callers go
    through the persistent JIT engine (``repro.ual.engine``) instead.
    """
    B, M = mem.shape
    bB = min(lanes, max(8, B))
    pad = (-B) % bB
    memT = jnp.pad(mem, ((0, pad), (0, 0))).T.astype(I32)     # (M, B')
    call = make_cgra_call(linked, M=M, bB=bB, n_tiles=(B + pad) // bB,
                          interpret=interpret)
    out = call(jnp.asarray(n_iters, I32).reshape(1, 1),
               jnp.asarray(linked.scalar), jnp.asarray(linked.ops),
               jnp.asarray(linked.regw), memT)
    return out.T[:B]

"""Public wrapper: MachineConfig -> lowered tables -> Pallas execution."""
from __future__ import annotations

import threading
from typing import Dict, Optional

import numpy as np

from repro.core.lowering import (LinkedConfig, config_fingerprint,
                                 link_config)
from repro.core.machine import MachineConfig

#: fingerprint-keyed memo for callers that pass ``linked=None``: external
#: one-shot users (tests, scripts) used to silently re-lower the same
#: config on every call — now every distinct configuration is lowered at
#: most once per process, mirroring the UAL pipeline's lowered-artifact
#: cache for callers that bypass the pipeline
_LINKED_MEMO: Dict[str, LinkedConfig] = {}
_LINKED_LOCK = threading.Lock()


def _memoized_link(cfg: MachineConfig) -> LinkedConfig:
    fp = config_fingerprint(cfg)
    with _LINKED_LOCK:
        linked = _LINKED_MEMO.get(fp)
    if linked is None:
        linked = link_config(cfg)
        with _LINKED_LOCK:
            linked = _LINKED_MEMO.setdefault(fp, linked)
    return linked


def cgra_exec_op(cfg: MachineConfig, mem: np.ndarray, n_iters: int, *,
                 lanes: int = 128, interpret: bool = True,
                 linked: Optional[LinkedConfig] = None) -> np.ndarray:
    """Execute a mapped CGRA configuration over a batch of test vectors.

    mem: (B, M) int32 scratchpad images.  interpret=True on CPU (the TPU
    lowering is exercised by the dry-run harness, not here).  ``linked``
    supplies a precomputed lowered artifact (e.g. the one memoized by the
    ``ual`` compile pipeline); when omitted the config is lowered through
    a per-process fingerprint memo, so no caller lowers the same
    configuration twice.  Execution goes through the persistent JIT
    engine (``repro.ual.engine``): repeat calls on one configuration hit
    warm traces instead of rebuilding the ``pallas_call``.
    """
    if linked is None:
        linked = _memoized_link(cfg)
    from repro.ual.engine import default_engine
    out, _ = default_engine().run(linked, np.asarray(mem, np.int32), n_iters,
                                  lanes=lanes, interpret=interpret)
    return out

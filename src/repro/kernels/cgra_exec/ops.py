"""Public wrapper: MachineConfig -> lowered tables -> Pallas execution."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.lowering import LinkedConfig, link_config
from repro.core.machine import MachineConfig
from repro.kernels.cgra_exec.kernel import cgra_exec


def cgra_exec_op(cfg: MachineConfig, mem: np.ndarray, n_iters: int, *,
                 lanes: int = 128, interpret: bool = True,
                 linked: Optional[LinkedConfig] = None) -> np.ndarray:
    """Execute a mapped CGRA configuration over a batch of test vectors.

    mem: (B, M) int32 scratchpad images.  interpret=True on CPU (the TPU
    lowering is exercised by the dry-run harness, not here).  ``linked``
    supplies a precomputed lowered artifact (e.g. the one memoized by the
    ``ual`` compile pipeline); when omitted the config is lowered here.
    """
    if linked is None:
        linked = link_config(cfg)
    out = cgra_exec(linked, jnp.asarray(mem, jnp.int32), n_iters,
                    lanes=lanes, interpret=interpret)
    return np.asarray(out)

"""Public wrapper: MachineConfig -> linked tables -> Pallas execution."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.machine import MachineConfig
from repro.kernels.cgra_exec.kernel import cgra_exec
from repro.kernels.cgra_exec.linking import LinkedConfig, link_config


def cgra_exec_op(cfg: MachineConfig, mem: np.ndarray, n_iters: int, *,
                 lanes: int = 128, interpret: bool = True) -> np.ndarray:
    """Execute a mapped CGRA configuration over a batch of test vectors.

    mem: (B, M) int32 scratchpad images.  interpret=True on CPU (the TPU
    lowering is exercised by the dry-run harness, not here).
    """
    linked = link_config(cfg)
    out = cgra_exec(linked, jnp.asarray(mem, jnp.int32), n_iters,
                    lanes=lanes, interpret=interpret)
    return np.asarray(out)

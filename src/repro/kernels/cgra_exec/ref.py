"""Oracle for the cgra_exec kernel: the cycle-accurate simulator, per lane.

Deliberately an INDEPENDENT code path: the simulator interprets the raw
MachineConfig (re-resolving the multi-hop wire chains every cycle), while
the kernel executes link-time-resolved tables — agreement over a batch of
random scratchpad images validates both the linker and the kernel.
"""
from __future__ import annotations

import numpy as np

from repro.core.machine import MachineConfig
from repro.core.simulator import simulate


def cgra_exec_ref(cfg: MachineConfig, mem: np.ndarray, n_iters: int
                  ) -> np.ndarray:
    """mem: (B, M) int32 scratchpad images -> final images, (B, M)."""
    out = np.empty_like(mem, dtype=np.int32)
    for b in range(mem.shape[0]):
        final, _ = simulate(cfg, mem[b], n_iters, check_ports=False)
        out[b] = final
    return out

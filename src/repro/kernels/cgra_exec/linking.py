"""Compatibility shim: the "linker" is now the shared lowering pass.

The dense-table construction that used to live here is the single source
of truth in ``repro.core.lowering`` — the same lowered artifact drives
the Pallas ``cgra_exec`` kernel, the vectorized batched simulator and the
``ual`` compile pipeline's ``lowering`` pass.  This module re-exports the
public names so existing imports keep working.
"""
from __future__ import annotations

from repro.core.lowering import (K_CONST, K_NONE, K_O, K_R, K_RESULT,
                                 LinkedConfig, link_config)

__all__ = ["K_CONST", "K_NONE", "K_O", "K_R", "K_RESULT", "LinkedConfig",
           "link_config"]

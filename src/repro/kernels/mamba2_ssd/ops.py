"""Jitted public wrapper for the mamba2_ssd kernel."""
import functools

import jax

from repro.kernels.mamba2_ssd.kernel import ssd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_op(x, dt, A_log, B, C, D, *, chunk: int = 64, interpret: bool = False):
    return ssd(x, dt, A_log, B, C, D, chunk=chunk, interpret=interpret)

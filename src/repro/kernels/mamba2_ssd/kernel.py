"""Pallas TPU kernel: chunked Mamba-2 SSD (state-space dual) scan.

Same skeleton as the rwkv6 kernel: grid = (B*H, n_chunks) with the chunk
axis innermost-sequential, per-head (P, N) state resident in VMEM scratch
for the whole sequence.  The SSD decay is *scalar per head per token*
(vs RWKV6's per-channel), so the intra-chunk weights collapse to an
(L, L) matrix — all three products are MXU matmuls:

    y_state = exp(cum) * (C @ S^T)               (L,N)(N,P)
    y_intra = (tril(exp(cum_t - cum_i)) * (C B^T) * dt) @ x    (L,L)(L,P)
    S'      = exp(cum_L) S + (dt * exp(cum_L - cum) * x)^T B   (P,L)(L,N)

Host wrapper pre-computes la = -dt * exp(A_log) and adds the D*x skip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, y_ref, s_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)                 # (L, P)
    dt = dt_ref[0].astype(jnp.float32)               # (L,)
    la = la_ref[0].astype(jnp.float32)               # (L,), <= 0
    Bm = b_ref[0].astype(jnp.float32)                # (L, N)
    Cm = c_ref[0].astype(jnp.float32)                # (L, N)
    L = chunk
    state = s_scr[...]                               # (P, N)

    cum = jnp.cumsum(la)                             # (L,) inclusive
    # inter-chunk contribution
    y_state = jnp.exp(cum)[:, None] * jax.lax.dot(Cm, state.T)   # (L, P)
    # intra-chunk (causal, diagonal included)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) \
        >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    expo = cum[:, None] - cum[None, :]
    g = jnp.where(tri, jnp.exp(jnp.where(tri, expo, 0.0)), 0.0)
    w = g * jax.lax.dot(Cm, Bm.T) * dt[None, :]      # (L, L)
    y_intra = jax.lax.dot(w, x)                      # (L, P)
    y_ref[0] = (y_state + y_intra).astype(y_ref.dtype)

    # state update
    decay_all = jnp.exp(cum[-1])
    k_dec = dt * jnp.exp(cum[-1] - cum)              # (L,), exponent <= 0
    s_scr[...] = state * decay_all + jax.lax.dot((x * k_dec[:, None]).T, Bm)


def ssd(x, dt, A_log, B, C, D, *, chunk: int = 64, interpret: bool = False):
    """Chunked SSD.  x: (B, S, H, P); dt: (B, S, H); B/C: (B, S, N);
    A_log/D: (H,).  Returns y: (B, S, H, P)."""
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    n = pl.cdiv(S, chunk)
    pad = n * chunk - S
    la = -dt.astype(jnp.float32) \
        * jnp.exp(A_log.astype(jnp.float32))[None, None, :]

    xh = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) \
        .transpose(0, 2, 1, 3).reshape(Bsz * H, n * chunk, P)
    dth = jnp.pad(dt, ((0, 0), (0, pad), (0, 0))) \
        .transpose(0, 2, 1).reshape(Bsz * H, n * chunk)
    lah = jnp.pad(la, ((0, 0), (0, pad), (0, 0))) \
        .transpose(0, 2, 1).reshape(Bsz * H, n * chunk)
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(Bsz * H, n),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci, h=H: (bh // h, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci, h=H: (bh // h, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz * H, n * chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dth, lah, Bp, Cp)
    y = y.reshape(Bsz, H, n * chunk, P).transpose(0, 2, 1, 3)[:, :S]
    return (y.astype(jnp.float32)
            + D.astype(jnp.float32)[None, None, :, None]
            * x.astype(jnp.float32)).astype(x.dtype)

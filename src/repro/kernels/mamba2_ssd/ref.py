"""Pure-jnp oracle for the mamba2_ssd kernel: sequential SSM recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A_log, B, C, D):
    """Sequential SSD.  Shapes as in kernel.ssd."""
    Bsz, S, H, P = x.shape
    a = jnp.exp(-dt.astype(jnp.float32)
                * jnp.exp(A_log.astype(jnp.float32))[None, None, :])

    def step(state, t):
        xt = x[:, t].astype(jnp.float32)
        St = state * a[:, t][..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t].astype(jnp.float32), xt,
            B[:, t].astype(jnp.float32))
        yt = jnp.einsum("bhpn,bn->bhp", St, C[:, t].astype(jnp.float32))
        return St, yt

    N = B.shape[-1]
    _, ys = jax.lax.scan(step, jnp.zeros((Bsz, H, P, N), jnp.float32),
                         jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)
    return (y + D.astype(jnp.float32)[None, None, :, None]
            * x.astype(jnp.float32)).astype(x.dtype)

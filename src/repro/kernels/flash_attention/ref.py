"""Pure-jnp oracle for the flash attention kernel."""
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """Plain softmax attention.  q: (B,Sq,H,D); k/v: (B,Skv,KV,D)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D) / math.sqrt(D)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qf, k.astype(jnp.float32))
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)

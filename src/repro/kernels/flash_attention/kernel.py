"""Pallas TPU flash attention (causal / sliding-window, GQA).

Tiling: grid = (batch*kv_heads*q_groups, n_q_blocks, n_kv_blocks) with the
KV dimension innermost; online-softmax statistics (m, l) and the output
accumulator live in VMEM scratch and persist across the KV grid steps
(TPU grids execute sequentially), exactly the blocking the paper's CGRA
mapper would choose: the "PE-resident" accumulator never round-trips HBM —
this is what removes the O(S * n_blocks) accumulator traffic that
dominates the pure-jnp path's memory roofline term.

Block shapes are (BQ, D) x (BK, D) with D padded to a lane multiple of 128
and BQ/BK multiples of 8 (f32 sublane) — MXU-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 bq: int, bk: int, n_kv: int, causal: bool, window: int,
                 scale: float, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Skv, KV, D).  Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    n_q = pl.cdiv(Sq, bq)
    n_kv = pl.cdiv(Skv, bk)
    # fold (B, KV, G) into one leading grid axis; pad seq dims to block
    # multiples (padded KV columns are masked by seq_len, padded Q rows are
    # sliced off the output)
    pad_q = n_q * bq - Sq
    pad_k = n_kv * bk - Skv
    qr = q.reshape(B, Sq, KV, G, D).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV * G, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
        window=window, scale=scale, seq_len=Skv)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV * G, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=G: (b // g, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=G: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out[:, :Sq].reshape(B, KV, G, Sq, D).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sq, H, D)

"""Pallas TPU kernel: chunked RWKV-6 (Finch) WKV with data-dependent decay.

The token recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T is the RecMII-style
loop-carried dependence of this family (DESIGN.md §4) — the chunked form
trades it for an intra-chunk quadratic with *non-positive* exponents (every
exp() is safe) plus an inter-chunk state carry.

Tiling: grid = (B*H, n_chunks), chunk axis innermost — TPU grids execute
sequentially, so the (K, K) per-head state lives in VMEM scratch across the
whole sequence and never round-trips HBM (the pure-jnp path carries it
through a lax.scan in registers/HBM at XLA's mercy).  Block shapes are
(1, L, K) with K a lane multiple (pad on host) and L the chunk length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_scr, *,
                 chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)                 # (L, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)               # (L, K), <= 0
    u = u_ref[0].astype(jnp.float32)                 # (K,)
    L = chunk

    cum = jnp.cumsum(lw, axis=0)                     # inclusive
    cum_ex = cum - lw                                # exclusive
    state = s_scr[...]

    # inter-chunk: o_state[t] = (r_t * exp(cum_ex[t])) @ S
    r_dec = r * jnp.exp(cum_ex)
    o_state = jax.lax.dot(r_dec, state)              # (L, K)

    # intra-chunk (strictly causal): a[t,i] = sum_d r k exp(cum_ex[t]-cum[i])
    expo = cum_ex[:, None, :] - cum[None, :, :]      # (L, L, K)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    expo = jnp.where(tri[:, :, None], expo, -jnp.inf)
    a = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(expo), axis=2)
    o_intra = jax.lax.dot(a, v)                      # (L, K)

    # diagonal bonus term
    diag = jnp.sum(r * u[None, :] * k, axis=1)       # (L,)
    o_ref[0] = (o_state + o_intra + diag[:, None] * v).astype(o_ref.dtype)

    # state update: S' = diag(exp(cum[-1])) S + sum_i exp(cum[-1]-cum[i]) k v^T
    decay_all = jnp.exp(cum[-1])                     # (K,)
    k_dec = k * jnp.exp(cum[-1:, :] - cum)           # (L, K), exponent <= 0
    s_scr[...] = state * decay_all[:, None] + jax.lax.dot(k_dec.T, v)


def wkv6(r, k, v, log_w, u, *, chunk: int = 32, interpret: bool = False):
    """Chunked WKV6.  r,k,v,log_w: (B, S, H, K); u: (H, K) -> (B, S, H, K)."""
    B, S, H, K = r.shape
    n = pl.cdiv(S, chunk)
    pad = n * chunk - S

    def prep(x):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3).reshape(B * H, n * chunk, K)

    rr, kk, vv, lw = (prep(x) for x in (r, k, v, log_w))
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, K), lambda bh, ci, h=H: (bh % h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, n * chunk, K), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, lw, u)
    return out.reshape(B, H, n * chunk, K).transpose(0, 2, 1, 3)[:, :S]

"""Jitted public wrapper for the rwkv6 WKV kernel."""
import functools

import jax

from repro.kernels.rwkv6.kernel import wkv6


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_op(r, k, v, log_w, u, *, chunk: int = 32, interpret: bool = False):
    return wkv6(r, k, v, log_w, u, chunk=chunk, interpret=interpret)

"""Pure-jnp oracle for the rwkv6 kernel: token-by-token recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, log_w, u):
    """Sequential WKV6.  r,k,v,log_w: (B, S, H, K); u: (H, K)."""
    B, S, H, K = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    w = jnp.exp(log_w.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], w[:, t]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        return state * wt[..., None] + kv, o

    _, outs = jax.lax.scan(step, jnp.zeros((B, H, K, K), jnp.float32),
                           jnp.arange(S))
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype)

"""Activation-sharding context: lets the (sharding-agnostic) model code
drop `with_sharding_constraint`s that the step builders configure.

Without explicit activation constraints XLA's SPMD propagation is free to
pick pathological layouts (e.g. replicating the batch dim and sharding
d_model across the FSDP axis), which wrecks both memory and collective
behavior — constraining `hidden` / `logits` / expert buffers pins the
intended DP x TP program.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: contextvars.ContextVar[Optional[Dict[str, NamedSharding]]] = \
    contextvars.ContextVar("activation_rules", default=None)


def make_rules(mesh: Mesh, batch_sharded: bool = True,
               strategy: str = "tp2d",
               kv_tp_ok: bool = True) -> Dict[str, NamedSharding]:
    from repro.sharding.specs import dp_axes, mesh_axis
    dp = dp_axes(mesh, strategy)
    tp = mesh_axis(mesh, "model") if strategy != "fsdp" else None
    if batch_sharded:
        hidden = P(dp, None, None)
        tokens2d = P(dp, None)
        logits = P(dp, None, tp)
        qkv = P(dp, None, tp, None)
    else:                       # sequence-parallel fallback (batch too small)
        hidden = P(None, dp, None)
        tokens2d = P(dp, None)          # flattened tokens still shard dim 0
        logits = P(None, dp, tp)
        qkv = P(None, dp, tp, None)
    rules = {
        "hidden": hidden,
        "logits": logits,
        "qkv": qkv,
        "tokens2d": tokens2d,
        "expert_buf": P(tp, None, None),       # (E, C, d): experts over TP
        "expert_hidden": P(tp, None, None),    # (E, C, f)
        # grouped (GShard-style) dispatch: groups align with the DP shards,
        # experts with TP — the group<->expert reshard is the EP all-to-all
        "moe_tokens_g": P(dp, None, None),     # (G, Tl, d)
        "expert_buf_g": P(dp, tp, None, None),     # (G, E, C, d)
        "expert_hidden_g": P(dp, tp, None, None),  # (G, E, C, f)
        # whole-head attention sharding (attn_head_shard="heads"): q heads
        # over TP (GSPMD pads ragged head counts); kv heads replicate when
        # kv_heads % tp != 0 so scores never reduce across devices
        "moe_gathered": P(dp, None, None, tp),     # (G, Tl, k, d/tp)
        "q_heads": P(dp if batch_sharded else None, None, tp, None),
        "kv_heads": P(dp if batch_sharded else None, None,
                      tp if kv_tp_ok else None, None),
    }
    return {k: NamedSharding(mesh, v) for k, v in rules.items()}


@contextlib.contextmanager
def activation_sharding(rules: Optional[Dict[str, NamedSharding]]):
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def constrain(x, kind: str):
    rules = _RULES.get()
    if rules is None or kind not in rules:
        return x
    sh = rules[kind]
    if x.ndim != len(sh.spec):
        return x
    return jax.lax.with_sharding_constraint(x, sh)

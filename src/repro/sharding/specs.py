"""Sharding rules: logical axes -> PartitionSpec over the (pod, data, model)
mesh.

Parallelism layout (MaxText-style, generalizes to any axis sizes):

  * DP   — batch over ('pod', 'data') (pods compose with the data axis)
  * FSDP — parameter d_model/reduction dims over 'data' (ZeRO-3: optimizer
           state inherits the param specs, so it is fully sharded too)
  * TP   — heads / ffn / vocab / experts over 'model' (Megatron pairs:
           column-parallel then row-parallel, one all-reduce per block)
  * EP   — MoE expert dim over 'model'; dispatch scatter = the all-to-all
  * SP   — long-context cells shard sequence over ('pod', 'data') when the
           batch axis is too small (e.g. long_500k with batch 1), and the
           decode KV cache over 'model' when kv_heads < model-axis size
           (flash-decode style partial-softmax combine, inserted by XLA)

Nothing here hard-codes axis sizes; scaling to 1000+ nodes only grows the
'pod'/'data' axes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def mesh_axis(mesh: Mesh, name: str) -> Optional[str]:
    return name if name in mesh.axis_names else None


def dp_axes(mesh: Mesh, strategy: str = "tp2d"):
    """Composite DP axis.

    tp2d: ('pod', 'data') — the model axis is reserved for TP/EP.
    fsdp: ('data', 'model') — batch over the whole pod; the pod axis stays
    pure (possibly redundant) DP so a fixed global batch still lowers on
    the 2-pod mesh (at real scale the batch would grow with pods).
    """
    if strategy == "fsdp":
        axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    else:
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


def fsdp_weight_axes(mesh: Mesh):
    """Combined weight-shard axes for the pure-FSDP (ZeRO-3) strategy."""
    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    return axes if axes else None


def param_specs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Any]:
    """PartitionSpec pytree matching ``init_params(cfg)``'s structure."""
    if cfg.shard_strategy == "fsdp":
        fsdp = fsdp_weight_axes(mesh)      # weights over (data x model)
        tp = None                          # no tensor parallelism
    else:
        fsdp = mesh_axis(mesh, "data")
        tp = mesh_axis(mesh, "model")

    # whole-head mode: keep KV projections off the TP axis when kv heads
    # don't divide it (their activations replicate; weights follow)
    kv_tp = tp
    if (tp is not None and cfg.attn_head_shard == "heads"
            and cfg.kv_heads % mesh.shape[tp] != 0):
        kv_tp = None

    def attn_specs():
        s = {
            "wq": P(None, fsdp, tp),
            "wk": P(None, fsdp, kv_tp),
            "wv": P(None, fsdp, kv_tp),
            "wo": P(None, tp, fsdp),
        }
        if cfg.qk_norm:
            s["q_norm"] = P(None, None)
            s["k_norm"] = P(None, None)
        return s

    def mlp_specs():
        s = {"w_up": P(None, fsdp, tp), "w_down": P(None, tp, fsdp)}
        if cfg.mlp_act == "silu":
            s["w_gate"] = P(None, fsdp, tp)
        return s

    specs: Dict[str, Any] = {
        "embed": P(tp, fsdp),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(fsdp, tp)
    if cfg.family in ("dense", "hubert", "paligemma"):
        specs.update(attn=attn_specs(), mlp=mlp_specs(),
                     norm1=P(None, None), norm2=P(None, None))
    elif cfg.family == "moe":
        moe = {
            "router": P(None, fsdp, None),
            "we_gate": P(None, tp, fsdp, None),
            "we_up": P(None, tp, fsdp, None),
            "we_down": P(None, tp, None, fsdp),
        }
        if cfg.n_shared_experts:
            moe.update(ws_gate=P(None, fsdp, tp), ws_up=P(None, fsdp, tp),
                       ws_down=P(None, tp, fsdp))
        if cfg.dense_residual:
            moe["dense"] = mlp_specs()
        specs.update(attn=attn_specs(), moe=moe,
                     norm1=P(None, None), norm2=P(None, None))
    elif cfg.family == "rwkv6":
        specs["rwkv"] = {
            "mix": P(None, None, None),
            "wr": P(None, fsdp, tp), "wk": P(None, fsdp, tp),
            "wv": P(None, fsdp, tp), "wg": P(None, fsdp, tp),
            "ww": P(None, fsdp, tp),
            "w_bias": P(None, tp), "u": P(None, tp),
            "wo": P(None, tp, fsdp), "ln_x": P(None, tp),
            "ffn_k": P(None, fsdp, tp), "ffn_v": P(None, tp, fsdp),
            "ffn_r": P(None, fsdp, tp),
            "norm1": P(None, None), "norm2": P(None, None),
        }
    elif cfg.family == "zamba2":
        specs["mamba"] = {
            "w_in": P(None, fsdp, tp),
            "conv_w": P(None, None, tp),
            "A_log": P(None, None), "D": P(None, None),
            "dt_bias": P(None, None),
            "w_out": P(None, tp, fsdp),
            "norm": P(None, None), "gate_norm": P(None, tp),
        }
        specs["shared_attn"] = attn_specs()
        specs["shared_mlp"] = mlp_specs()
        specs["shared_norm1"] = P(None, None)
        specs["shared_norm2"] = P(None, None)
    if cfg.frontend == "audio":
        specs["frontend_proj"] = P(fsdp, tp)
        specs["mask_embed"] = P(None)
    if cfg.frontend == "image":
        specs["img_proj"] = P(fsdp, tp)
    return specs


def batch_specs(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                kind: str) -> Dict[str, Any]:
    """Input-batch PartitionSpecs; batch over DP if divisible else seq."""
    dp = dp_axes(mesh, cfg.shard_strategy)
    dp_size = 1
    if dp:
        for a in dp:
            dp_size *= mesh.shape[a]
    batch_ok = dp and (global_batch % dp_size == 0) and global_batch >= dp_size
    bspec = dp if batch_ok else None
    sspec = None if batch_ok else dp            # sequence-parallel fallback
    if cfg.family == "hubert":
        return {"features": P(bspec, sspec, None),
                "mask": P(bspec, sspec), "targets": P(bspec, sspec)}
    out = {"tokens": P(bspec, sspec)}
    if cfg.family == "paligemma":
        out["img_embeds"] = P(bspec, None, None)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int) -> Dict[str, Any]:
    """Decode-cache PartitionSpecs (see module docstring for the policy)."""
    dp = dp_axes(mesh, cfg.shard_strategy)
    tp = (mesh_axis(mesh, "model") if cfg.shard_strategy != "fsdp" else None)
    tp_size = mesh.shape[tp] if tp else 1
    dp_size = 1
    if dp:
        for a in dp:
            dp_size *= mesh.shape[a]
    batch_ok = dp and (batch % dp_size == 0)
    b = dp if batch_ok else None
    # KV heads over model when divisible, else shard cache sequence (SP)
    heads_ok = tp and (cfg.kv_heads % tp_size == 0)
    kvh = tp if heads_ok else None
    kvs = None if heads_ok else (tp if batch_ok else dp)
    if not batch_ok and not heads_ok:
        kvs = dp          # batch=1 & few kv heads: SP over the big DP axis
    if cfg.family in ("dense", "moe", "paligemma"):
        return {"k": P(None, b, kvs, kvh, None),
                "v": P(None, b, kvs, kvh, None), "len": P()}
    if cfg.family == "rwkv6":
        return {"wkv": P(None, b, tp, None, None),
                "tmix": P(None, b, None), "cmix": P(None, b, None),
                "len": P()}
    if cfg.family == "zamba2":
        return {"conv": P(None, b, None, tp),
                "ssm": P(None, b, tp, None, None),
                "k": P(None, b, kvs, kvh, None),
                "v": P(None, b, kvs, kvh, None), "len": P()}
    raise ValueError(cfg.family)


def activation_spec(mesh: Mesh, global_batch: int) -> P:
    dp = dp_axes(mesh)
    dp_size = 1
    if dp:
        for a in dp:
            dp_size *= mesh.shape[a]
    if dp and global_batch % dp_size == 0 and global_batch >= dp_size:
        return P(dp, None, None)
    return P(None, dp, None)


def to_shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_specs(tree_specs, tree_shapes, mesh: Mesh):
    """Shape-aware spec cleanup: pad each PartitionSpec to the leaf's full
    rank and drop mesh axes from any dimension they don't divide evenly
    (XLA requires divisibility for explicit in/out shardings).  Keeps the
    sharding rules declarative while staying correct for odd sizes such as
    hubert's 504-entry codebook embedding."""
    def fix(spec, leaf):
        if not isinstance(spec, P):
            return spec
        shape = leaf.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        return P(*(ax if dim % _axes_size(mesh, ax) == 0 else None
                   for dim, ax in zip(shape, entries)))
    return jax.tree.map(fix, tree_specs, tree_shapes,
                        is_leaf=lambda x: isinstance(x, P))

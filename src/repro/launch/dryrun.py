import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as a fresh process (python -m repro.launch.dryrun ...):
the XLA_FLAGS line above runs before any other import so the host platform
exposes 512 placeholder devices for the production meshes.

For each cell we jit the appropriate step (train_step / prefill / decode)
with explicit shardings, .lower() it on ShapeDtypeStructs (no allocation),
.compile(), and record memory_analysis(), cost_analysis() and the parsed
collective schedule into artifacts/dryrun/<cell>.json — the roofline
analysis and EXPERIMENTS.md read from these.
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402

from repro.analysis.hlo_cost import analyze_hlo                               # noqa: E402
from repro.analysis.roofline import analyze_per_device, model_flops          # noqa: E402
from repro.configs import ARCHS, FAMILIES, get_config                        # noqa: E402
from repro.configs.shapes import SHAPES, cell_skip_reason                    # noqa: E402
from repro.launch.input_specs import (batch_structs, cache_structs,          # noqa: E402
                                      opt_structs, param_structs,
                                      token_structs)
from repro.train.optimizer import OptConfig                                  # noqa: E402


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512).

    Quarantined here with its only consumers (this dry-run and the
    collectives CLI, both of which force 512 host devices before jax
    loads): host-scale code must not pull a 512-chip mesh constructor
    out of ``repro.launch.mesh``.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def opt_for(cfg) -> OptConfig:
    # factored second moment for the very large configs (optimizer memory)
    factored = cfg.param_count() > 100e9
    return OptConfig(factored=factored)


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               overrides=None):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    with mesh:
        if shape.kind == "train":
            from repro.train.train_step import make_sharded_train_step
            opt = opt_for(cfg)
            step, _ = make_sharded_train_step(cfg, opt, mesh,
                                              shape.global_batch)
            args = (param_structs(cfg), opt_structs(cfg, opt),
                    batch_structs(cfg, shape))
        elif shape.kind == "prefill":
            from repro.serve.serve_step import make_sharded_prefill
            step, _ = make_sharded_prefill(cfg, mesh, shape.global_batch)
            args = (param_structs(cfg), batch_structs(cfg, shape))
        else:  # decode
            from repro.serve.serve_step import make_sharded_decode
            step, _ = make_sharded_decode(cfg, mesh, shape.global_batch)
            args = (param_structs(cfg),
                    cache_structs(cfg, shape.global_batch, shape.seq_len),
                    token_structs(shape.global_batch))
        lowered = step.lower(*args)
        compiled = lowered.compile()
    return cfg, shape, lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             overrides=None, tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}{tag}"
    out_path = out_dir / f"{cell_id}.json"
    skip = cell_skip_reason(FAMILIES[arch], shape_name)
    if skip:
        rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "status": "skipped", "reason": skip}
        out_path.write_text(json.dumps(rec, indent=1))
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.size
        cfg, shape, lowered, compiled = lower_cell(arch, shape_name, mesh,
                                                   mesh_name, overrides)
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_size_in_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_size_in_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
            }
        except Exception:
            mem_d = {}
        hlo = compiled.as_text()
        hc = analyze_hlo(hlo)
        mflops = model_flops(cfg, shape.kind, shape.seq_len,
                             shape.global_batch, decode=(shape.kind == "decode"))
        per_dev_bytes = (mem_d.get("argument_size_in_bytes", 0)
                         + mem_d.get("temp_size_in_bytes", 0)) / chips
        res = analyze_per_device(arch, shape_name, mesh_name, chips, hc,
                                 mflops, per_dev_bytes)
        rec = {
            "cell": cell_id, "arch": arch, "shape": shape_name,
            "mesh": mesh_name, "status": "ok",
            "compile_s": time.time() - t0,
            "memory_analysis": mem_d,
            "cost_analysis_xla": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float))
                                  and k in ("flops", "bytes accessed",
                                            "transcendentals")},
            "hlo_cost": {k: v for k, v in hc.items() if k != "collectives"},
            "roofline": res.to_dict(),
            "hlo_bytes_len": len(hlo),
            "overrides": overrides or {},
        }
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "status": "error",
               "compile_s": time.time() - t0,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:],
               "overrides": overrides or {}}
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ModelConfig overrides (perf exps)")
    ap.add_argument("--tag", default="", help="suffix for override runs")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    overrides = json.loads(args.overrides) if args.overrides else None
    n_devices = len(jax.devices())
    assert n_devices >= 512, f"host platform has {n_devices} devices, need 512"
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{arch}__{shape}__{mesh_name}{args.tag}"
                if args.skip_existing and (out_dir / f"{cell}.json").exists():
                    print(f"[skip-existing] {cell}", flush=True)
                    continue
                rec = run_cell(arch, shape, mp, out_dir, overrides, args.tag)
                status = rec["status"]
                extra = (f" bottleneck={rec['roofline']['bottleneck']}"
                         if status == "ok" else
                         f" reason={rec.get('reason', rec.get('error'))}")
                print(f"[{status}] {cell} ({rec.get('compile_s', 0):.0f}s)"
                      f"{extra}", flush=True)


if __name__ == "__main__":
    main()

"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together the full substrate: config registry -> deterministic data
pipeline (host-sharded, restart-safe) -> pjit-ed train step with explicit
shardings (FSDP x TP over whatever mesh this host offers) -> sharded
checkpointing -> fault-tolerant supervisor (heartbeat/straggler/restart).
On the CPU container this trains the reduced --smoke configs or a --scale
override (~100M params) for a few hundred steps; on a real fleet the same
file runs under multi-host JAX with the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, smoke_config
from repro.data.pipeline import DataConfig, host_batch
from repro.launch.mesh import make_host_mesh, make_mesh
from repro.runtime.fault_tolerance import FaultConfig, Supervisor
from repro.models.common import init_params
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_sharded_train_step, make_train_state


def build(cfg, opt, mesh, global_batch, n_microbatches, compress):
    step, (p_specs, o_specs, b_specs) = make_sharded_train_step(
        cfg, opt, mesh, global_batch, n_microbatches, compress)
    return step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--scale", default=None,
                    help="JSON dict of ModelConfig overrides")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="error-feedback int8 gradient compression")
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4,2' for a (data=4, model=2) mesh")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.scale:
        cfg = dataclasses.replace(cfg, **json.loads(args.scale))
    opt = OptConfig(lr=args.lr, total_steps=args.steps,
                    warmup_steps=max(1, args.steps // 20))
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[:len(shape)])
    else:
        mesh = make_host_mesh()
    dc = DataConfig(seed=args.seed, global_batch=args.batch,
                    seq_len=args.seq)

    with mesh:
        step_fn = build(cfg, opt, mesh, args.batch, args.microbatches,
                        args.compress)

        def make_state():
            params = init_params(jax.random.PRNGKey(args.seed), cfg)
            return {"params": params,
                    "opt": make_train_state(cfg, opt, params, args.compress)}

        n_params = None
        losses = []

        def one_step(state, step_idx):
            nonlocal n_params
            batch = {k: jax.numpy.asarray(v) for k, v in
                     host_batch(cfg, dc, step_idx).items()}
            params, opt_state, metrics = step_fn(state["params"],
                                                 state["opt"], batch)
            if n_params is None:
                n_params = sum(int(np.prod(p.shape))
                               for p in jax.tree.leaves(params))
            loss = float(metrics["total_loss"])
            losses.append(loss)
            if step_idx % args.log_every == 0:
                print(f"step {step_idx:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(metrics['grad_norm']):7.3f}  "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            return {"params": params, "opt": opt_state}

        t0 = time.time()
        if args.ckpt_dir:
            sup = Supervisor(
                FaultConfig(ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every),
                make_state=make_state, step_fn=one_step)
            state = sup.run(args.steps)
        else:
            state = make_state()
            for i in range(args.steps):
                state = one_step(state, i)
        wall = time.time() - t0

    first = float(np.mean(losses[:5])) if losses else float("nan")
    last = float(np.mean(losses[-5:])) if losses else float("nan")
    print(f"\narch={cfg.name} params={n_params:,} steps={args.steps} "
          f"wall={wall:.1f}s  loss {first:.3f} -> {last:.3f}")
    assert math.isfinite(last), "training diverged"
    return {"first_loss": first, "last_loss": last, "params": n_params,
            "wall_s": wall}


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input (no allocation).

Used by the dry-run: weak-type-correct, shardable, covering params,
optimizer state, batches and decode caches for every (arch x shape) cell.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.common import ModelConfig
from repro.train.optimizer import OptConfig

AUDIO_FRAME_DIM = None     # = d_model (stub frontend supplies embeddings)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def param_structs(cfg: ModelConfig) -> Dict[str, Any]:
    """Mirror init_params() shapes without allocating."""
    return jax.eval_shape(
        lambda k: __import__("repro.models.common", fromlist=["init_params"])
        .init_params(k, cfg), jax.random.PRNGKey(0))


def batch_structs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "hubert":
        return {
            "features": _sds((B, S, cfg.d_model), jnp.float32),
            "mask": _sds((B, S), jnp.bool_),
            "targets": _sds((B, S), jnp.int32),
        }
    out = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.family == "paligemma":
        out["img_embeds"] = _sds((B, cfg.n_prefix_tokens, cfg.d_model),
                                 jnp.float32)
    return out


def cache_structs(cfg: ModelConfig, batch: int, max_len: int):
    from repro.models.lm import init_cache
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def opt_structs(cfg: ModelConfig, opt: OptConfig, compress: bool = False):
    from repro.models.common import init_params
    from repro.train.train_step import make_train_state

    def build(k):
        p = init_params(k, cfg)
        return make_train_state(cfg, opt, p, compress)
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def token_structs(batch: int):
    return _sds((batch, 1), jnp.int32)

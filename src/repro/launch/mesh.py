"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module touches no JAX device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any JAX
import, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist on this host, as a 1D 'data' mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))

"""Host mesh construction + forced-device-count helpers.

Everything here is a function (never a module-level constant) and jax is
imported *inside* the functions: importing this module must touch no JAX
device state, because the whole point of ``forced_host_devices`` is to
set ``--xla_force_host_platform_device_count`` **before** jax first
initializes its backends.  Once jax has picked up the flag, the CPU
platform exposes N virtual devices — the mechanism the sharded serving
cluster uses to test multi-device execution paths on a plain CPU host
(see docs/serving.md for the recipe).

The multi-pod production mesh used by the 512-device dry-run lives with
its only consumer, ``repro.launch.dryrun`` (which sets the forced count
to 512 at the top of its own module) — it is deliberately not part of
this module's surface.
"""
from __future__ import annotations

import os
import sys
from typing import Mapping, Optional, Sequence

_FLAG = "xla_force_host_platform_device_count"


def _with_forced_count(flags: str, n: int) -> str:
    """``flags`` with any existing forced-count flag replaced by ``n``."""
    kept = [f for f in flags.split() if not f.startswith(f"--{_FLAG}=")]
    kept.append(f"--{_FLAG}={n}")
    return " ".join(kept)


def forced_host_devices(n: int) -> int:
    """Make the CPU backend expose ``n`` virtual devices in THIS process.

    Patches ``XLA_FLAGS`` in the environment (replacing any existing
    forced-count flag).  The flag is only read when jax initializes, so
    this must run before the first ``import jax`` anywhere in the
    process; calling it after jax is already imported raises rather than
    silently doing nothing — a too-late call is exactly the bug this
    guard exists to surface.  Returns ``n`` for convenience::

        from repro.launch.mesh import forced_host_devices
        forced_host_devices(4)        # BEFORE any jax import
        import jax
        assert len(jax.devices()) == 4
    """
    if n < 1:
        raise ValueError(f"forced device count must be >= 1, got {n}")
    if "jax" in sys.modules:
        raise RuntimeError(
            f"forced_host_devices({n}) called after jax was imported — "
            f"XLA_FLAGS is only read at backend init, so the flag would "
            f"be ignored.  Set it before the first jax import (or launch "
            f"a fresh process with forced_device_env({n}))")
    os.environ["XLA_FLAGS"] = _with_forced_count(
        os.environ.get("XLA_FLAGS", ""), n)
    return n


def forced_device_env(n: int,
                      base: Optional[Mapping[str, str]] = None) -> dict:
    """Environment dict for a *subprocess* that should see ``n`` host
    devices: a copy of ``base`` (default ``os.environ``) with the forced
    count patched into ``XLA_FLAGS``.  The escape hatch when jax is
    already live in the current process — the child reads the flag at
    its own backend init."""
    if n < 1:
        raise ValueError(f"forced device count must be >= 1, got {n}")
    env = dict(base if base is not None else os.environ)
    env["XLA_FLAGS"] = _with_forced_count(env.get("XLA_FLAGS", ""), n)
    return env


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    import jax
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist on this host, as a 1D 'data' mesh — the
    mesh the sharded engine path (``ual.engine.ShardedKernelEngine``)
    shard_maps the batch axis over."""
    import jax
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))

"""Batched serving driver: continuous-batching prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --requests 16 --max-new 32

A miniature production serving loop: requests arrive with different
prompt lengths, are left-padded into a batch, prefilled once, then decoded
token-by-token with the KV/state cache sharded per
``repro.sharding.specs.cache_specs``.  Works for every family that
decodes (dense / MoE / VLM / RWKV6 / Zamba2 hybrid).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.common import init_params
from repro.models.lm import decode_step, init_cache


def greedy_generate(params, cfg, prompts, max_new: int, max_len: int):
    """prompts: list of 1D int arrays.  Returns (B, max_new) tokens."""
    B = len(prompts)
    cache = init_cache(cfg, B, max_len)
    dstep = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    # sequential prefill through the decode path keeps cache semantics
    # identical for every family (attention KV vs recurrent state)
    maxp = max(len(p) for p in prompts)
    padded = np.zeros((B, maxp), np.int32)
    for i, p in enumerate(prompts):
        padded[i, maxp - len(p):] = p          # left-pad
    for t in range(maxp):
        logits, cache = dstep(params, cache, jnp.asarray(padded[:, t:t + 1]))
    out = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True).astype(jnp.int32)
    for _ in range(max_new):
        out.append(np.asarray(tok))
        logits, cache = dstep(params, cache, tok)
        tok = jnp.argmax(logits[:, -1, :], axis=-1,
                         keepdims=True).astype(jnp.int32)
    return np.concatenate(out, axis=1)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "hubert":
        raise SystemExit("hubert is encoder-only: no decode path")
    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab, rng.integers(4, 12)).astype(np.int32)
               for _ in range(args.requests)]
    with mesh:
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        t0 = time.time()
        toks = greedy_generate(params, cfg, prompts, args.max_new,
                               max_len=64 + args.max_new)
        wall = time.time() - t0
    tput = args.requests * args.max_new / wall
    print(f"arch={cfg.name} requests={args.requests} new={args.max_new} "
          f"wall={wall:.1f}s  {tput:.1f} tok/s")
    print("sample:", toks[0][:16].tolist())
    assert toks.shape == (args.requests, args.max_new)
    return {"tokens": toks, "wall_s": wall, "tok_s": tput}


if __name__ == "__main__":
    main()

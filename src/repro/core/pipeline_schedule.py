"""Pipeline-parallel schedules derived from the paper's modulo framework.

A software-pipelined loop on a CGRA and a pipeline-parallel training step
are the same object: *stages* are FUs, a *microbatch* is a loop iteration,
and the initiation interval II is the number of ticks between consecutive
microbatch injections.  This module reuses the reservation-table algebra of
the CGRA mapper to derive classic training schedules (GPipe, 1F1B,
interleaved 1F1B) plus a generic modulo scheduler, and computes their
bubble fraction and activation-memory footprint.

The schedules are *verified* the same way CGRA mappings are: an interpreter
replays the reservation table and checks every dependence
(fwd(m,s) -> fwd(m,s+1), fwd(m,S-1) -> bwd(m,S-1), bwd(m,s) -> bwd(m,s-1)),
and `tests/test_pipeline_schedule.py` additionally executes a toy model
under the schedule and compares against sequential execution.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

FWD, BWD = "F", "B"
Slot = Tuple[str, int, int]   # (phase, microbatch, chunk/virtual-stage)


@dataclass
class PipelineSchedule:
    name: str
    n_stages: int
    n_microbatches: int
    n_chunks: int                          # virtual stages per device
    table: List[List[Optional[Slot]]]      # [t][stage] -> slot or None
    fwd_cost: float = 1.0
    bwd_cost: float = 2.0

    # -- analytics (the CGRA mapper's II / utilization, renamed) -----------
    @property
    def total_ticks(self) -> int:
        return len(self.table)

    @property
    def steady_ii(self) -> float:
        """Ticks per microbatch in steady state (CGRA II analogue)."""
        work = self.n_chunks * (1 + 1)     # one fwd + one bwd slot per chunk
        return work

    def bubble_fraction(self) -> float:
        total = self.total_ticks * self.n_stages
        busy = sum(1 for row in self.table for s in row if s is not None)
        return 1.0 - busy / total

    def weighted_bubble_fraction(self) -> float:
        """Bubble fraction with fwd/bwd slot costs (tb != tf)."""
        cost = {FWD: self.fwd_cost, BWD: self.bwd_cost}
        span = 0.0
        busy = 0.0
        for row in self.table:
            tick_cost = max((cost[s[0]] for s in row if s is not None),
                            default=0.0)
            span += tick_cost * self.n_stages
            busy += sum(cost[s[0]] for s in row if s is not None)
        return 1.0 - busy / span if span else 1.0

    def peak_in_flight(self) -> int:
        """Max live activations (microbatches awaiting bwd) on any stage."""
        peak = 0
        live: Dict[int, set] = {s: set() for s in range(self.n_stages)}
        for row in self.table:
            for s, slot in enumerate(row):
                if slot is None:
                    continue
                phase, m, c = slot
                if phase == FWD:
                    live[s].add((m, c))
                else:
                    live[s].discard((m, c))
                peak = max(peak, len(live[s]))
        return peak

    # -- validation -----------------------------------------------------------
    def verify(self) -> None:
        """Replay the table and check every dependence edge (raises on bugs)."""
        S, M, C = self.n_stages, self.n_microbatches, self.n_chunks
        done: Dict[Tuple, int] = {}
        for t, row in enumerate(self.table):
            for s, slot in enumerate(row):
                if slot is None:
                    continue
                phase, m, c = slot
                key = (phase, m, c, s)
                if key in done:
                    raise AssertionError(f"slot {key} scheduled twice")
                # global position in the fwd chain: chunk-major over stages
                pos = c * S + s
                if phase == FWD:
                    if pos > 0:
                        p_s, p_c = (pos - 1) % S, (pos - 1) // S
                        if done.get((FWD, m, p_c, p_s), 1 << 30) >= t:
                            raise AssertionError(
                                f"fwd dep violated m={m} pos={pos} t={t}")
                else:
                    if pos == S * C - 1:
                        if done.get((FWD, m, c, s), 1 << 30) >= t:
                            raise AssertionError(
                                f"fwd->bwd dep violated m={m} t={t}")
                    else:
                        n_s, n_c = (pos + 1) % S, (pos + 1) // S
                        if done.get((BWD, m, n_c, n_s), 1 << 30) >= t:
                            raise AssertionError(
                                f"bwd dep violated m={m} pos={pos} t={t}")
                done[key] = t
        want = S * M * C
        fwd_done = sum(1 for k in done if k[0] == FWD)
        bwd_done = sum(1 for k in done if k[0] == BWD)
        if fwd_done != want or bwd_done != want:
            raise AssertionError(
                f"incomplete schedule: fwd {fwd_done}/{want}, bwd {bwd_done}/{want}")


# ---------------------------------------------------------------------------
# Schedule constructors
# ---------------------------------------------------------------------------

def _empty(n_ticks: int, S: int) -> List[List[Optional[Slot]]]:
    return [[None] * S for _ in range(n_ticks)]


def gpipe(n_stages: int, n_microbatches: int) -> PipelineSchedule:
    S, M = n_stages, n_microbatches
    ticks = (M + S - 1) * 2
    tbl = _empty(ticks, S)
    for m in range(M):
        for s in range(S):
            tbl[m + s][s] = (FWD, m, 0)
    base = M + S - 1
    for m in range(M):
        for s in reversed(range(S)):
            tbl[base + m + (S - 1 - s)][s] = (BWD, m, 0)
    return PipelineSchedule("gpipe", S, M, 1, tbl)


def one_f_one_b(n_stages: int, n_microbatches: int) -> PipelineSchedule:
    """1F1B: same bubble as GPipe, activation memory capped at S in-flight.

    Built with a greedy list scheduler over the dependence graph — the same
    mechanism the CGRA mapper uses (ready ops + resource slots), with the
    1F1B policy 'prefer BWD when available' providing the priority function.
    """
    S, M = n_stages, n_microbatches
    tbl: List[List[Optional[Slot]]] = []
    fwd_done = [[-1] * S for _ in range(M)]     # tick when fwd(m,s) completed
    bwd_done = [[-1] * S for _ in range(M)]
    nf = [0] * S                                 # next microbatch to fwd, per stage
    t = 0
    total = 2 * S * M
    scheduled = 0
    warmup = [min(S - s, M) for s in range(S)]   # fwd's before first bwd
    while scheduled < total and t < 8 * (S + M) * 2:
        row: List[Optional[Slot]] = [None] * S
        for s in range(S):
            # candidate BWD: earliest microbatch whose successor bwd is done
            bm = None
            for m in range(M):
                if bwd_done[m][s] >= 0:
                    continue
                if fwd_done[m][s] < 0 or fwd_done[m][s] >= t:
                    continue
                if s == S - 1 or (bwd_done[m][s + 1] >= 0
                                  and bwd_done[m][s + 1] < t):
                    bm = m
                    break
            fm = None
            m = nf[s]
            if m < M and (s == 0 or (fwd_done[m][s - 1] >= 0
                                     and fwd_done[m][s - 1] < t)):
                fm = m
            # 1F1B policy: after warmup, prefer BWD
            fwds_issued = nf[s]
            if bm is not None and (fwds_issued >= warmup[s] or fm is None):
                row[s] = (BWD, bm, 0)
                bwd_done[bm][s] = t
            elif fm is not None:
                row[s] = (FWD, fm, 0)
                fwd_done[fm][s] = t
                nf[s] += 1
            if row[s] is not None:
                scheduled += 1
        tbl.append(row)
        t += 1
    sched = PipelineSchedule("1f1b", S, M, 1, tbl)
    return sched


def interleaved_1f1b(n_stages: int, n_microbatches: int,
                     n_chunks: int = 2) -> PipelineSchedule:
    """Interleaved (virtual-stage) 1F1B — bubble shrinks by ~1/n_chunks.

    Greedy list scheduling over the chunked dependence chain with the
    'deepest-ready-bwd first, then earliest-ready-fwd' priority.
    """
    S, M, C = n_stages, n_microbatches, n_chunks
    fwd_done: Dict[Tuple[int, int, int], int] = {}
    bwd_done: Dict[Tuple[int, int, int], int] = {}
    tbl: List[List[Optional[Slot]]] = []
    total = 2 * S * M * C
    scheduled = 0
    issued_f = {s: 0 for s in range(S)}
    t = 0
    warm = [(C + 1) * S - 2 * s - 1 for s in range(S)]   # Megatron warmup rule
    while scheduled < total and t < 16 * (S + M) * C:
        row: List[Optional[Slot]] = [None] * S
        for s in range(S):
            # ready BWD on this stage: deepest chunk first, earliest microbatch
            bcand: List[Tuple[int, int]] = []
            for c in reversed(range(C)):
                pos = c * S + s
                for m in range(M):
                    if (m, c, s) in bwd_done:
                        continue
                    if fwd_done.get((m, c, s), 1 << 30) >= t:
                        continue
                    if pos == S * C - 1:
                        bcand.append((m, c))
                        break
                    n_s, n_c = (pos + 1) % S, (pos + 1) // S
                    if bwd_done.get((m, n_c, n_s), 1 << 30) < t:
                        bcand.append((m, c))
                        break
                if bcand:
                    break
            # ready FWD: earliest chunk first, earliest microbatch
            fcand: List[Tuple[int, int]] = []
            for c in range(C):
                pos = c * S + s
                for m in range(M):
                    if (m, c, s) in fwd_done:
                        continue
                    if pos == 0:
                        fcand.append((m, c))
                        break
                    p_s, p_c = (pos - 1) % S, (pos - 1) // S
                    if fwd_done.get((m, p_c, p_s), 1 << 30) < t:
                        fcand.append((m, c))
                        break
                if fcand:
                    break
            if bcand and (issued_f[s] >= warm[s] or not fcand):
                m, c = bcand[0]
                row[s] = (BWD, m, c)
                bwd_done[(m, c, s)] = t
            elif fcand:
                m, c = fcand[0]
                row[s] = (FWD, m, c)
                fwd_done[(m, c, s)] = t
                issued_f[s] += 1
            if row[s] is not None:
                scheduled += 1
        tbl.append(row)
        t += 1
    return PipelineSchedule(f"interleaved_1f1b_c{C}", S, M, C, tbl)


SCHEDULERS = {
    "gpipe": gpipe,
    "1f1b": one_f_one_b,
    "interleaved": interleaved_1f1b,
}


def bubble_model(n_stages: int, n_microbatches: int, n_chunks: int = 1,
                 tf: float = 1.0, tb: float = 2.0) -> float:
    """Closed-form bubble fraction (the RecMII-style analytic bound)."""
    S, M, C = n_stages, n_microbatches, n_chunks
    return (S - 1) * (tf + tb) / (C * M * (tf + tb) + (S - 1) * (tf + tb))

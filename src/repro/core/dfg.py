"""Dataflow-graph IR + generation (Morpher phase 1).

Morpher's compiler frontend turns annotated kernels into a data-rich DFG:
compute / memory / predication nodes with recurrence (loop-carried) edges,
scheduling hints, and data-layout constants embedded into memory nodes.
Here the frontend is JAX:

  * ``DFGBuilder`` — a small builder DSL for loop-body kernels (the analogue
    of Morpher's annotated-C input) with explicit ``load``/``store``/
    ``counter``/``recur`` for memory and loop-carried state,
  * ``trace_into`` — jaxpr-based DFG extraction for the pure-compute part of
    a kernel (the analogue of Morpher's LLVM-based DFG generation),
  * ``interpret`` — the reference executor used for automated test-vector
    validation (paper Table II's distinguishing feature),
  * ``DataLayout`` — round-robin bank allocation with base addresses folded
    into LOAD/STORE node constants (paper §III-A-1).

All values are int32 (the fabric datapath); this gives bit-exact validation
between the DFG interpreter, the cycle-accurate simulator and the Pallas
``cgra_exec`` kernel.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adl import MEM_OPS

INT = np.int32
_MASK = np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# IR
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Operand:
    src: int                 # producing node id
    dist: int = 0            # recurrence distance in iterations
    init: int = 0            # value used for iterations i < dist


@dataclass
class Node:
    id: int
    op: str
    operands: List[Operand] = field(default_factory=list)
    const: Optional[int] = None      # immediate folded into the instruction
    array: Optional[str] = None      # LOAD/STORE target array
    # -- scheduling metadata (paper: ASAP/ALAP hints, parent/child counts) --
    asap: int = 0
    alap: int = 0

    @property
    def is_mem(self) -> bool:
        return self.op in MEM_OPS


@dataclass
class DFG:
    nodes: List[Node]
    arrays: Dict[str, int]                      # name -> length (words)
    name: str = "kernel"
    outputs: Tuple[str, ...] = ()               # arrays to check after run

    def __post_init__(self) -> None:
        self.users: Dict[int, List[Tuple[int, int]]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for k, o in enumerate(n.operands):
                self.users[o.src].append((n.id, k))

    # -- structure -----------------------------------------------------------
    def topo_order(self) -> List[int]:
        """Topological order over non-recurrence (dist==0) edges."""
        indeg = {n.id: 0 for n in self.nodes}
        for n in self.nodes:
            for o in n.operands:
                if o.dist == 0:
                    indeg[n.id] += 1
        order, stack = [], sorted(i for i, d in indeg.items() if d == 0)
        while stack:
            u = stack.pop(0)
            order.append(u)
            for (v, _) in self.users[u]:
                node = self.nodes[v]
                if any(o.src == u and o.dist == 0 for o in node.operands):
                    indeg[v] -= sum(1 for o in node.operands
                                    if o.src == u and o.dist == 0)
                    if indeg[v] == 0:
                        stack.append(v)
        if len(order) != len(self.nodes):
            raise ValueError(f"{self.name}: cycle through dist==0 edges")
        return order

    def recurrence_cycles(self) -> List[List[int]]:
        """Elementary cycles that include >=1 dist>0 edge (loop recurrences).

        Found by, for every dist>0 edge (u -> v), searching a dist==0 path
        v ->* u; the recurrence cycle is that path plus the back edge.
        """
        adj0: Dict[int, List[int]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            for o in n.operands:
                if o.dist == 0:
                    adj0[o.src].append(n.id)
        cycles = []
        for n in self.nodes:
            for o in n.operands:
                if o.dist > 0:
                    u, v = o.src, n.id        # value u(iter i) -> v(iter i+dist)
                    path = _bfs_path(adj0, v, u)
                    if path is not None:
                        cycles.append(path)   # v .. u, closed by back edge
                    elif u == v:
                        cycles.append([u])
        return cycles

    def compute_asap_alap(self, horizon: int) -> None:
        order = self.topo_order()
        asap = {i: 0 for i in order}
        for u in order:
            for (v, _) in self.users[u]:
                for o in self.nodes[v].operands:
                    if o.src == u and o.dist == 0:
                        asap[v] = max(asap[v], asap[u] + 1)
        alap = {i: horizon for i in order}
        for u in reversed(order):
            for o in self.nodes[u].operands:
                if o.dist == 0:
                    alap[o.src] = min(alap[o.src], alap[u] - 1)
        for n in self.nodes:
            n.asap, n.alap = asap[n.id], alap[n.id]

    @property
    def n_mem_ops(self) -> int:
        return sum(1 for n in self.nodes if n.is_mem)


def _bfs_path(adj: Dict[int, List[int]], s: int, t: int) -> Optional[List[int]]:
    if s == t:
        return [s]
    prev, q, seen = {}, [s], {s}
    while q:
        u = q.pop(0)
        for v in adj[u]:
            if v not in seen:
                seen.add(v)
                prev[v] = u
                if v == t:
                    path = [t]
                    while path[-1] != s:
                        path.append(prev[path[-1]])
                    return list(reversed(path))
                q.append(v)
    return None


# ---------------------------------------------------------------------------
# Builder DSL
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Ref:
    id: int


class DFGBuilder:
    def __init__(self, name: str = "kernel"):
        self.name = name
        self._nodes: List[Node] = []
        self._arrays: Dict[str, int] = {}
        self._outputs: List[str] = []
        self._pending: Dict[int, Tuple[int, int]] = {}   # placeholder -> (init, extra_dist)
        self._bound: Dict[int, int] = {}                 # placeholder -> producer id

    # -- raw node -----------------------------------------------------------
    def op(self, opcode: str, *args, const: Optional[int] = None,
           array: Optional[str] = None) -> Ref:
        # Fold a single *trailing* immediate into the instruction const field
        # (paper: constants embedded as node metadata); any other immediate
        # becomes an explicit MOVC so operand order is preserved.
        args = list(args)
        if (const is None and args
                and isinstance(args[-1], (int, np.integer))):
            const = int(args.pop())
        operands = []
        for a in args:
            if isinstance(a, Ref):
                operands.append(Operand(a.id))
            elif isinstance(a, (int, np.integer)):
                operands.append(Operand(self.op("MOVC", const=int(a)).id))
            else:
                raise TypeError(f"bad operand {a!r}")
        nid = len(self._nodes)
        self._nodes.append(Node(nid, opcode, operands, const=const, array=array))
        return Ref(nid)

    # -- memory ---------------------------------------------------------------
    def array(self, name: str, length: int, output: bool = False) -> str:
        self._arrays[name] = int(length)
        if output:
            self._outputs.append(name)
        return name

    def load(self, array: str, idx) -> Ref:
        """LOAD: operands [idx?]; const holds the (base+)fixed offset."""
        assert array in self._arrays, f"undeclared array {array}"
        if isinstance(idx, (int, np.integer)):
            return self.op("LOAD", const=int(idx), array=array)
        return self.op("LOAD", idx, array=array)

    def store(self, array: str, idx, value) -> Ref:
        """STORE: operands [idx?, value]; const holds the fixed offset."""
        assert array in self._arrays, f"undeclared array {array}"
        if array not in self._outputs:
            self._outputs.append(array)
        if not isinstance(value, Ref):
            value = self.op("MOVC", const=int(value))
        if isinstance(idx, (int, np.integer)):
            nid = len(self._nodes)
            self._nodes.append(Node(nid, "STORE", [Operand(value.id)],
                                    const=int(idx), array=array))
            return Ref(nid)
        nid = len(self._nodes)
        self._nodes.append(Node(nid, "STORE",
                                [Operand(idx.id), Operand(value.id)],
                                array=array))
        return Ref(nid)

    # -- loop-carried state ---------------------------------------------------
    def counter(self, start: int = 0, step: int = 1) -> Ref:
        """Loop induction variable: i_t = i_{t-1} + step, i_0 = start."""
        nid = len(self._nodes)
        self._nodes.append(Node(nid, "ADD",
                                [Operand(nid, dist=1, init=start - step)],
                                const=step))
        return Ref(nid)

    def recur(self, init: int = 0, dist: int = 1) -> Ref:
        """Placeholder for a loop-carried value; close with ``bind``."""
        nid = len(self._nodes)
        self._nodes.append(Node(nid, "__PH__"))
        self._pending[nid] = (int(init), dist)
        return Ref(nid)

    def bind(self, placeholder: Ref, producer: Ref) -> None:
        assert placeholder.id in self._pending, "not a recur() placeholder"
        self._bound[placeholder.id] = producer.id

    # -- finalize ------------------------------------------------------------
    def build(self) -> DFG:
        missing = set(self._pending) - set(self._bound)
        if missing:
            raise ValueError(f"unbound recur() placeholders: {missing}")
        # rewrite operand references through placeholders
        nodes = []
        remap: Dict[int, Tuple[int, int, int]] = {}
        for ph, prod in self._bound.items():
            init, dist = self._pending[ph]
            remap[ph] = (prod, dist, init)
        keep = [n for n in self._nodes if n.op != "__PH__"]
        newid = {n.id: i for i, n in enumerate(keep)}
        for n in keep:
            ops = []
            for o in n.operands:
                if o.src in remap:
                    prod, dist, init = remap[o.src]
                    ops.append(Operand(newid[prod], o.dist + dist, init))
                else:
                    ops.append(Operand(newid[o.src], o.dist, o.init))
            nodes.append(Node(newid[n.id], n.op, ops, const=n.const,
                              array=n.array))
        return DFG(nodes, dict(self._arrays), name=self.name,
                   outputs=tuple(self._outputs))


# ---------------------------------------------------------------------------
# jaxpr-based extraction (LLVM-frontend analogue)
# ---------------------------------------------------------------------------

def trace_into(b: DFGBuilder, fn: Callable, inputs: Sequence[Ref]) -> List[Ref]:
    """Trace a pure scalar-int function into the builder.

    ``fn`` takes len(inputs) int32 scalars and returns one or a tuple of
    int32 scalars; its jaxpr is walked and each primitive becomes a DFG node.
    """
    import jax
    import jax.numpy as jnp

    avals = [jnp.int32(0)] * len(inputs)
    jaxpr = jax.make_jaxpr(fn)(*avals).jaxpr

    from jax.extend import core as jex_core

    PRIMS = {
        "add": "ADD", "add_any": "ADD", "sub": "SUB", "mul": "MUL",
        "max": "MAX", "min": "MIN", "and": "AND", "or": "OR", "xor": "XOR",
        "shift_left": "SHL", "shift_right_arithmetic": "SHR",
        "shift_right_logical": "SHR",
        "lt": "CMPLT", "gt": "CMPGT", "eq": "CMPEQ", "ne": "CMPNE",
        "le": "CMPLE", "ge": "CMPGE", "abs": "ABS", "neg": None,
    }

    def walk(jx, argrefs):
        env: Dict = dict(zip(jx.invars, argrefs))

        def read(atom):
            if isinstance(atom, jex_core.Literal):
                return int(atom.val)
            return env[atom]

        for eqn in jx.eqns:
            prim = eqn.primitive.name
            args = [read(a) for a in eqn.invars]
            if prim in ("jit", "pjit", "closed_call", "custom_jvp_call",
                        "custom_vjp_call"):
                sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                outs = walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, args)
                for var, o in zip(eqn.outvars, outs):
                    env[var] = o
                continue
            if prim in ("convert_element_type", "copy", "stop_gradient"):
                env[eqn.outvars[0]] = args[0]
                continue
            if prim == "neg":
                out = (b.op("SUB", 0, args[0]) if isinstance(args[0], Ref)
                       else -args[0])
            elif prim == "integer_pow":
                y = int(eqn.params["y"])
                out = args[0]
                for _ in range(y - 1):
                    out = b.op("MUL", out, args[0])
            elif prim == "select_n":
                pred, on_false, on_true = args
                out = b.op("SELECT", pred, on_true, on_false)
            elif prim in PRIMS and PRIMS[prim]:
                if all(isinstance(a, int) for a in args):
                    out = b.op("MOVC", const=_const_eval(PRIMS[prim], args))
                else:
                    out = b.op(PRIMS[prim], *args)
            else:
                raise NotImplementedError(f"primitive {prim} in DFG extraction")
            env[eqn.outvars[0]] = out
        return [read(v) for v in jx.outvars]

    outs = walk(jaxpr, list(inputs))
    return [o if isinstance(o, Ref) else b.op("MOVC", const=o) for o in outs]


def _const_eval(op: str, args: List[int]) -> int:
    a = [np.int32(x) for x in args]
    return int(_eval_op(op, a, None))


# ---------------------------------------------------------------------------
# Reference interpreter (test-vector oracle)
# ---------------------------------------------------------------------------

def _eval_op(op: str, vals: List[np.int32], const: Optional[int]) -> np.int32:
    v = list(vals)
    if const is not None:
        v.append(np.int32(const))
    with np.errstate(over="ignore"):
        if op == "ADD":
            return np.int32(v[0] + v[1])
        if op == "SUB":
            return np.int32(v[0] - v[1])
        if op == "MUL":
            return np.int32(v[0] * v[1])
        if op == "SHL":
            return np.int32(v[0] << (np.uint32(v[1]) & np.uint32(31)))
        if op == "SHR":
            return np.int32(v[0] >> (np.uint32(v[1]) & np.uint32(31)))
        if op == "AND":
            return np.int32(v[0] & v[1])
        if op == "OR":
            return np.int32(v[0] | v[1])
        if op == "XOR":
            return np.int32(v[0] ^ v[1])
        if op == "MIN":
            return np.int32(min(v[0], v[1]))
        if op == "MAX":
            return np.int32(max(v[0], v[1]))
        if op == "ABS":
            return np.int32(abs(v[0]))
        if op == "CMPLT":
            return np.int32(v[0] < v[1])
        if op == "CMPGT":
            return np.int32(v[0] > v[1])
        if op == "CMPEQ":
            return np.int32(v[0] == v[1])
        if op == "CMPNE":
            return np.int32(v[0] != v[1])
        if op == "CMPLE":
            return np.int32(v[0] <= v[1])
        if op == "CMPGE":
            return np.int32(v[0] >= v[1])
        if op == "SELECT":
            return np.int32(v[1] if v[0] else v[2])
        if op == "MOVC":
            return np.int32(const)
        if op == "NOP" or op == "ROUTE":
            return v[0] if v else np.int32(0)
    raise ValueError(f"unknown op {op}")


def interpret(dfg: DFG, mem: Dict[str, np.ndarray], n_iters: int
              ) -> Dict[str, np.ndarray]:
    """Execute the DFG for ``n_iters`` loop iterations (the oracle)."""
    mem = {k: v.astype(INT).copy() for k, v in mem.items()}
    for name, ln in dfg.arrays.items():
        if name not in mem:
            mem[name] = np.zeros(ln, INT)
    order = dfg.topo_order()
    hist: Dict[int, List[np.int32]] = {n.id: [] for n in dfg.nodes}
    for i in range(n_iters):
        vals: Dict[int, np.int32] = {}
        for nid in order:
            n = dfg.nodes[nid]
            ops = []
            for o in n.operands:
                if o.dist == 0:
                    ops.append(vals[o.src])
                elif i - o.dist < 0:
                    ops.append(np.int32(o.init))
                else:
                    ops.append(hist[o.src][i - o.dist])
            if n.op == "LOAD":
                idx = (int(ops[0]) if ops else 0) + (n.const or 0)
                vals[nid] = np.int32(mem[n.array][idx])
            elif n.op == "STORE":
                if len(ops) == 2:
                    idx, val = int(ops[0]) + 0, ops[1]
                else:
                    idx, val = 0, ops[0]
                idx += n.const or 0
                mem[n.array][idx] = val
                vals[nid] = val
            else:
                vals[nid] = _eval_op(n.op, ops, n.const)
            hist[nid].append(vals[nid])
    return mem


# ---------------------------------------------------------------------------
# Data layout (paper: round-robin bank allocation, bases folded into nodes)
# ---------------------------------------------------------------------------

@dataclass
class DataLayout:
    bases: Dict[str, int]            # array -> global base word address
    banks: Dict[str, int]            # array -> bank id
    n_banks: int
    bank_words: int

    @property
    def total_words(self) -> int:
        return self.n_banks * self.bank_words


def plan_layout(dfg: DFG, n_banks: int = 4, bank_words: int = 2048) -> DataLayout:
    bases, banks = {}, {}
    fill = [0] * n_banks
    for i, (name, ln) in enumerate(dfg.arrays.items()):
        b = i % n_banks                            # round-robin (paper heuristic)
        if fill[b] + ln > bank_words:
            b = int(np.argmin(fill))
        if fill[b] + ln > bank_words:
            raise ValueError(f"array {name} ({ln}w) does not fit any bank")
        banks[name] = b
        bases[name] = b * bank_words + fill[b]
        fill[b] += ln
    return DataLayout(bases, banks, n_banks, bank_words)


def apply_layout(dfg: DFG, layout: DataLayout) -> DFG:
    """Fold base addresses into LOAD/STORE consts (returns a new DFG)."""
    nodes = []
    for n in dfg.nodes:
        if n.op in MEM_OPS:
            nodes.append(replace(n, const=(n.const or 0) + layout.bases[n.array]))
        else:
            nodes.append(replace(n))
    return DFG(nodes, dict(dfg.arrays), name=dfg.name, outputs=dfg.outputs)


def flat_memory(layout: DataLayout, mem: Dict[str, np.ndarray]) -> np.ndarray:
    flat = np.zeros(layout.total_words, INT)
    for name, base in layout.bases.items():
        arr = mem.get(name)
        if arr is not None:
            flat[base:base + len(arr)] = arr.astype(INT)
    return flat


def unflatten_memory(layout: DataLayout, flat: np.ndarray,
                     arrays: Dict[str, int]) -> Dict[str, np.ndarray]:
    return {name: flat[layout.bases[name]:layout.bases[name] + ln].copy()
            for name, ln in arrays.items()}


def flat_memory_batch(layout: DataLayout,
                      mems: List[Dict[str, np.ndarray]]) -> np.ndarray:
    """Batched ``flat_memory``: B named-array dicts -> (B, total_words).

    One allocation and one vectorized assignment per *array name* instead
    of a Python loop over samples — the hot path of every natively-batched
    backend.  Samples may still omit arrays (zero-filled) or pass short
    arrays; only such ragged names fall back to a per-sample copy.
    """
    B = len(mems)
    flat = np.zeros((B, layout.total_words), INT)
    for name, base in layout.bases.items():
        rows = [m.get(name) for m in mems]
        present = [r for r in rows if r is not None]
        if not present:
            continue
        lens = {len(r) for r in present}
        if len(present) == B and len(lens) == 1:
            ln = lens.pop()
            flat[:, base:base + ln] = np.asarray(rows, dtype=INT)
        else:                                    # ragged / missing: per row
            for b, r in enumerate(rows):
                if r is not None:
                    flat[b, base:base + len(r)] = np.asarray(r, dtype=INT)
    return flat


def unflatten_memory_batch(layout: DataLayout, flats: np.ndarray,
                           arrays: Dict[str, int]
                           ) -> List[Dict[str, np.ndarray]]:
    """Batched ``unflatten_memory``: (B, total_words) -> B dicts.

    One contiguous copy per array name; the per-sample dicts share those
    copies as row views (callers treat outputs as read-only snapshots,
    exactly like the scalar path's fresh arrays)."""
    cols = {name: flats[:, layout.bases[name]:layout.bases[name] + ln].copy()
            for name, ln in arrays.items()}
    return [{name: col[b] for name, col in cols.items()}
            for b in range(flats.shape[0])]

"""Cycle-accurate CGRA simulator (Morpher §III-A-3).

Executes a ``MachineConfig`` bitstream against a flat scratchpad image:
per cycle it resolves crossbar wires (including HyCUBE's single-cycle
multi-hop bypass chains, by relaxing ``max_hops`` times), fires the
instruction slot of every PE, and applies register writes — exactly the
semantics the mapper scheduled.  Because the configuration, not the DFG,
is what executes, a mis-scheduled route or collision produces wrong
outputs and is caught by validation against the DFG interpreter oracle.

PEs outside their instruction's firing window are idle — the simulator
also reports idle-slot statistics, which feed the PACE dynamic
clock-gating energy model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.machine import (MachineConfig, OPC, OPCODES, SRC_CONST,
                                SRC_IN, SRC_NONE, SRC_REG, SRC_SELF, XB_IN,
                                XB_NONE, XB_O, XB_REG)

I32 = np.int32


@dataclass
class SimStats:
    cycles: int
    fired: int
    idle_slots: int
    mem_accesses: int
    max_mem_ports_used: int

    @property
    def pe_activity(self) -> float:
        total = self.fired + self.idle_slots
        return self.fired / total if total else 0.0


def _alu(opc: str, ops, const: Optional[int]) -> I32:
    from repro.core.dfg import _eval_op
    return _eval_op(opc, list(ops), const)


def simulate(cfg: MachineConfig, mem: np.ndarray, n_iters: int,
             check_ports: bool = True) -> Tuple[np.ndarray, SimStats]:
    """Run the configuration for ``n_iters`` steady-state iterations."""
    f = cfg.fabric
    II, P = cfg.II, f.n_pes
    n_links = len(f.links)
    n_regs = cfg.regw.shape[2]
    mem = mem.astype(I32).copy()

    O = np.zeros(P, I32)                     # output latches
    R = np.zeros((P, n_regs), I32)           # input registers
    t_end = int(cfg.t0.max()) + n_iters * II + II + 2
    fired = idle = mem_acc = max_ports = 0

    for t in range(t_end):
        s = t % II
        # ---- resolve wires (multi-hop bypass: relax max_hops times) -------
        wires = np.zeros(n_links, I32)
        driven = np.zeros(n_links, bool)
        for _ in range(max(1, f.max_hops)):
            changed = False
            for p in range(P):
                for j, li in enumerate(f.out_links(p)):
                    kind, idx = cfg.xbar[s, p, j]
                    if kind == XB_NONE or driven[li]:
                        continue
                    if kind == XB_O:
                        wires[li] = O[p]
                        driven[li] = True
                        changed = True
                    elif kind == XB_REG:
                        wires[li] = R[p, idx]
                        driven[li] = True
                        changed = True
                    elif kind == XB_IN and driven[idx]:
                        wires[li] = wires[idx]
                        driven[li] = True
                        changed = True
            if not changed:
                break

        # ---- execute instruction slots ------------------------------------
        results: Dict[int, I32] = {}
        ports_used = 0
        for p in range(P):
            opc_i = int(cfg.opcode[s, p])
            t0 = int(cfg.t0[s, p])
            if opc_i == OPC["NOP"] or t0 < 0 or t < t0 or (t - t0) % II:
                idle += 1
                continue
            i = (t - t0) // II
            if i >= n_iters:
                idle += 1
                continue
            fired += 1
            opc = OPCODES[opc_i]
            ops = []
            for k in range(3):
                kind, idx, dist, init = cfg.op_src[s, p, k]
                if kind == SRC_NONE:
                    continue
                if dist > 0 and i < dist:
                    ops.append(I32(init))
                    continue
                if kind == SRC_REG:
                    ops.append(R[p, idx])
                elif kind == SRC_IN:
                    ops.append(wires[idx])
                elif kind == SRC_SELF:
                    ops.append(O[p])
                elif kind == SRC_CONST:
                    ops.append(I32(cfg.const[s, p]))
            const = int(cfg.const[s, p])
            if opc == "LOAD":
                addr = (int(ops[0]) if ops else 0) + const
                results[p] = I32(mem[addr])
                ports_used += 1
                mem_acc += 1
            elif opc == "STORE":
                if len(ops) == 2:
                    addr, val = int(ops[0]) + const, ops[1]
                else:
                    addr, val = const, ops[0]
                mem[addr] = val
                results[p] = val
                ports_used += 1
                mem_acc += 1
            elif opc == "MOVC":
                results[p] = I32(const)
            elif opc == "ROUTE":
                results[p] = ops[0]
            else:
                use_c = bool(cfg.use_const[s, p])
                results[p] = _alu(opc, ops, const if use_c else None)
        max_ports = max(max_ports, ports_used)
        if check_ports and ports_used > f.n_mem_ports:
            raise RuntimeError(f"memory port oversubscription at cycle {t}: "
                               f"{ports_used} > {f.n_mem_ports}")

        # ---- register writes (end of cycle), then output latches ----------
        for p in range(P):
            for r in range(n_regs):
                kind, idx = cfg.regw[s, p, r]
                if kind == XB_NONE:
                    continue
                if kind == XB_IN and driven[idx]:
                    R[p, r] = wires[idx]
                elif kind == XB_O and p in results:
                    R[p, r] = results[p]
        for p, v in results.items():
            O[p] = v

    stats = SimStats(t_end, fired, idle, mem_acc, max_ports)
    return mem, stats

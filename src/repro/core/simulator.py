"""Cycle-accurate CGRA simulation (Morpher §III-A-3) — two engines.

``simulate_reference`` is the readable semantics spec: a scalar Python
triple-loop that interprets a ``MachineConfig`` bitstream against one flat
scratchpad image.  Per cycle it resolves crossbar wires (including
HyCUBE's single-cycle multi-hop bypass chains, by relaxing ``max_hops``
times), fires the instruction slot of every PE, and applies register
writes — exactly the semantics the mapper scheduled.  Because the
configuration, not the DFG, is what executes, a mis-scheduled route or
collision produces wrong outputs and is caught by validation against the
DFG interpreter oracle.

``simulate_batch`` is the production engine: it consumes the **lowered
artifact** (``core.lowering.LinkedConfig`` — wire chains resolved once,
at lowering time), precomputes per-slot numpy gather/scatter plans, and
steps a whole batch of scratchpad images through the fabric
simultaneously — all PEs of a cycle execute as array ops over a leading
batch axis.  It is bit-exact against ``simulate_reference`` (proved by
the engine-parity property tests) at a two-to-three-orders-of-magnitude
lower per-sample cost, which is what makes batched validation, DSE and
serving tractable.

PEs outside their instruction's firing window are idle — both engines
report idle-slot statistics, which feed the PACE dynamic clock-gating
energy model, and both record memory-port pressure (worst cycle, ports
used) in ``SimStats`` even when ``check_ports=False``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.lowering import (K_CONST, K_NONE, K_O, K_R, K_RESULT,
                                 LinkedConfig)
from repro.core.machine import (MachineConfig, OPC, OPCODES, SRC_CONST,
                                SRC_IN, SRC_NONE, SRC_REG, SRC_SELF, XB_IN,
                                XB_NONE, XB_O, XB_REG)

I32 = np.int32


@dataclass
class SimStats:
    cycles: int
    fired: int
    idle_slots: int
    mem_accesses: int
    max_mem_ports_used: int
    #: cycle at which ``max_mem_ports_used`` was first observed (-1: none);
    #: recorded even with ``check_ports=False`` so oversubscription is
    #: diagnosable after the fact instead of only via a mid-run RuntimeError
    worst_port_cycle: int = -1
    #: the fabric's port budget the run was checked against (0 = unknown)
    mem_ports_limit: int = 0

    @property
    def pe_activity(self) -> float:
        total = self.fired + self.idle_slots
        return self.fired / total if total else 0.0

    @property
    def oversubscribed(self) -> bool:
        """Whether any cycle used more memory ports than the fabric has."""
        return (self.mem_ports_limit > 0
                and self.max_mem_ports_used > self.mem_ports_limit)


def _alu(opc: str, ops, const: Optional[int]) -> I32:
    from repro.core.dfg import _eval_op
    return _eval_op(opc, list(ops), const)


def simulate_reference(cfg: MachineConfig, mem: np.ndarray, n_iters: int,
                       check_ports: bool = True
                       ) -> Tuple[np.ndarray, SimStats]:
    """Run the configuration for ``n_iters`` steady-state iterations.

    The scalar reference engine: one sample, pure Python, wire chains
    re-relaxed every cycle.  Kept as the executable semantics spec that
    ``simulate_batch`` (and the Pallas kernel) must match bit-exactly.
    """
    f = cfg.fabric
    II, P = cfg.II, f.n_pes
    n_links = len(f.links)
    n_regs = cfg.regw.shape[2]
    mem = mem.astype(I32).copy()

    out_latch = np.zeros(P, I32)             # PE output latches
    R = np.zeros((P, n_regs), I32)           # input registers
    t_end = int(cfg.t0.max()) + n_iters * II + II + 2
    fired = idle = mem_acc = max_ports = 0
    worst_cycle = -1

    for t in range(t_end):
        s = t % II
        # ---- resolve wires (multi-hop bypass: relax max_hops times) -------
        wires = np.zeros(n_links, I32)
        driven = np.zeros(n_links, bool)
        for _ in range(max(1, f.max_hops)):
            changed = False
            for p in range(P):
                for j, li in enumerate(f.out_links(p)):
                    kind, idx = cfg.xbar[s, p, j]
                    if kind == XB_NONE or driven[li]:
                        continue
                    if kind == XB_O:
                        wires[li] = out_latch[p]
                        driven[li] = True
                        changed = True
                    elif kind == XB_REG:
                        wires[li] = R[p, idx]
                        driven[li] = True
                        changed = True
                    elif kind == XB_IN and driven[idx]:
                        wires[li] = wires[idx]
                        driven[li] = True
                        changed = True
            if not changed:
                break

        # ---- execute instruction slots ------------------------------------
        results: Dict[int, I32] = {}
        ports_used = 0
        for p in range(P):
            opc_i = int(cfg.opcode[s, p])
            t0 = int(cfg.t0[s, p])
            if opc_i == OPC["NOP"] or t0 < 0 or t < t0 or (t - t0) % II:
                idle += 1
                continue
            i = (t - t0) // II
            if i >= n_iters:
                idle += 1
                continue
            fired += 1
            opc = OPCODES[opc_i]
            ops = []
            for k in range(3):
                kind, idx, dist, init = cfg.op_src[s, p, k]
                if kind == SRC_NONE:
                    continue
                if dist > 0 and i < dist:
                    ops.append(I32(init))
                    continue
                if kind == SRC_REG:
                    ops.append(R[p, idx])
                elif kind == SRC_IN:
                    ops.append(wires[idx])
                elif kind == SRC_SELF:
                    ops.append(out_latch[p])
                elif kind == SRC_CONST:
                    ops.append(I32(cfg.const[s, p]))
            const = int(cfg.const[s, p])
            if opc == "LOAD":
                addr = (int(ops[0]) if ops else 0) + const
                results[p] = I32(mem[addr])
                ports_used += 1
                mem_acc += 1
            elif opc == "STORE":
                if len(ops) == 2:
                    addr, val = int(ops[0]) + const, ops[1]
                else:
                    addr, val = const, ops[0]
                mem[addr] = val
                results[p] = val
                ports_used += 1
                mem_acc += 1
            elif opc == "MOVC":
                results[p] = I32(const)
            elif opc == "ROUTE":
                results[p] = ops[0]
            else:
                use_c = bool(cfg.use_const[s, p])
                results[p] = _alu(opc, ops, const if use_c else None)
        if ports_used > max_ports:
            max_ports = ports_used
            worst_cycle = t
        if check_ports and ports_used > f.n_mem_ports:
            raise RuntimeError(f"memory port oversubscription at cycle {t}: "
                               f"{ports_used} > {f.n_mem_ports}")

        # ---- register writes (end of cycle), then output latches ----------
        for p in range(P):
            for r in range(n_regs):
                kind, idx = cfg.regw[s, p, r]
                if kind == XB_NONE:
                    continue
                if kind == XB_IN and driven[idx]:
                    R[p, r] = wires[idx]
                elif kind == XB_O and p in results:
                    R[p, r] = results[p]
        for p, v in results.items():
            out_latch[p] = v

    stats = SimStats(t_end, fired, idle, mem_acc, max_ports,
                     worst_port_cycle=worst_cycle,
                     mem_ports_limit=f.n_mem_ports)
    return mem, stats


#: historical name — the scalar engine was simply ``simulate`` before the
#: vectorized batched engine existed; existing callers keep the reference
#: semantics they were written against
simulate = simulate_reference


# ---------------------------------------------------------------------------
# Vectorized batched engine
# ---------------------------------------------------------------------------

def _vec_alu(opc: str, v0: np.ndarray, v1: np.ndarray,
             v2: np.ndarray) -> np.ndarray:
    """Numpy-vectorized ALU over (N, B) operand blocks, int32 wrapping."""
    if opc == "ADD":
        return v0 + v1
    if opc == "SUB":
        return v0 - v1
    if opc == "MUL":
        return v0 * v1
    if opc == "SHL":
        return v0 << (v1 & I32(31))
    if opc == "SHR":
        return v0 >> (v1 & I32(31))
    if opc == "AND":
        return v0 & v1
    if opc == "OR":
        return v0 | v1
    if opc == "XOR":
        return v0 ^ v1
    if opc == "MIN":
        return np.minimum(v0, v1)
    if opc == "MAX":
        return np.maximum(v0, v1)
    if opc == "ABS":
        return np.abs(v0)
    if opc == "CMPLT":
        return (v0 < v1).astype(I32)
    if opc == "CMPGT":
        return (v0 > v1).astype(I32)
    if opc == "CMPEQ":
        return (v0 == v1).astype(I32)
    if opc == "CMPNE":
        return (v0 != v1).astype(I32)
    if opc == "CMPLE":
        return (v0 <= v1).astype(I32)
    if opc == "CMPGE":
        return (v0 >= v1).astype(I32)
    if opc == "SELECT":
        return np.where(v0 != 0, v1, v2)
    if opc == "ROUTE":
        return v0
    raise AssertionError(f"unvectorized opcode {opc}")


class _SlotPlan:
    """Precomputed gather/scatter plan for one II slot of a LinkedConfig.

    Everything data-independent is resolved here, once: operand source
    rows into the stacked (O ++ R) state, the trailing-immediate fill,
    ALU opcode groups, the ordered memory-op list and the register-write
    scatter.  Per cycle only the firing window (a function of ``t``) and
    the actual array ops remain.
    """

    __slots__ = ("opc", "const", "t0", "src_row", "is_state", "is_const",
                 "dist", "init", "alu_groups", "movc_idx", "mem_ops",
                 "rw_state_rows", "rw_state_src", "rw_res_rows", "rw_res_pe")

    def __init__(self, linked: LinkedConfig, s: int):
        P, R = linked.n_pes, linked.n_regs
        sc = linked.scalar[s]
        tab = linked.ops[s]
        self.opc = sc[:, 0].copy()
        self.const = sc[:, 1].copy()
        self.t0 = sc[:, 3].copy()
        use_c = sc[:, 2] != 0

        kind = tab[:, :, 0]                      # (P, 3)
        n_ops = (kind != K_NONE).sum(axis=1)     # (P,)
        # operand k reads row ``src_row`` of the stacked state
        # [O (P rows) ++ R (P*R rows)]; const/none slots read row 0 (masked)
        self.src_row = np.where(
            kind == K_O, tab[:, :, 1],
            np.where(kind == K_R, P + tab[:, :, 1] * R + tab[:, :, 2], 0))
        self.is_state = (kind == K_O) | (kind == K_R)
        # the immediate is a *trailing* ALU operand when use_const is set:
        # it fills the first absent slot after the real operands
        k_idx = np.arange(3)[None, :]
        self.is_const = (kind == K_CONST) | ((kind == K_NONE)
                                             & use_c[:, None]
                                             & (n_ops[:, None] == k_idx))
        self.dist = tab[:, :, 3].copy()
        self.init = tab[:, :, 4].copy()

        # ---- ALU opcode groups (mem ops handled separately, in PE order) --
        self.alu_groups: List[Tuple[str, np.ndarray]] = []
        self.movc_idx = np.nonzero(self.opc == OPC["MOVC"])[0]
        special = {OPC["NOP"], OPC["LOAD"], OPC["STORE"], OPC["MOVC"]}
        for code in np.unique(self.opc):
            if int(code) in special:
                continue
            idx = np.nonzero(self.opc == code)[0]
            self.alu_groups.append((OPCODES[int(code)], idx))

        # ---- memory ops: ascending PE order == reference engine order -----
        self.mem_ops: List[Tuple[int, bool, bool, int]] = []
        for p in range(P):
            if self.opc[p] == OPC["LOAD"]:
                self.mem_ops.append((p, True, kind[p, 0] != K_NONE,
                                     int(self.const[p])))
            elif self.opc[p] == OPC["STORE"]:
                self.mem_ops.append((p, False, kind[p, 1] != K_NONE,
                                     int(self.const[p])))

        # ---- register writes: flat scatter into the stacked state ---------
        # register (p, r) lives at stacked-state row P + p*R + r
        rw = linked.regw[s].reshape(P * R, 3)
        rwk, rwp, rwr = rw[:, 0], rw[:, 1], rw[:, 2]
        state_mask = (rwk == K_O) | (rwk == K_R)
        self.rw_state_rows = P + np.nonzero(state_mask)[0]
        self.rw_state_src = np.where(rwk == K_O, rwp, P + rwp * R + rwr
                                     )[state_mask]
        res_mask = rwk == K_RESULT
        self.rw_res_rows = P + np.nonzero(res_mask)[0]
        self.rw_res_pe = rwp[res_mask]


class BatchedSimulator:
    """Vectorized execution engine over a lowered artifact.

    Construct once per ``LinkedConfig`` (plans are precomputed per slot),
    then ``run`` arbitrarily many batches: the state carries a trailing
    batch axis, so ``B`` scratchpad images step through the fabric
    simultaneously and each cycle is a handful of numpy array ops instead
    of a Python loop over PEs and links.
    """

    def __init__(self, linked: LinkedConfig):
        self.linked = linked
        self.plans = [_SlotPlan(linked, s) for s in range(linked.II)]

    def run(self, mems: np.ndarray, n_iters: int,
            check_ports: bool = True) -> Tuple[np.ndarray, SimStats]:
        """Execute a (B, M) batch of scratchpad images for ``n_iters``
        steady-state iterations; returns ((B, M) images, per-sample stats).

        Firing, idling and port pressure are functions of the (static)
        configuration and the cycle alone, so ``SimStats`` is identical
        for every sample in the batch — and identical to the reference
        engine's stats for one sample.
        """
        linked = self.linked
        II, P, R = linked.II, linked.n_pes, linked.n_regs
        mems = np.ascontiguousarray(mems, dtype=I32)
        if mems.ndim != 2:
            raise ValueError(f"simulate_batch expects (B, M) images, "
                             f"got shape {mems.shape}")
        B = mems.shape[0]
        mem = mems.copy()
        lanes = np.arange(B)
        state = np.zeros((P + P * R, B), I32)   # [O latches ++ registers]
        t_end = linked.total_cycles(n_iters)
        fired_n = mem_acc = max_ports = 0
        worst_cycle = -1
        limit = linked.n_mem_ports

        with np.errstate(over="ignore"):
            for t in range(t_end):
                pl = self.plans[t % II]
                it = np.where(pl.t0 >= 0, (t - pl.t0) // II, 0)
                fire = ((pl.opc != OPC["NOP"]) & (pl.t0 >= 0)
                        & (t >= pl.t0) & (it < n_iters))
                n_fire = int(fire.sum())
                fired_n += n_fire
                if n_fire == 0:
                    # no PE fires, but route pipelines crossing this slot
                    # still shift: wire-fed register writes read pre-cycle
                    # state (numpy evaluates the RHS gather before the
                    # scatter, so in-place is the simultaneous semantics)
                    if len(pl.rw_state_rows):
                        state[pl.rw_state_rows] = state[pl.rw_state_src]
                    continue

                # ---- operand fetch: one static gather per operand slot ---
                cvec = np.broadcast_to(pl.const[:, None], (P, B))
                vs = []
                for k in range(3):
                    v = np.where(pl.is_state[:, k, None],
                                 state[pl.src_row[:, k]], I32(0))
                    v = np.where(pl.is_const[:, k, None], cvec, v)
                    use_init = (pl.dist[:, k] > 0) & (it < pl.dist[:, k])
                    v = np.where(use_init[:, None],
                                 pl.init[:, k, None].astype(I32), v)
                    vs.append(v)
                v0, v1, v2 = vs

                # ---- ALU: one vector op per opcode present in the slot ---
                result = np.zeros((P, B), I32)
                for opc, idx in pl.alu_groups:
                    result[idx] = _vec_alu(opc, v0[idx], v1[idx], v2[idx])
                if len(pl.movc_idx):
                    result[pl.movc_idx] = cvec[pl.movc_idx]

                # ---- memory ops: ascending PE order (reference order) ----
                ports_used = 0
                for p, is_load, has_idx, const in pl.mem_ops:
                    if not fire[p]:
                        continue
                    ports_used += 1
                    mem_acc += 1
                    if is_load:
                        addr = (v0[p] if has_idx else I32(0)) + const
                        result[p] = mem[lanes, addr]
                    else:
                        if has_idx:                 # [addr_operand, value]
                            addr, val = v0[p] + const, v1[p]
                        else:                       # [value] @ immediate
                            addr = np.full(B, const, I32)
                            val = v0[p]
                        mem[lanes, addr] = val
                        result[p] = val
                if ports_used > max_ports:
                    max_ports = ports_used
                    worst_cycle = t
                # guard semantics (explicit contract, tested in
                # tests/test_verifier.py): ``linked.n_mem_ports == 0``
                # means *unknown/unbounded* — the oversubscription check
                # is disabled entirely (`limit and ...` short-circuits),
                # while pressure is still recorded in SimStats above.
                # ``link_config`` threads the fabric's real limit through
                # unconditionally, so 0 only appears on hand-built
                # tables; the static verifier flags it as UAL011
                if check_ports and limit and ports_used > limit:
                    raise RuntimeError(
                        f"memory port oversubscription at cycle {t}: "
                        f"{ports_used} > {limit}")

                # ---- end of cycle: register writes, then output latches --
                new_state = state.copy()
                if len(pl.rw_state_rows):
                    new_state[pl.rw_state_rows] = state[pl.rw_state_src]
                if len(pl.rw_res_rows):
                    live = fire[pl.rw_res_pe]
                    rows = pl.rw_res_rows[live]
                    new_state[rows] = result[pl.rw_res_pe[live]]
                new_state[:P] = np.where(fire[:, None], result, state[:P])
                state = new_state

        stats = SimStats(t_end, fired_n, t_end * P - fired_n, mem_acc,
                         max_ports, worst_port_cycle=worst_cycle,
                         mem_ports_limit=limit)
        return mem, stats


def batched_engine(linked: LinkedConfig) -> BatchedSimulator:
    """The (memoized) vectorized engine for a lowered artifact: plans are
    precomputed once per LinkedConfig and reused across runs/backends."""
    eng = getattr(linked, "_engine", None)
    if eng is None:
        eng = BatchedSimulator(linked)
        linked._engine = eng
    return eng


def simulate_batch(linked: LinkedConfig, mems: np.ndarray, n_iters: int,
                   check_ports: bool = True) -> Tuple[np.ndarray, SimStats]:
    """Vectorized batched simulation of a lowered artifact.

    ``mems``: (B, M) int32 scratchpad images -> ((B, M) final images,
    per-sample ``SimStats``).  Bit-exact against ``simulate_reference``
    run per sample.
    """
    return batched_engine(linked).run(mems, n_iters, check_ports=check_ports)

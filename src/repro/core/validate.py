"""End-to-end validation: map -> simulate -> check (paper Table II rows
"Test data generation" and "Validation against test data").

The bespoke layout/map/flatten/simulate/compare loop that used to live
here is now ``Executable.validate()`` in the unified abstraction layer
(``repro.ual``); ``validate_kernel`` remains as the stable entry point and
delegates — existing callers keep working and now share the UAL mapping
cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core.adl import Fabric
from repro.core.dfg import DFG
from repro.core.mapper import MapResult
from repro.core.simulator import SimStats


@dataclass
class ValidationReport:
    kernel: str
    fabric: str
    map_result: MapResult
    passed: bool
    n_iters: int
    sim_stats: Optional[SimStats] = None
    mismatches: int = 0
    backend_results: Optional[Dict[str, bool]] = field(default=None)
    #: how many random test vectors were swept (one natively-batched run
    #: per backend — see ``Executable.validate``)
    n_vectors: int = 1

    def __str__(self) -> str:
        status = "PASS" if self.passed else "FAIL"
        ii = self.map_result.II if self.map_result.success else "—"
        return (f"[{status}] {self.kernel} on {self.fabric}: II={ii} "
                f"(MII={self.map_result.mii}), "
                f"util={self.map_result.fu_util:.2f}, "
                f"restarts={self.map_result.restarts}")


def validate_kernel(dfg: DFG, make_mem: Callable, n_iters: int,
                    fabric: Fabric, seed: int = 0, ii_max: int = 48,
                    strategy: str = "adaptive") -> ValidationReport:
    """Map ``dfg`` onto ``fabric`` and check the simulated configuration
    bit-exactly against the DFG-interpreter oracle on random test vectors.
    """
    # function-level import: ual imports ValidationReport from this module
    from repro import ual
    program = ual.Program.from_dfg(dfg, n_iters, make_mem=make_mem,
                                   n_banks=fabric.n_mem_ports)
    target = ual.Target(fabric, backend="sim", strategy=strategy,
                        ii_max=ii_max, seed=seed)
    exe = ual.compile(program, target)
    return exe.validate(seed=seed, n_iters=n_iters, make_mem=make_mem)

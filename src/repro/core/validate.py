"""End-to-end validation: map -> simulate -> check (paper Table II rows
"Test data generation" and "Validation against test data").

For a kernel DFG this pipeline (1) plans the data layout, (2) maps the DFG
onto the fabric, (3) lowers to a machine configuration, (4) generates random
test vectors, (5) runs both the DFG interpreter (oracle) and the
cycle-accurate simulator, and (6) compares every output array bit-exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.adl import Fabric
from repro.core.dfg import (DFG, apply_layout, flat_memory, interpret,
                            plan_layout, unflatten_memory)
from repro.core.mapper import MapResult, map_dfg
from repro.core.simulator import SimStats, simulate


@dataclass
class ValidationReport:
    kernel: str
    fabric: str
    map_result: MapResult
    passed: bool
    n_iters: int
    sim_stats: Optional[SimStats] = None
    mismatches: int = 0

    def __str__(self) -> str:
        status = "PASS" if self.passed else "FAIL"
        ii = self.map_result.II if self.map_result.success else "—"
        return (f"[{status}] {self.kernel} on {self.fabric}: II={ii} "
                f"(MII={self.map_result.mii}), "
                f"util={self.map_result.fu_util:.2f}, "
                f"restarts={self.map_result.restarts}")


def validate_kernel(dfg: DFG, make_mem: Callable, n_iters: int,
                    fabric: Fabric, seed: int = 0, ii_max: int = 48,
                    strategy: str = "adaptive") -> ValidationReport:
    layout = plan_layout(dfg, n_banks=fabric.n_mem_ports,
                         bank_words=max(2048, max(dfg.arrays.values()) + 64))
    laid = apply_layout(dfg, layout)
    result = map_dfg(laid, fabric, ii_max=ii_max, seed=seed, strategy=strategy)
    if not result.success:
        return ValidationReport(dfg.name, fabric.name, result, False, n_iters)
    rng = np.random.default_rng(seed)
    mem_in = make_mem(rng)
    # oracle: DFG interpreter on named arrays
    expect = interpret(dfg, mem_in, n_iters)
    # device: cycle-accurate simulation of the machine configuration
    flat = flat_memory(layout, mem_in)
    flat_out, stats = simulate(result.config, flat, n_iters)
    got = unflatten_memory(layout, flat_out, dfg.arrays)
    mism = 0
    for name in dfg.outputs:
        mism += int((expect[name] != got[name]).sum())
    return ValidationReport(dfg.name, fabric.name, result, mism == 0,
                            n_iters, stats, mism)

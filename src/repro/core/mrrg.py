"""Modulo Routing Resource Graph: occupancy model + Dijkstra router.

The MRRG unrolls the fabric over a candidate II; FUs, links and registers
become schedulable resources with capacity checked modulo II (paper
§III-B-2).  HyCUBE's single-cycle multi-hop interconnect appears as
within-cycle link chaining (up to ``max_hops`` segments); a traditional
N2N fabric instead requires a ROUTE slot on the intermediate PE's FU to
continue a path.  Multicast falls out of route-tree reuse: routing a value
to a second sink starts from every node already committed to that value's
tree at zero cost.

Search-node encodings (absolute time ``t``; capacities keyed mod II):
  ('O', pe, t)        output latch of ``pe`` holding the value during cycle t
  ('R', pe, r, t)     input register r of ``pe`` holding the value during t
  ('L', link, t, h)   value travelling link ``link`` during cycle t, h-th hop
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.adl import Fabric

Key = Tuple  # (kind, *idx, slot)

BASE_COST = {"L": 1.0, "R": 0.35, "FU": 3.0}
OVERUSE_PENALTY = 24.0


class Occupancy:
    """Per-(resource, slot mod II) usage with congestion history (SPR/PathFinder).

    Each (key, value) claim records the *absolute* cycle of the claim: the
    same value may share a resource slot across multiple route edges only at
    the same absolute time (true multicast).  A claim at a different absolute
    time would be a *different iteration* of the value — physically a
    conflict with itself — and is blocked at search time.
    """

    def __init__(self, fabric: Fabric, II: int):
        self.fabric = fabric
        self.II = II
        self.occ: Dict[Key, Dict[int, List[int]]] = {}  # key -> {vid: [count, abs_t]}
        self.hist: Dict[Key, float] = {}

    def users(self, key: Key) -> Dict[int, List[int]]:
        return self.occ.get(key, {})

    def blocked(self, key: Key, vid: int, t: int) -> bool:
        ent = self.occ.get(key, {}).get(vid)
        return ent is not None and ent[1] != t

    def add(self, key: Key, vid: int, t: int) -> None:
        d = self.occ.setdefault(key, {})
        if vid in d:
            if d[vid][1] != t:
                raise AssertionError(
                    f"value {vid} claims {key} at two times {d[vid][1]} vs {t}")
            d[vid][0] += 1
        else:
            d[vid] = [1, t]

    def remove(self, key: Key, vid: int) -> None:
        d = self.occ[key]
        d[vid][0] -= 1
        if d[vid][0] == 0:
            del d[vid]
        if not d:
            del self.occ[key]

    def overused(self) -> List[Key]:
        out = []
        for key, users in self.occ.items():
            cap = self.capacity(key)
            if len(users) > cap:
                out.append(key)
        return out

    def capacity(self, key: Key) -> int:
        if key[0] == "MEM":
            return self.fabric.n_mem_ports
        return 1

    def bump_hist(self, keys: Iterable[Key], amt: float = 1.0) -> None:
        for k in keys:
            self.hist[k] = self.hist.get(k, 0.0) + amt

    def cost(self, key: Key, vid: int) -> float:
        base = BASE_COST.get(key[0], 1.0)
        h = 1.0 + self.hist.get(key, 0.0)
        users = self.occ.get(key, {})
        extra = sum(1 for u in users if u != vid)
        over = max(0, extra + 1 - self.capacity(key))
        return base * h + OVERUSE_PENALTY * over * h

    def clear_routes(self) -> None:
        """Drop all occupancy but keep congestion history across restarts."""
        self.occ.clear()


@dataclass
class Route:
    """A committed path for one DFG edge (producer value -> one sink)."""

    vid: int
    sink_node: int
    sink_operand: int
    path: List[Tuple]                    # search nodes, source -> sink
    keys: List[Tuple[Key, int]]          # (resource, absolute time) consumed
    sink_entry: Tuple                    # last search node before the sink


class Router:
    """Dijkstra over the time-expanded resource graph."""

    def __init__(self, fabric: Fabric, occ: Occupancy):
        self.f = fabric
        self.occ = occ

    # -- expansion -----------------------------------------------------------
    def _neighbors(self, node: Tuple, vid: int, t_max: int):
        f, occ, II = self.f, self.occ, self.occ.II

        def use(key, t):
            if occ.blocked(key, vid, t):
                return None
            return [(key, t)], occ.cost(key, vid)

        kind = node[0]
        if kind == "O":
            _, p, t = node
            if t > t_max:
                return
            # write own register (value available in reg during cycle t)
            for r in range(f.pes[p].n_regs):
                u = use(("R", p, r, t % II), t)
                if u:
                    yield ("R", p, r, t), *u
            # drive out-links (crossbar / output broadcast)
            for li in f.out_links(p):
                u = use(("L", li, t % II), t)
                if u:
                    yield ("L", li, t, 1), *u
        elif kind == "L":
            _, li, t, h = node
            a, bpe = f.links[li]
            # latch into a register of the destination (held during t+1)
            if t + 1 <= t_max:
                for r in range(f.pes[bpe].n_regs):
                    u = use(("R", bpe, r, (t + 1) % II), t + 1)
                    if u:
                        yield ("R", bpe, r, t + 1), *u
            # single-cycle multi-hop chaining (HyCUBE bypass repeaters)
            if not f.route_through_fu and h < f.max_hops:
                for lj in f.out_links(bpe):
                    if f.links[lj][1] != a:          # no immediate U-turn
                        u = use(("L", lj, t % II), t)
                        if u:
                            yield ("L", lj, t, h + 1), *u
        elif kind == "R":
            _, p, r, t = node
            # hold one more cycle
            if t + 1 <= t_max:
                u = use(("R", p, r, (t + 1) % II), t + 1)
                if u:
                    yield ("R", p, r, t + 1), *u
            if f.route_through_fu:
                # N2N: continuing needs a ROUTE slot on this FU
                if t + 1 <= t_max:
                    u = use(("FU", p, t % II), t)
                    if u:
                        yield ("O", p, t + 1), *u
            else:
                # HyCUBE: crossbar forwards register contents directly
                for li in f.out_links(p):
                    u = use(("L", li, t % II), t)
                    if u:
                        yield ("L", li, t, 1), *u

    def _reaches_sink(self, node: Tuple, sink_pe: int, tc: int) -> bool:
        kind = node[0]
        if kind == "O":
            return node[1] == sink_pe and node[2] == tc
        if kind == "L":
            return self.f.links[node[1]][1] == sink_pe and node[2] == tc
        if kind == "R":
            return node[1] == sink_pe and node[3] == tc
        return False

    # -- search ---------------------------------------------------------------
    def route(self, vid: int, tree: Dict[Tuple, int], src_pe: int, t_src: int,
              sink_node: int, sink_operand: int, sink_pe: int, tc: int,
              max_cost: float = 1e9) -> Optional[Route]:
        """Route value ``vid`` (produced on src_pe at t_src) to (sink_pe, tc).

        ``tree``: search-node -> refcount of the value's committed tree; all
        of them seed the frontier at zero cost (multicast reuse).
        """
        if tc <= t_src:
            return None
        start: Dict[Tuple, float] = {("O", src_pe, t_src + 1): 0.0}
        for n in tree:
            if n not in start and self._time_of(n) <= tc:
                start[n] = 0.0
        dist: Dict[Tuple, float] = dict(start)
        prev: Dict[Tuple, Tuple] = {}
        prev_keys: Dict[Tuple, List[Key]] = {}
        pq = [(c, n) for n, c in start.items()]
        heapq.heapify(pq)
        best_sink, best_cost = None, max_cost
        while pq:
            c, n = heapq.heappop(pq)
            if c > dist.get(n, 1e18) or c >= best_cost:
                continue
            if self._reaches_sink(n, sink_pe, tc):
                best_sink, best_cost = n, c
                continue
            for nxt, keys, w in self._neighbors(n, vid, tc):
                nc = c + w
                if nc < dist.get(nxt, 1e18) and nc < best_cost:
                    dist[nxt] = nc
                    prev[nxt] = n
                    prev_keys[nxt] = keys
                    heapq.heappush(pq, (nc, nxt))
        if best_sink is None:
            return None
        # backtrack to a tree/start node (the seed is kept in the path so
        # machine emission can recover the seed->first-new-node action)
        path, keys = [best_sink], []
        node = best_sink
        while node in prev and node not in start:
            keys.extend(prev_keys[node])
            node = prev[node]
            path.append(node)
        path.reverse()
        # a path that claims the same (resource, slot) at two absolute times
        # would overlap consecutive iterations of its own value (e.g. a
        # register held >= II cycles) — physically infeasible, reject
        kk = [k for (k, _) in keys]
        if len(set(kk)) != len(kk):
            return None
        return Route(vid, sink_node, sink_operand, path, keys,
                     sink_entry=best_sink)

    @staticmethod
    def _time_of(node: Tuple) -> int:
        if node[0] == "L":
            return node[2]
        return node[-1]

"""LISA-lite: a learned placement-bias model for the mapper (paper §III-D).

LISA [HPCA'22] replaces simulated-annealing mapping with GNN-predicted
labels that bias placement.  This is a deliberately small, fully
self-contained analogue: an MLP scores (node, PE) pairs from structural
features; it is trained — with this repo's own AdamW — on (node → chosen
PE) pairs harvested from successful low-II mappings of a training kernel
set, and plugged into the mapper through the ``label_fn`` hook
(`ModuloMapper(label_fn=...)`), biasing the PE ranking of the candidate
enumerator on unseen kernels.

The point is the plumbing the paper calls for (a learned method swapped
into an architecture-adaptive mapper without toolchain changes), not SOTA
mapping quality.
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adl import Fabric, MEM_OPS
from repro.core.dfg import DFG
from repro.core.mapper import map_dfg

N_NODE_F = 6
N_PE_F = 5


def node_features(dfg: DFG) -> np.ndarray:
    dfg.compute_asap_alap(4 * len(dfg.nodes))
    horizon = max(1, max(n.alap for n in dfg.nodes))
    rec_nodes = {nid for cyc in dfg.recurrence_cycles() for nid in cyc}
    out = np.zeros((len(dfg.nodes), N_NODE_F), np.float32)
    for n in dfg.nodes:
        out[n.id] = (
            n.asap / horizon,
            n.alap / horizon,
            float(n.op in MEM_OPS),
            len(n.operands) / 3.0,
            len(dfg.users[n.id]) / 4.0,
            float(n.id in rec_nodes),
        )
    return out


def pe_features(fabric: Fabric) -> np.ndarray:
    out = np.zeros((fabric.n_pes, N_PE_F), np.float32)
    for p in range(fabric.n_pes):
        r, c = fabric.pe_xy(p)
        out[p] = (
            r / max(1, fabric.rows - 1),
            c / max(1, fabric.cols - 1),
            float(fabric.pes[p].is_mem),
            c / max(1, fabric.cols - 1),          # distance to mem column 0
            min(r, fabric.rows - 1 - r) / max(1, fabric.rows - 1),
        )
    return out


# ---------------------------------------------------------------------------
# Model: MLP over [node_feat, pe_feat] -> score
# ---------------------------------------------------------------------------

def init_model(key, hidden: int = 32):
    k1, k2 = jax.random.split(key)
    d_in = N_NODE_F + N_PE_F
    return {
        "w1": jax.random.normal(k1, (d_in, hidden)) * (1.0 / d_in ** 0.5),
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, 1)) * (1.0 / hidden ** 0.5),
        "b2": jnp.zeros(1),
    }


def score(params, nf, pf):
    """nf: (..., N_NODE_F); pf: (..., N_PE_F) -> (...,) logits."""
    x = jnp.concatenate([nf, pf], axis=-1)
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return (h @ params["w2"] + params["b2"])[..., 0]


def collect_dataset(kernels: Sequence[Tuple[DFG, int]], fabric: Fabric,
                    seed: int = 0):
    """Harvest (node_feat, chosen_pe) pairs from successful mappings."""
    pf = pe_features(fabric)
    feats, labels = [], []
    for dfg, _ in kernels:
        res = map_dfg(dfg, fabric, seed=seed)
        if not res.success:
            continue
        nf = node_features(dfg)
        for nid, (pe, _t) in res.placements.items():
            feats.append(nf[nid])
            labels.append(pe)
    return np.stack(feats), np.array(labels, np.int32), pf


def train(feats: np.ndarray, labels: np.ndarray, pf: np.ndarray,
          steps: int = 300, lr: float = 1e-2, seed: int = 0):
    """Softmax-over-PEs classification with this repo's AdamW."""
    from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
    params = init_model(jax.random.PRNGKey(seed))
    opt = OptConfig(lr=lr, warmup_steps=10, total_steps=steps,
                    weight_decay=0.0)
    state = init_opt_state(params, opt)
    X = jnp.asarray(feats)                        # (N, F)
    y = jnp.asarray(labels)                       # (N,)
    P = jnp.asarray(pf)                           # (n_pes, PF)

    def loss_fn(prm):
        logits = score(prm, X[:, None, :].repeat(P.shape[0], 1),
                       P[None, :, :].repeat(X.shape[0], 0))   # (N, n_pes)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, y[:, None], 1).mean()

    loss_grad = jax.jit(jax.value_and_grad(loss_fn))
    losses = []
    for _ in range(steps):
        loss, grads = loss_grad(params)
        params, state, _ = adamw_update(params, grads, state, opt)
        losses.append(float(loss))
    return params, losses


def make_label_fn(params, fabric: Fabric, weight: float = 0.5,
                  mem_only: bool = True) -> Callable[[DFG], Callable]:
    """Returns dfg -> label_fn(nid, pe, II) for ``map_dfg(label_fn=...)``.

    The bias is normalized to [0, weight) per node so it acts as a
    TIEBREAK on the mapper's proximity ranking (LISA labels guide, the
    router still decides) rather than overriding feasibility-driven
    placement.

    ``mem_only`` (measured ablation, examples/learned_mapper.py): the
    absolute-PE labels this small model learns transfer well for MEMORY
    nodes (mem-capable column structure is fabric-invariant) but mislead
    for compute nodes on unseen kernels (II 4->8 on nw even at weight
    0.2) — real LISA uses *relative* GNN labels for exactly this reason.
    Default applies the learned bias to memory nodes only, which gives
    II parity with no restart inflation on the held-out set.
    """
    pf = jnp.asarray(pe_features(fabric))

    def for_dfg(dfg: DFG):
        nf = jnp.asarray(node_features(dfg))
        logits = score(params, nf[:, None, :].repeat(pf.shape[0], 1),
                       pf[None, :, :].repeat(nf.shape[0], 0))
        p = np.asarray(jax.nn.softmax(logits, -1))
        bias = weight * (1.0 - p / p.max(axis=1, keepdims=True))
        if mem_only:
            bias = bias * np.asarray(nf[:, 2:3])   # is_mem feature

        def label_fn(nid: int, pe: int, II: int) -> float:
            return float(bias[nid, pe])
        return label_fn
    return for_dfg

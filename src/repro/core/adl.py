"""Architecture Description Language (ADL) — Morpher-style fabric models.

The paper's ADL describes arbitrary CGRAs with three abstractions:
``Module`` (FU / RF / MU / PE / composite), ``Port`` and ``Connection``;
multiplexers are inferred from port fan-in.  This module provides

  * the ADL surface (``Module``/``Port``/``Connection`` + JSON round-trip),
  * ``Fabric`` — the elaborated topology the mapper/simulator consume,
  * builders for the paper's fabrics: ``hycube`` (single-cycle multi-hop
    crossbar interconnect, multicast), ``n2n`` (neighbor-to-neighbor with
    FU route-through), ``pace`` (8x8, four clusters, 16-bit datapath) and a
    ``spatial`` Snafu-like variant (no time multiplexing),
  * a ``tpu_pod`` builder that describes a TPU mesh in the same vocabulary
    (devices = PEs, ICI links = Connections) for the distributed scheduler.

Only scheduling-relevant semantics are modelled: FU opcode support, memory
capability, per-PE input registers, directed links, the max number of link
hops a value may traverse in one cycle (HyCUBE's clockless-repeater bypass)
and whether the interconnect multicasts.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

# ---------------------------------------------------------------------------
# Opcode classes
# ---------------------------------------------------------------------------

ALU_OPS = (
    "ADD", "SUB", "MUL", "SHL", "SHR", "AND", "OR", "XOR",
    "MIN", "MAX", "ABS",
    "CMPLT", "CMPGT", "CMPEQ", "CMPNE", "CMPLE", "CMPGE",
    "SELECT", "MOVC", "NOP",
)
MEM_OPS = ("LOAD", "STORE")
ROUTE_OP = "ROUTE"  # N2N pass-through occupying an FU slot
ALL_OPS = ALU_OPS + MEM_OPS + (ROUTE_OP,)


# ---------------------------------------------------------------------------
# ADL surface (Modules / Ports / Connections)
# ---------------------------------------------------------------------------

@dataclass
class Port:
    name: str
    direction: str  # "in" | "out"


@dataclass
class Module:
    """Hierarchical hardware block.  ``kind`` in {FU, RF, MU, PE, FABRIC}."""

    name: str
    kind: str
    ops: Tuple[str, ...] = ()
    size: int = 0                      # RF: #registers, MU: #words
    ports: List[Port] = field(default_factory=list)
    submodules: List["Module"] = field(default_factory=list)
    attrs: Dict[str, object] = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "ops": list(self.ops),
            "size": self.size,
            "ports": [{"name": p.name, "direction": p.direction} for p in self.ports],
            "submodules": [m.to_dict() for m in self.submodules],
            "attrs": dict(self.attrs),
        }

    @staticmethod
    def from_dict(d: dict) -> "Module":
        return Module(
            name=d["name"],
            kind=d["kind"],
            ops=tuple(d.get("ops", ())),
            size=int(d.get("size", 0)),
            ports=[Port(p["name"], p["direction"]) for p in d.get("ports", [])],
            submodules=[Module.from_dict(m) for m in d.get("submodules", [])],
            attrs=dict(d.get("attrs", {})),
        )


@dataclass
class Connection:
    """Directed wire between two module ports (mux inferred at the sink)."""

    src: str  # "module.port"
    dst: str


# ---------------------------------------------------------------------------
# Elaborated fabric
# ---------------------------------------------------------------------------

@dataclass
class PEAttr:
    ops: frozenset
    is_mem: bool          # has LSU access to the shared scratchpad
    n_regs: int           # input/operand registers


@dataclass
class Fabric:
    """Elaborated CGRA topology consumed by the mapper and simulator."""

    name: str
    rows: int
    cols: int
    pes: List[PEAttr]
    links: List[Tuple[int, int]]          # directed (src_pe, dst_pe)
    max_hops: int                          # link segments traversable per cycle
    multicast: bool
    route_through_fu: bool                 # N2N: continuing a route costs an FU slot
    temporal: bool = True                  # False => spatial (no time multiplexing)
    datapath_bits: int = 32
    cm_bytes_per_pe: int = 256             # configuration memory (PACE: 0.25KB)
    n_mem_ports: int = 4                   # shared scratchpad ports
    clusters: int = 1
    link_gbps: float = 0.0                 # only for pod fabrics
    attrs: Dict[str, object] = field(default_factory=dict)

    # -- derived -----------------------------------------------------------
    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    def pe_xy(self, p: int) -> Tuple[int, int]:
        return divmod(p, self.cols)

    def out_links(self, p: int) -> List[int]:
        return self._out_links[p]

    def in_links(self, p: int) -> List[int]:
        return self._in_links[p]

    def __post_init__(self) -> None:
        self._out_links: List[List[int]] = [[] for _ in range(self.n_pes)]
        self._in_links: List[List[int]] = [[] for _ in range(self.n_pes)]
        for li, (s, d) in enumerate(self.links):
            self._out_links[s].append(li)
            self._in_links[d].append(li)
        self.mem_pes = [i for i, a in enumerate(self.pes) if a.is_mem]

    def supports(self, pe: int, op: str) -> bool:
        a = self.pes[pe]
        if op in MEM_OPS:
            return a.is_mem and op in a.ops
        return op in a.ops

    # -- serialization (Morpher parses JSON architecture files) -------------
    def to_json(self) -> str:
        d = {
            "name": self.name, "rows": self.rows, "cols": self.cols,
            "pes": [{"ops": sorted(a.ops), "is_mem": a.is_mem, "n_regs": a.n_regs}
                    for a in self.pes],
            "links": [list(ab) for ab in self.links],
            "max_hops": self.max_hops, "multicast": self.multicast,
            "route_through_fu": self.route_through_fu, "temporal": self.temporal,
            "datapath_bits": self.datapath_bits,
            "cm_bytes_per_pe": self.cm_bytes_per_pe,
            "n_mem_ports": self.n_mem_ports, "clusters": self.clusters,
            "link_gbps": self.link_gbps, "attrs": self.attrs,
        }
        return json.dumps(d, indent=1)

    @staticmethod
    def from_json(s: str) -> "Fabric":
        d = json.loads(s)
        return Fabric(
            name=d["name"], rows=d["rows"], cols=d["cols"],
            pes=[PEAttr(frozenset(p["ops"]), p["is_mem"], p["n_regs"])
                 for p in d["pes"]],
            links=[tuple(ab) for ab in d["links"]],
            max_hops=d["max_hops"], multicast=d["multicast"],
            route_through_fu=d["route_through_fu"], temporal=d["temporal"],
            datapath_bits=d["datapath_bits"],
            cm_bytes_per_pe=d["cm_bytes_per_pe"],
            n_mem_ports=d["n_mem_ports"], clusters=d["clusters"],
            link_gbps=d.get("link_gbps", 0.0), attrs=d.get("attrs", {}),
        )

    # -- ADL view ------------------------------------------------------------
    def to_adl(self) -> Module:
        """Render the fabric as a hierarchy of ADL Modules (paper Fig. 3)."""
        pes = []
        for i, a in enumerate(self.pes):
            fu = Module(f"FU{i}", "FU", ops=tuple(sorted(a.ops)))
            rf = Module(f"RF{i}", "RF", size=a.n_regs)
            subs = [fu, rf]
            if a.is_mem:
                subs.append(Module(f"LSU{i}", "MU", size=0))
            pes.append(Module(f"PE{i}", "PE", submodules=subs,
                              ports=[Port("in", "in"), Port("out", "out")]))
        return Module(self.name, "FABRIC", submodules=pes,
                      attrs={"links": len(self.links), "max_hops": self.max_hops})


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def _mesh_links(rows: int, cols: int, torus: bool = False) -> List[Tuple[int, int]]:
    links = []
    for r in range(rows):
        for c in range(cols):
            p = r * cols + c
            for dr, dc in ((0, 1), (0, -1), (1, 0), (-1, 0)):
                rr, cc = r + dr, c + dc
                if torus:
                    rr, cc = rr % rows, cc % cols
                elif not (0 <= rr < rows and 0 <= cc < cols):
                    continue
                q = rr * cols + cc
                if q != p:
                    links.append((p, q))
    return sorted(set(links))


def _pe_row(rows: int, cols: int, mem_cols: Sequence[int], ops: Sequence[str],
            n_regs: int) -> List[PEAttr]:
    pes = []
    base = frozenset(ops)
    for r in range(rows):
        for c in range(cols):
            is_mem = c in mem_cols
            pe_ops = base | frozenset(MEM_OPS) if is_mem else base
            pes.append(PEAttr(pe_ops, is_mem, n_regs))
    return pes


def hycube(rows: int = 4, cols: int = 4, max_hops: int = 4,
           n_regs: int = 4, datapath_bits: int = 32) -> Fabric:
    """HyCUBE: single-cycle multi-hop crossbar mesh with multicast.

    Leftmost column PEs are memory-capable (LSUs to a 4-port scratchpad).
    """
    return Fabric(
        name=f"hycube_{rows}x{cols}_h{max_hops}",
        rows=rows, cols=cols,
        pes=_pe_row(rows, cols, mem_cols=(0,), ops=ALU_OPS, n_regs=n_regs),
        links=_mesh_links(rows, cols),
        max_hops=max_hops, multicast=True, route_through_fu=False,
        temporal=True, datapath_bits=datapath_bits,
    )


def n2n(rows: int = 4, cols: int = 4, n_regs: int = 4) -> Fabric:
    """Traditional neighbor-to-neighbor CGRA: 1 hop/cycle, route-through FUs."""
    return Fabric(
        name=f"n2n_{rows}x{cols}",
        rows=rows, cols=cols,
        pes=_pe_row(rows, cols, mem_cols=(0,), ops=ALU_OPS + (ROUTE_OP,),
                    n_regs=n_regs),
        links=_mesh_links(rows, cols),
        max_hops=1, multicast=False, route_through_fu=True,
        temporal=True,
    )


def pace(max_hops: int = 4) -> Fabric:
    """PACE: 8x8 HyCUBE-style CGRA, four clusters, 16-bit datapath."""
    f = hycube(8, 8, max_hops=max_hops, datapath_bits=16)
    f.name = "pace_8x8"
    f.clusters = 4
    f.cm_bytes_per_pe = 256
    return f


def spatial(rows: int = 4, cols: int = 4) -> Fabric:
    """Snafu-like spatial fabric: no time multiplexing (one op per PE)."""
    f = n2n(rows, cols)
    f.name = f"spatial_{rows}x{cols}"
    f.temporal = False
    return f


def tpu_pod(data: int = 16, model: int = 16, pods: int = 1,
            link_gbps: float = 50.0) -> Fabric:
    """A TPU pod in ADL vocabulary: chips = PEs, ICI = Connections.

    Used by the pipeline scheduler and the roofline model; 2D ICI torus per
    pod, pod axis connected by DCN-like links (modelled as regular links with
    the same builder; bandwidth annotated).
    """
    rows, cols = data, model * pods
    return Fabric(
        name=f"tpu_pod_{pods}x{data}x{model}",
        rows=rows, cols=cols,
        pes=_pe_row(rows, cols, mem_cols=range(cols), ops=ALU_OPS, n_regs=2),
        links=_mesh_links(rows, cols, torus=True),
        max_hops=1, multicast=False, route_through_fu=False,
        temporal=True, link_gbps=link_gbps,
        attrs={"pods": pods, "data": data, "model": model},
    )


FABRIC_BUILDERS = {
    "hycube": hycube,
    "n2n": n2n,
    "pace": pace,
    "spatial": spatial,
    "tpu_pod": tpu_pod,
}

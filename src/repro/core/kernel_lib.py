"""Benchmark kernel DFGs (paper Table III / Fig. 9 workloads).

Loop bodies for fft, adpcm, aes, disparity, dct, nw and GeMM, written
against the ``DFGBuilder`` DSL (the annotated-kernel analogue).  Each entry
returns ``(dfg, make_mem(rng), n_iters)``; the DFG interpreter is the
oracle against which mapped configurations are validated, exactly like
Morpher's automated test-vector flow.

DFG sizes are chosen to be representative of the paper's kernels on a 4x4
fabric (ResMII in the 2-4 range, so routing pressure — not raw FU count —
decides II, which is what Table III measures).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

from repro.core.dfg import DFG, DFGBuilder, trace_into

KernelEntry = Tuple[DFG, Callable[[np.random.Generator], Dict[str, np.ndarray]], int]

N_ITERS = 16


def _rand(rng, n, lo=-128, hi=128):
    return rng.integers(lo, hi, size=n).astype(np.int32)


# ---------------------------------------------------------------------------

def gemm() -> KernelEntry:
    """Inner-product accumulation, k-loop unrolled by 4."""
    b = DFGBuilder("gemm")
    K = 4 * N_ITERS
    b.array("A", K)
    b.array("B", K)
    b.array("C", 1, output=True)
    k = b.counter(0, 4)
    acc = b.recur(0)
    parts = []
    for u in range(4):
        idx = b.op("ADD", k, const=u)
        a = b.load("A", idx)
        bb = b.load("B", idx)
        parts.append(b.op("MUL", a, bb))
    s01 = b.op("ADD", parts[0], parts[1])
    s23 = b.op("ADD", parts[2], parts[3])
    s = b.op("ADD", s01, s23)
    acc2 = b.op("ADD", acc, s)
    b.bind(acc, acc2)
    b.store("C", 0, acc2)
    return b.build(), lambda r: {"A": _rand(r, K), "B": _rand(r, K)}, N_ITERS


def fft() -> KernelEntry:
    """Radix-2 butterfly, fixed-point (shift-scaled twiddles)."""
    b = DFGBuilder("fft")
    N = N_ITERS
    for nm in ("ar", "ai", "br", "bi", "wr", "wi"):
        b.array(nm, N)
    b.array("or0", N, output=True)
    b.array("oi0", N, output=True)
    b.array("or1", N, output=True)
    b.array("oi1", N, output=True)
    i = b.counter()
    ar, ai = b.load("ar", i), b.load("ai", i)
    br, bi = b.load("br", i), b.load("bi", i)
    wr, wi = b.load("wr", i), b.load("wi", i)
    t1 = b.op("MUL", br, wr)
    t2 = b.op("MUL", bi, wi)
    t3 = b.op("MUL", br, wi)
    t4 = b.op("MUL", bi, wr)
    tr = b.op("SHR", b.op("SUB", t1, t2), 8)
    ti = b.op("SHR", b.op("ADD", t3, t4), 8)
    b.store("or0", i, b.op("ADD", ar, tr))
    b.store("oi0", i, b.op("ADD", ai, ti))
    b.store("or1", i, b.op("SUB", ar, tr))
    b.store("oi1", i, b.op("SUB", ai, ti))
    def mk(r):
        return {nm: _rand(r, N) for nm in ("ar", "ai", "br", "bi", "wr", "wi")}
    return b.build(), mk, N


def adpcm() -> KernelEntry:
    """IMA-ADPCM decoder step: two recurrences + table lookups + clamps."""
    b = DFGBuilder("adpcm")
    N = N_ITERS
    b.array("code", N)
    b.array("steptab", 96)
    b.array("idxtab", 16)
    b.array("out", N, output=True)
    i = b.counter()
    index = b.recur(init=0)
    valpred = b.recur(init=0)
    code = b.op("AND", b.load("code", i), 15)
    step = b.load("steptab", index)
    # vpdiff = step>>3 + bits
    vp = b.op("SHR", step, 3)
    b4 = b.op("AND", code, 4)
    b2 = b.op("AND", code, 2)
    b1 = b.op("AND", code, 1)
    vp = b.op("ADD", vp, b.op("SELECT", b.op("CMPNE", b4, 0), step, 0))
    vp = b.op("ADD", vp, b.op("SELECT", b.op("CMPNE", b2, 0),
                              b.op("SHR", step, 1), 0))
    vp = b.op("ADD", vp, b.op("SELECT", b.op("CMPNE", b1, 0),
                              b.op("SHR", step, 2), 0))
    sign = b.op("AND", code, 8)
    nv = b.op("SELECT", b.op("CMPNE", sign, 0),
              b.op("SUB", valpred, vp), b.op("ADD", valpred, vp))
    nv = b.op("MAX", b.op("MIN", nv, 32767), -32768)
    didx = b.load("idxtab", code)
    nidx = b.op("MAX", b.op("MIN", b.op("ADD", index, didx), 88), 0)
    b.bind(index, nidx)
    b.bind(valpred, nv)
    b.store("out", i, nv)

    def mk(r):
        idxtab = np.array([-1, -1, -1, -1, 2, 4, 6, 8] * 2, np.int32)
        steptab = np.minimum(7 * (np.arange(96, dtype=np.int64) + 1) ** 2,
                             32767).astype(np.int32)
        return {"code": _rand(r, N, 0, 16), "steptab": steptab, "idxtab": idxtab}
    return b.build(), mk, N


def aes() -> KernelEntry:
    """SubBytes + AddRoundKey on a 32-bit word (4 sbox lookups)."""
    b = DFGBuilder("aes")
    N = N_ITERS
    b.array("state", N)
    b.array("rkey", N)
    b.array("sbox", 256)
    b.array("out", N, output=True)
    i = b.counter()
    w = b.load("state", i)
    k = b.load("rkey", i)
    bytes_out = []
    for s in range(4):
        byte = b.op("AND", b.op("SHR", w, 8 * s), 255)
        sub = b.load("sbox", byte)
        bytes_out.append(b.op("SHL", sub, 8 * s))
    w1 = b.op("OR", bytes_out[0], bytes_out[1])
    w2 = b.op("OR", bytes_out[2], bytes_out[3])
    sub_w = b.op("OR", w1, w2)
    b.store("out", i, b.op("XOR", sub_w, k))

    def mk(r):
        return {"state": _rand(r, N, 0, 1 << 30), "rkey": _rand(r, N, 0, 1 << 30),
                "sbox": _rand(r, 256, 0, 256)}
    return b.build(), mk, N


def disparity() -> KernelEntry:
    """Stereo SAD over an 8-pixel window + running argmin (two recurrences)."""
    b = DFGBuilder("disparity")
    N = N_ITERS
    W = 8
    b.array("left", N + W)
    b.array("right", N + W)
    b.array("best", 1, output=True)
    b.array("bestd", 1, output=True)
    d = b.counter()
    best = b.recur(init=1 << 20)
    bestd = b.recur(init=0)
    diffs = []
    for w in range(W):
        idx = b.op("ADD", d, const=w)
        lv = b.load("left", w)
        rr = b.load("right", idx)
        diffs.append(b.op("ABS", b.op("SUB", lv, rr)))
    while len(diffs) > 1:
        diffs = [b.op("ADD", diffs[2 * j], diffs[2 * j + 1])
                 for j in range(len(diffs) // 2)]
    sad = diffs[0]
    better = b.op("CMPLT", sad, best)
    nbest = b.op("SELECT", better, sad, best)
    nbestd = b.op("SELECT", better, d, bestd)
    b.bind(best, nbest)
    b.bind(bestd, nbestd)
    b.store("best", 0, nbest)
    b.store("bestd", 0, nbestd)
    def mk(r):
        return {"left": _rand(r, N + W, 0, 256),
                "right": _rand(r, N + W, 0, 256)}
    return b.build(), mk, N


def dct() -> KernelEntry:
    """8-point 1D DCT butterfly stage (feed-forward, wide)."""
    b = DFGBuilder("dct")
    N = N_ITERS
    b.array("x", 8 * N)
    b.array("y", 8 * N, output=True)
    i = b.counter(0, 8)
    x = [b.load("x", b.op("ADD", i, const=j)) for j in range(8)]
    s = [b.op("ADD", x[j], x[7 - j]) for j in range(4)]
    dd = [b.op("SUB", x[j], x[7 - j]) for j in range(4)]
    c = [64, 83, 36, 89, 75, 50, 18]
    y0 = b.op("SHR", b.op("MUL", b.op("ADD", b.op("ADD", s[0], s[3]),
                                      b.op("ADD", s[1], s[2])), c[0]), 7)
    y4 = b.op("SHR", b.op("MUL", b.op("SUB", b.op("ADD", s[0], s[3]),
                                      b.op("ADD", s[1], s[2])), c[0]), 7)
    y2 = b.op("SHR", b.op("ADD", b.op("MUL", b.op("SUB", s[0], s[3]), c[1]),
                          b.op("MUL", b.op("SUB", s[1], s[2]), c[2])), 7)
    y6 = b.op("SHR", b.op("SUB", b.op("MUL", b.op("SUB", s[0], s[3]), c[2]),
                          b.op("MUL", b.op("SUB", s[1], s[2]), c[1])), 7)
    y1 = b.op("SHR", b.op("ADD", b.op("MUL", dd[0], c[3]),
                          b.op("MUL", dd[1], c[4])), 7)
    y3 = b.op("SHR", b.op("ADD", b.op("MUL", dd[2], c[5]),
                          b.op("MUL", dd[3], c[6])), 7)
    y5 = b.op("SHR", b.op("SUB", b.op("MUL", dd[1], c[5]),
                          b.op("MUL", dd[3], c[3])), 7)
    y7 = b.op("SHR", b.op("SUB", b.op("MUL", dd[2], c[6]),
                          b.op("MUL", dd[0], c[2])), 7)
    for j, y in enumerate((y0, y1, y2, y3, y4, y5, y6, y7)):
        b.store("y", b.op("ADD", i, const=j), y)
    return b.build(), (lambda r: {"x": _rand(r, 8 * N)}), N


def nw() -> KernelEntry:
    """Needleman-Wunsch row sweep: tight recurrence on the left cell."""
    b = DFGBuilder("nw")
    N = N_ITERS
    b.array("above", N + 1)
    b.array("seqa", N)
    b.array("seqb", N)
    b.array("row", N, output=True)
    j = b.counter()
    left = b.recur(init=0)
    diag = b.load("above", j)
    up = b.load("above", b.op("ADD", j, const=1))
    a = b.load("seqa", j)
    bb = b.load("seqb", j)
    match = b.op("SELECT", b.op("CMPEQ", a, bb), 1, -1)
    c_diag = b.op("ADD", diag, match)
    c_up = b.op("SUB", up, 1)
    c_left = b.op("SUB", left, 1)
    score = b.op("MAX", b.op("MAX", c_diag, c_up), c_left)
    b.bind(left, score)
    b.store("row", j, score)
    def mk(r):
        return {"above": _rand(r, N + 1, -8, 8), "seqa": _rand(r, N, 0, 4),
                "seqb": _rand(r, N, 0, 4)}
    return b.build(), mk, N


def jax_poly() -> KernelEntry:
    """jaxpr-extracted compute kernel (exercises trace_into end-to-end)."""
    b = DFGBuilder("jax_poly")
    N = N_ITERS
    b.array("x", N)
    b.array("y", N, output=True)
    i = b.counter()
    x = b.load("x", i)

    def f(v):
        import jax.numpy as jnp
        p = v * v + 3 * v - 7
        q = jnp.where(p > 0, p, -p)
        return jnp.minimum(q, 1 << 20) ^ 1023

    (out,) = trace_into(b, f, [x])
    b.store("y", i, out)
    return b.build(), (lambda r: {"x": _rand(r, N)}), N


KERNELS: Dict[str, Callable[[], KernelEntry]] = {
    "fft": fft,
    "adpcm": adpcm,
    "aes": aes,
    "disparity": disparity,
    "dct": dct,
    "nw": nw,
    "gemm": gemm,
    "jax_poly": jax_poly,
}

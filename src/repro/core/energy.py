"""Analytic area/power/efficiency model calibrated to PACE silicon.

We have no 40 nm silicon here, so the paper's measured results
(Figs. 10-11, Table IV) are reproduced as a calibrated analytic model:

  * frequency:  f(V) = 210 MHz/V * (V - 0.5 V)      — fits (0.6 V, 21 MHz)
                                                       and (1.0 V, 105 MHz)
  * CGRA power: P(V) = k * V^2 * f(V) + P_static     — fits (0.6 V, 4.4 mW)
                                                       and (1.0 V, 43 mW)
  * power split at 0.6 V (Fig. 11c): CM 52%, PE ctrl 23%, router 14%,
    ALU 8%, data memory 3% — CM dominates because it is read every cycle.
  * area split (Fig. 11b): PE logic 42%, dmem 29%, CM 21%, routing 8%
    of the CGRA's 3.02 mm^2 (normalized), inside the 7.6 mm^2 SoC
    (RISC-V 42%, SRAM 24%, CGRA 34%, Fig. 11a).

`efficiency()` reproduces the paper's energy-efficiency curve (~305-360
GOPS/W at 0.6 V falling to ~154 GOPS/W at 0.95-1.0 V) and the Table IV
normalization rules; `kernel_energy()` prices a mapped kernel from its
machine configuration, including PACE's dynamic clock gating of idle PEs
(paper: ~10% extra savings).
"""
from __future__ import annotations

from typing import Dict


# -- calibration constants (fit to the paper's measurements) ------------------
N_PES = 64
F_SLOPE_MHZ_PER_V = 210.0
V_T = 0.5
K_DYN_MW_PER_V2MHZ = 0.3962        # from (0.6V, 4.4mW) and (1.0V, 43mW)
P_STATIC_MW = 1.405
POWER_SPLIT = {"cm": 0.52, "ctrl": 0.23, "router": 0.14, "alu": 0.08,
               "dmem": 0.03}
AREA_SPLIT_CGRA = {"pe_logic": 0.42, "dmem": 0.29, "cm": 0.21, "routing": 0.08}
AREA_SPLIT_SOC = {"riscv": 0.42, "sram": 0.24, "cgra": 0.34}
SOC_AREA_MM2 = 7.6
CGRA_AREA_MM2 = 3.02               # normalized, Table IV
DYNAMIC_GATING_SAVINGS = 0.10      # paper: "additional 10% power reduction"
# PACE's peak-GOPS accounting counts slightly more than one op per active
# PE-cycle (multi-hop router forwards count as ops); calibrated so the
# model reproduces the published 360 GOPS/W at (0.6 V, 21 MHz, 4.4 mW).
OPS_PER_PE_CYCLE = 1.18


def freq_mhz(vdd: float) -> float:
    return max(0.0, F_SLOPE_MHZ_PER_V * (vdd - V_T))


def cgra_power_mw(vdd: float, activity: float = 1.0,
                  dynamic_gating: bool = False) -> float:
    """Total CGRA power; ``activity`` scales the dynamic component."""
    f = freq_mhz(vdd)
    dyn = K_DYN_MW_PER_V2MHZ * vdd ** 2 * f * activity
    if dynamic_gating:
        dyn *= 1.0 - DYNAMIC_GATING_SAVINGS
    return dyn + P_STATIC_MW


def efficiency_gops_w(vdd: float, util: float = 1.0,
                      dynamic_gating: bool = False) -> float:
    """GOPS/W at a supply voltage (64 PEs, one op per active PE-cycle)."""
    f = freq_mhz(vdd)
    gops = N_PES * f * 1e6 * util * OPS_PER_PE_CYCLE / 1e9
    p_w = cgra_power_mw(vdd, activity=max(util, 0.3),
                        dynamic_gating=dynamic_gating) / 1e3
    return gops / p_w if p_w > 0 else 0.0


def point_efficiency_gops_w(n_ops: int, II: int, n_pes: int,
                            vdd: float = 0.6,
                            dynamic_gating: bool = True) -> float:
    """GOPS/W of a mapped design point from its achieved II.

    Utilization is ops issued per cycle over the array:
    ``n_ops / (II * n_pes)`` — identical to the active-slot fraction
    ``MachineConfig.utilization()`` reports for a temporal mapping, and
    the natural generalization for the spatial analytic model (which has
    no machine configuration to count slots in).  This is the efficiency
    axis of the DSE Pareto report (``ual.explore``).
    """
    if II <= 0 or n_pes <= 0:
        return 0.0
    util = min(1.0, n_ops / (II * n_pes))
    return efficiency_gops_w(vdd, util=util, dynamic_gating=dynamic_gating)


def normalized_area(area_mm2: float, node_nm: float) -> float:
    return area_mm2 * (40.0 / node_nm)


def normalized_efficiency(gops_w: float, node_nm: float) -> float:
    return gops_w * (node_nm / 40.0) ** 2


# -- per-component energy (pJ per PE-cycle at a given V) ----------------------

def component_energy_pj(vdd: float = 0.6) -> Dict[str, float]:
    """Energy per PE per cycle split by component, from the Fig. 11c shares."""
    f = freq_mhz(vdd)
    total_dyn_mw = K_DYN_MW_PER_V2MHZ * vdd ** 2 * f
    e_cycle_nj = total_dyn_mw / (f * 1e6) * 1e6      # nJ per CGRA cycle
    e_pe_pj = e_cycle_nj / N_PES * 1e3
    return {k: v * e_pe_pj for k, v in POWER_SPLIT.items()}


def kernel_energy(config, n_iters: int, vdd: float = 0.6,
                  dynamic_gating: bool = True) -> Dict[str, float]:
    """Energy estimate (pJ) for running a mapped kernel ``n_iters`` times.

    CM is read every cycle for every non-gated PE (the paper's dominant
    term); ALU/dmem energy scales with fired ops; router energy with
    crossbar activity; idle PEs burn CM+ctrl unless dynamically gated.
    """
    comp = component_energy_pj(vdd)
    II, P = config.II, config.n_pes
    from repro.core.machine import OPC
    active_slots = int((config.opcode != OPC["NOP"]).sum())
    mem_slots = int(((config.opcode == OPC["LOAD"]) |
                     (config.opcode == OPC["STORE"])).sum())
    route_fields = int((config.xbar[..., 0] != 0).sum())
    total_slots = II * P
    idle_slots = total_slots - active_slots
    idle_factor = (1.0 - DYNAMIC_GATING_SAVINGS * 2) if dynamic_gating else 1.0
    e = {
        "cm": comp["cm"] * (active_slots + idle_slots * idle_factor),
        "ctrl": comp["ctrl"] * (active_slots + idle_slots * idle_factor),
        "alu": comp["alu"] * active_slots,
        "router": comp["router"] * (route_fields + 0.25 * active_slots),
        "dmem": comp["dmem"] * mem_slots * (P / 4.0),
    }
    per_iter = sum(e.values())
    e_total = {k: v * n_iters for k, v in e.items()}
    e_total["total"] = per_iter * n_iters
    e_total["per_op"] = per_iter / max(1, active_slots)
    return e_total


def table4_comparison() -> Dict[str, Dict[str, float]]:
    """Reproduce Table IV's normalized comparison."""
    rows = {
        "Amber":  dict(node=16, area=20.1, eff=538.0),
        "SSCL":   dict(node=28, area=3.9, eff=307.0),
        "ISSCC":  dict(node=22, area=4.9, eff=978.0),
        "JSSC":   dict(node=28, area=4.80, eff=196.0),
        "PACE":   dict(node=40, area=3.02, eff=efficiency_gops_w(0.6)),
    }
    out = {}
    for k, r in rows.items():
        out[k] = {
            **r,
            "norm_area": normalized_area(r["area"], r["node"]),
            "norm_eff": normalized_efficiency(r["eff"], r["node"]),
        }
    return out

"""Shared lowering: resolve a MachineConfig's static routes to dense tables.

This is the single source of truth for the **lowered artifact** every
execution engine consumes.  HyCUBE's central claim is that the
interconnect is *compiler-scheduled*: crossbar settings are static per
II-slot, so a single-cycle multi-hop path is a fixed combinational chain.
We exploit exactly that property — every wire chain is resolved ONCE, at
lowering time, into a direct (source PE, source register) select, so no
engine ever routes dynamically:

  * the vectorized batched simulator (``core.simulator.simulate_batch``)
    turns operand fetch into static numpy gathers over the PE-output /
    register state,
  * the Pallas ``cgra_exec`` TPU kernel turns it into one-hot
    compare/select reductions over the same state (the TPU-native
    analogue of the clockless-repeater bypass).

The ``ual`` compile pipeline runs this as its ``lowering`` pass and
memoizes the result in the mapping cache next to the ``MapResult``,
keyed by the same ``(program.digest, target.digest)`` pair — lower once,
run many.

Lowered operand/source kinds (values in the dense tables):
  K_NONE   = 0 — absent operand
  K_O      = 1 — previous-cycle output latch of PE ``pe``
  K_R      = 2 — register ``reg`` of PE ``pe`` (previous-cycle value)
  K_CONST  = 3 — the instruction immediate
  K_RESULT = 4 — current-cycle ALU result of own PE (register writes only)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.core.machine import (MachineConfig, SRC_CONST, SRC_NONE, SRC_REG,
                                SRC_SELF, XB_IN, XB_NONE, XB_O, XB_REG)

K_NONE, K_O, K_R, K_CONST, K_RESULT = 0, 1, 2, 3, 4

#: bump when the dense-table layout changes — folded into the on-disk
#: cache entry name so stale lowered artifacts are never deserialized
#: (v2: added the ``unresolved_inputs`` lowering-health counter)
LOWERING_VERSION = 2


@dataclass
class LinkedConfig:
    """Dense int32 tables driving every execution engine (CM-in-VMEM image
    for the Pallas kernel, gather/scatter plans for the batched simulator).
    """
    II: int
    n_pes: int
    n_regs: int
    mem_pes: Tuple[int, ...]
    scalar: np.ndarray    # (S, P, 4)    [opcode, const, use_const, t0]
    ops: np.ndarray       # (S, P, 3, 5) [kind, pe, reg, dist, init]
    regw: np.ndarray      # (S, P, R, 3) [kind, pe, reg]
    #: the fabric's shared-scratchpad port budget, threaded through
    #: unconditionally by ``link_config``.  0 means *unknown/unbounded*:
    #: the engines' runtime oversubscription guard (``limit and
    #: ports_used > limit``) and the static verifier's UAL001 check are
    #: both disabled — port pressure is still *recorded* in ``SimStats``.
    #: Every registered fabric sets a real limit; 0 only appears on
    #: hand-built tables that never saw a fabric.
    n_mem_ports: int = 0
    #: how many wire selects (``SRC_IN`` operands / ``XB_IN`` register
    #: writes) failed to resolve to a driver at lowering time and were
    #: collapsed to a silent ``K_NONE`` row.  0 for every config a
    #: correct mapper emits; the static verifier
    #: (``repro.analysis.verifier``, code UAL004) flags any nonzero
    #: count without re-deriving routing — this is the root exposure of
    #: the silent-``K_NONE`` lowering hazard
    unresolved_inputs: int = 0

    def cm_bytes(self) -> int:
        return self.scalar.nbytes + self.ops.nbytes + self.regw.nbytes

    def __getstate__(self):
        # runtime attachments (the memoized batched-engine plans) must not
        # leak into cache pickles — only the dense tables are the artifact
        return {k: v for k, v in self.__dict__.items()
                if not k.startswith("_")}

    @property
    def t0_max(self) -> int:
        """Latest issue slot in the schedule (static: a table property)."""
        t0 = self.scalar[:, :, 3]
        return int(t0.max()) if (t0 >= 0).any() else 0

    def total_cycles(self, n_iters: int) -> int:
        return self.t0_max + n_iters * self.II + self.II + 2


def lowered_fingerprint(linked: LinkedConfig) -> str:
    """Content hash of the dense tables themselves.

    Identifies a lowered artifact independently of how it was produced —
    the persistent JIT execution engine (``ual.engine``) keys its trace
    cache on it, so two Executables sharing one artifact (same mapping,
    different Program wrappers) also share every compiled trace.  Memoized
    on the instance (underscore attribute: excluded from cache pickles by
    ``LinkedConfig.__getstate__``).
    """
    fp = getattr(linked, "_fingerprint", None)
    if fp is None:
        import hashlib
        h = hashlib.sha256()
        h.update(f"{LOWERING_VERSION}:{linked.II}:{linked.n_pes}:"
                 f"{linked.n_regs}:{linked.mem_pes}:"
                 f"{linked.n_mem_ports}".encode())
        for a in (linked.scalar, linked.ops, linked.regw):
            h.update(np.ascontiguousarray(a).tobytes())
        fp = h.hexdigest()
        linked._fingerprint = fp
    return fp


def config_fingerprint(cfg: MachineConfig) -> str:
    """Content hash of the executable configuration state.

    Identifies WHICH configuration a lowered artifact was derived from:
    the wall-clock-budgeted mapper may legitimately produce different
    configs for the same ``(program, target)`` key on different machines,
    so cached lowered tables are only trusted when their fingerprint
    matches the config in use.
    """
    import hashlib
    h = hashlib.sha256()
    h.update(str(cfg.II).encode())
    for a in (cfg.opcode, cfg.const, cfg.use_const, cfg.t0, cfg.op_src,
              cfg.xbar, cfg.regw):
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def _resolve_drivers(cfg: MachineConfig, s: int) -> np.ndarray:
    """Per-link ultimate driver for slot ``s``: rows [kind, pe, reg].

    Relaxes the bypass chain the same way the reference simulator does per
    cycle — but once, at lowering time, because the chain is static.
    """
    f = cfg.fabric
    n_links = len(f.links)
    drv = np.zeros((n_links, 3), np.int64)          # K_NONE
    for _ in range(max(1, f.max_hops)):
        changed = False
        for p in range(f.n_pes):
            for j, li in enumerate(f.out_links(p)):
                kind, idx = cfg.xbar[s, p, j]
                if kind == XB_NONE or drv[li, 0] != K_NONE:
                    continue
                if kind == XB_O:
                    drv[li] = (K_O, p, 0)
                    changed = True
                elif kind == XB_REG:
                    drv[li] = (K_R, p, idx)
                    changed = True
                elif kind == XB_IN and drv[idx, 0] != K_NONE:
                    drv[li] = drv[idx]
                    changed = True
        if not changed:
            break
    return drv


def link_config(cfg: MachineConfig) -> LinkedConfig:
    """Lower a MachineConfig to the dense tables the engines execute."""
    S, P = cfg.II, cfg.fabric.n_pes
    R = cfg.regw.shape[2]
    scalar = np.zeros((S, P, 4), np.int32)
    ops = np.zeros((S, P, 3, 5), np.int32)
    regw = np.zeros((S, P, R, 3), np.int32)
    scalar[:, :, 0] = cfg.opcode
    scalar[:, :, 1] = cfg.const
    scalar[:, :, 2] = cfg.use_const
    scalar[:, :, 3] = cfg.t0

    unresolved = 0
    for s in range(S):
        drv = _resolve_drivers(cfg, s)
        for p in range(P):
            for k in range(3):
                kind, idx, dist, init = cfg.op_src[s, p, k]
                if kind == SRC_NONE:
                    row = (K_NONE, 0, 0, dist, init)
                elif kind == SRC_REG:
                    row = (K_R, p, idx, dist, init)
                elif kind == SRC_SELF:
                    row = (K_O, p, 0, dist, init)
                elif kind == SRC_CONST:
                    row = (K_CONST, 0, 0, dist, init)
                else:                                  # SRC_IN: wire -> driver
                    dk, dp, dr = drv[idx]
                    if dk == K_NONE:
                        # the driver fixed point never resolved: the
                        # operand collapses to an absent source.  Count
                        # it so the verifier / fingerprint consumers can
                        # flag the hazard without re-deriving routing
                        unresolved += 1
                    row = (int(dk), int(dp), int(dr), dist, init)
                ops[s, p, k] = row
            for r in range(R):
                kind, idx = cfg.regw[s, p, r]
                if kind == XB_NONE:
                    regw[s, p, r] = (K_NONE, 0, 0)
                elif kind == XB_O:
                    regw[s, p, r] = (K_RESULT, p, 0)
                else:                                  # XB_IN via wire
                    dk, dp, dr = drv[idx]
                    if dk == K_NONE:
                        unresolved += 1
                    regw[s, p, r] = (int(dk), int(dp), int(dr))
    return LinkedConfig(II=cfg.II, n_pes=P, n_regs=R,
                        mem_pes=tuple(cfg.fabric.mem_pes),
                        scalar=scalar, ops=ops, regw=regw,
                        n_mem_ports=cfg.fabric.n_mem_ports,
                        unresolved_inputs=unresolved)

"""Machine-level CGRA configuration ("bitstream") + emission from a mapping.

The mapper's placements and route trees are lowered to per-(slot, PE)
instruction words, exactly what HyCUBE's per-PE configuration memory holds
(paper §III-B-1): ALU opcode + operand selects, crossbar settings, register
writes and an immediate.  The same arrays drive

  * the cycle-accurate simulator (`core/simulator.py`),
  * the Pallas TPU kernel (`kernels/cgra_exec`) — CM resident in VMEM.

Prologue/epilogue are handled the way PACE's idle-state instructions do it:
every instruction carries its first firing cycle ``t0``; a PE is clock-gated
(idle) for slots whose window has not started, and recurrence operands carry
``(dist, init)`` so iterations ``i < dist`` substitute the initial value.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from repro.core.adl import Fabric
from repro.core.dfg import DFG
from repro.core.mrrg import Route

OPCODES = (
    "NOP", "ADD", "SUB", "MUL", "SHL", "SHR", "AND", "OR", "XOR",
    "MIN", "MAX", "ABS", "CMPLT", "CMPGT", "CMPEQ", "CMPNE", "CMPLE",
    "CMPGE", "SELECT", "MOVC", "LOAD", "STORE", "ROUTE",
)
OPC = {o: i for i, o in enumerate(OPCODES)}

# operand source kinds
SRC_NONE, SRC_REG, SRC_IN, SRC_SELF, SRC_CONST = 0, 1, 2, 3, 4
# crossbar / register-write source kinds
XB_NONE, XB_O, XB_IN, XB_REG = 0, 1, 2, 3


@dataclass
class MachineConfig:
    fabric: Fabric
    II: int
    opcode: np.ndarray        # (S, P) int32
    const: np.ndarray         # (S, P) int32
    use_const: np.ndarray     # (S, P) int32: const is a trailing ALU operand
    t0: np.ndarray            # (S, P) int32, -1 = never fires
    node_id: np.ndarray       # (S, P) int32, -1 = none
    op_src: np.ndarray        # (S, P, 3, 4) int32 [kind, idx, dist, init]
    xbar: np.ndarray          # (S, P, max_out, 2) int32 [kind, idx(globlink/reg)]
    regw: np.ndarray          # (S, P, n_regs, 2) int32 [kind, idx(globlink)]

    @property
    def n_pes(self) -> int:
        return self.fabric.n_pes

    def cm_words(self) -> int:
        """Configuration-memory words per PE (for the energy model)."""
        per_slot = 2 + 3 * 2 + self.xbar.shape[2] + self.regw.shape[2]
        return self.II * per_slot

    def utilization(self) -> float:
        used = int((self.opcode != OPC["NOP"]).sum())
        return used / float(self.II * self.n_pes)


def _slot(t: int, II: int) -> int:
    return t % II


def emit_config(dfg: DFG, fabric: Fabric, II: int,
                placements: Dict[int, Tuple[int, int]],
                routes: List[Route]) -> MachineConfig:
    """Lower placements + routes to the machine configuration."""
    S, P = II, fabric.n_pes
    max_out = max((len(fabric.out_links(p)) for p in range(P)), default=1)
    n_regs = max(a.n_regs for a in fabric.pes)
    cfg = MachineConfig(
        fabric=fabric, II=II,
        opcode=np.full((S, P), OPC["NOP"], np.int32),
        const=np.zeros((S, P), np.int32),
        use_const=np.zeros((S, P), np.int32),
        t0=np.full((S, P), -1, np.int32),
        node_id=np.full((S, P), -1, np.int32),
        op_src=np.zeros((S, P, 3, 4), np.int32),
        xbar=np.zeros((S, P, max_out, 2), np.int32),
        regw=np.zeros((S, P, n_regs, 2), np.int32),
    )
    local_out = {}
    for p in range(P):
        for j, li in enumerate(fabric.out_links(p)):
            local_out[li] = j

    def set_instr(slot, pe, opc, t0, nid, const=0):
        cur = cfg.opcode[slot, pe]
        if cur != OPC["NOP"] and not (cur == OPC[opc] and cfg.t0[slot, pe] == t0):
            raise ValueError(f"FU collision at slot={slot} pe={pe}")
        cfg.opcode[slot, pe] = OPC[opc]
        cfg.t0[slot, pe] = t0
        cfg.node_id[slot, pe] = nid
        cfg.const[slot, pe] = np.int64(const).astype(np.int32)

    def set_xbar(slot, pe, li, kind, idx):
        j = local_out[li]
        cur = cfg.xbar[slot, pe, j]
        if cur[0] != XB_NONE and (cur[0] != kind or cur[1] != idx):
            raise ValueError(f"xbar collision slot={slot} pe={pe} link={li}")
        cfg.xbar[slot, pe, j] = (kind, idx)

    def set_regw(slot, pe, r, kind, idx):
        cur = cfg.regw[slot, pe, r]
        if cur[0] != XB_NONE and (cur[0] != kind or cur[1] != idx):
            raise ValueError(f"regw collision slot={slot} pe={pe} r={r}")
        cfg.regw[slot, pe, r] = (kind, idx)

    # ---- instructions for placed nodes -------------------------------------
    for nid, (pe, t) in placements.items():
        n = dfg.nodes[nid]
        set_instr(_slot(t, II), pe, n.op, t, nid, n.const or 0)
        if n.const is not None and n.op not in ("LOAD", "STORE", "MOVC"):
            cfg.use_const[_slot(t, II), pe] = 1

    # ---- route actions -------------------------------------------------------
    for rt in routes:
        path = rt.path
        for a, b in zip(path[:-1], path[1:]):
            ka, kb = a[0], b[0]
            if ka == "O" and kb == "L":
                _, p, t = a
                set_xbar(_slot(t, II), p, b[1], XB_O, 0)
            elif ka == "R" and kb == "L":
                _, p, r, t = a
                set_xbar(_slot(t, II), p, b[1], XB_REG, r)
            elif ka == "L" and kb == "L":
                li, t = a[1], a[2]
                mid = fabric.links[li][1]
                set_xbar(_slot(t, II), mid, b[1], XB_IN, li)
            elif ka == "L" and kb == "R":
                li, t = a[1], a[2]
                dst = fabric.links[li][1]
                set_regw(_slot(t, II), dst, b[2], XB_IN, li)
            elif ka == "O" and kb == "R":
                _, p, t = a
                # write own result into own register (happens with the latch)
                set_regw(_slot(t - 1, II), p, b[2], XB_O, 0)
            elif ka == "R" and kb == "R":
                pass  # register hold
            elif ka == "R" and kb == "O":
                # N2N ROUTE through the FU
                _, p, r, t = a
                set_instr(_slot(t, II), p, "ROUTE", t, -1)
                cfg.op_src[_slot(t, II), p, 0] = (SRC_REG, r, 0, 0)
            else:
                raise AssertionError(f"bad route transition {a} -> {b}")

    # ---- consumer operand selects ---------------------------------------------
    for rt in routes:
        v = dfg.nodes[rt.sink_node]
        pe, tv = placements[rt.sink_node]
        opnd = v.operands[rt.sink_operand]
        entry = rt.sink_entry
        if entry[0] == "L":
            src = (SRC_IN, entry[1], opnd.dist, opnd.init)
        elif entry[0] == "R":
            src = (SRC_REG, entry[2], opnd.dist, opnd.init)
        else:  # 'O' — same-PE forward
            src = (SRC_SELF, 0, opnd.dist, opnd.init)
        cur = cfg.op_src[_slot(tv, II), pe, rt.sink_operand]
        if cur[0] != SRC_NONE and tuple(cur) != src:
            raise ValueError(f"operand collision node={rt.sink_node}")
        cfg.op_src[_slot(tv, II), pe, rt.sink_operand] = src

    return cfg

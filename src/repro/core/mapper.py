"""Architecture-adaptive modulo-scheduling mapper (paper §III-A-2).

Given a DFG and an ADL fabric, find the minimum-II modulo schedule:

  1. MII = max(ResMII, RecMII)  [Rau's iterative modulo scheduling bounds]
  2. For II = MII, MII+1, ...: place DFG nodes in topological order with
     recurrence-cycle nodes prioritized by cycle length onto (FU, time)
     instances of the MRRG, routing every edge with Dijkstra; ports may be
     temporarily oversubscribed.
  3. Oversubscription is resolved by a pluggable ``MapperStrategy`` — the
     built-ins are (a) ``adaptive``, the SPR-inspired heuristic that
     inflates the cost of overused resources between restarts, and
     (b) ``sa``, simulated annealing that perturbs placements along a
     cooling schedule.  Third parties add strategies with
     ``register_strategy`` (also exported as ``ual.register_strategy``);
     a LISA-style label hook can bias placement candidates.

Success at an II yields a machine configuration (see `core/machine.py`).
"""
from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.adl import Fabric, MEM_OPS
from repro.core.dfg import DFG
from repro.core.machine import MachineConfig, emit_config
from repro.core.mrrg import Occupancy, Route, Router

#: bump whenever mapping behavior changes (placement order, routing cost,
#: restart schedule, ...) — the UAL mapping cache folds this into its key,
#: so stale on-disk MapResults from an older mapper are never served
MAPPER_VERSION = 1


@dataclass
class MapResult:
    success: bool
    II: int
    mii: int
    placements: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    config: Optional[MachineConfig] = None
    schedule_len: int = 0
    restarts: int = 0
    wall_s: float = 0.0
    strategy: str = "adaptive"

    @property
    def fu_util(self) -> float:
        return self.config.utilization() if self.config else 0.0


# ---------------------------------------------------------------------------
# MII bounds
# ---------------------------------------------------------------------------

def res_mii(dfg: DFG, fabric: Fabric) -> int:
    n_fus = fabric.n_pes
    n_mem_fus = max(1, len(fabric.mem_pes))
    bounds = [
        math.ceil(len(dfg.nodes) / n_fus),
        math.ceil(dfg.n_mem_ops / n_mem_fus),
        math.ceil(dfg.n_mem_ops / max(1, fabric.n_mem_ports)),
    ]
    return max(1, *bounds)


def rec_mii(dfg: DFG) -> int:
    best = 1
    for n in dfg.nodes:
        for o in n.operands:
            if o.dist > 0:
                # cycle length = edges on the dist==0 path u..v plus back edge
                cyc = _cycle_len(dfg, o.src, n.id)
                if cyc is not None:
                    best = max(best, math.ceil(cyc / o.dist))
    return best


def _cycle_len(dfg: DFG, u: int, v: int) -> Optional[int]:
    """Edges on shortest dist==0 path v ->* u, +1 for the back edge."""
    if u == v:
        return 1
    from collections import deque
    adj = {n.id: [] for n in dfg.nodes}
    for n in dfg.nodes:
        for o in n.operands:
            if o.dist == 0:
                adj[o.src].append(n.id)
    dq, dist = deque([v]), {v: 0}
    while dq:
        x = dq.popleft()
        for y in adj[x]:
            if y not in dist:
                dist[y] = dist[x] + 1
                if y == u:
                    return dist[y] + 1
                dq.append(y)
    return None


def compute_mii(dfg: DFG, fabric: Fabric) -> int:
    return max(res_mii(dfg, fabric), rec_mii(dfg))


# ---------------------------------------------------------------------------
# Placement order (topological, recurrence cycles first)
# ---------------------------------------------------------------------------

def placement_order(dfg: DFG) -> List[int]:
    cyc_len: Dict[int, int] = {}
    for cyc in dfg.recurrence_cycles():
        for nid in cyc:
            cyc_len[nid] = max(cyc_len.get(nid, 0), len(cyc))
    dfg.compute_asap_alap(4 * len(dfg.nodes))
    indeg = {n.id: sum(1 for o in n.operands if o.dist == 0) for n in dfg.nodes}
    ready = [i for i, d in indeg.items() if d == 0]
    order = []
    while ready:
        ready.sort(key=lambda i: (-cyc_len.get(i, 0), dfg.nodes[i].asap, i))
        u = ready.pop(0)
        order.append(u)
        for (v, _) in dfg.users[u]:
            cnt = sum(1 for o in dfg.nodes[v].operands
                      if o.src == u and o.dist == 0)
            if cnt:
                indeg[v] -= cnt
                if indeg[v] == 0:
                    ready.append(v)
    return order


# ---------------------------------------------------------------------------
# The mapper
# ---------------------------------------------------------------------------

class ModuloMapper:
    def __init__(self, dfg: DFG, fabric: Fabric, II: int, seed: int = 0,
                 label_fn: Optional[Callable[[int, int, int], float]] = None):
        self.dfg = dfg
        self.f = fabric
        self.II = II
        self.occ = Occupancy(fabric, II)
        self.router = Router(fabric, self.occ)
        self.rng = random.Random(seed)
        self.label_fn = label_fn      # LISA-style placement bias hook
        self.placements: Dict[int, Tuple[int, int]] = {}
        self.value_tree: Dict[int, Dict[Tuple, bool]] = {}
        self.value_routes: Dict[int, List[Route]] = {}
        self._order = placement_order(dfg)

    # -- route bookkeeping ----------------------------------------------------
    def _commit(self, rt: Route) -> None:
        for (k, t) in rt.keys:
            self.occ.add(k, rt.vid, t)
        tree = self.value_tree.setdefault(rt.vid, {})
        for n in rt.path:
            tree[n] = True
        self.value_routes.setdefault(rt.vid, []).append(rt)

    def _rip_value(self, vid: int) -> List[Tuple[int, int]]:
        """Remove all routes of a value; returns its (sink, operand) edges."""
        edges = []
        for rt in self.value_routes.get(vid, []):
            for (k, _) in rt.keys:
                self.occ.remove(k, vid)
            edges.append((rt.sink_node, rt.sink_operand))
        self.value_routes[vid] = []
        self.value_tree[vid] = {}
        return edges

    def _route_edge(self, vid: int, sink: int, k: int) -> Optional[Route]:
        pp, tp = self.placements[vid]
        pv, tv = self.placements[sink]
        d = self.dfg.nodes[sink].operands[k].dist
        tc = tv + d * self.II
        return self.router.route(vid, self.value_tree.get(vid, {}),
                                 pp, tp, sink, k, pv, tc)

    # -- candidate generation ---------------------------------------------------
    def _candidates(self, nid: int) -> List[Tuple[int, int]]:
        n = self.dfg.nodes[nid]
        pes = (self.f.mem_pes if n.op in MEM_OPS
               else [p for p in range(self.f.n_pes) if self.f.supports(p, n.op)])
        earliest = max(0, n.asap)
        latest = None
        for o in n.operands:
            if o.src in self.placements:
                _, tp = self.placements[o.src]
                earliest = max(earliest, tp + 1 - o.dist * self.II)
            else:
                # modulo constraint through an unplaced producer: it cannot
                # execute before its own ASAP, so this node cannot execute
                # before asap(src) + 1 - dist*II  (critical for recurrence
                # sinks placed ahead of their back-edge source)
                earliest = max(earliest,
                               self.dfg.nodes[o.src].asap + 1 - o.dist * self.II)
        for (v, k) in self.dfg.users[nid]:
            if v in self.placements:
                d = self.dfg.nodes[v].operands[k].dist
                _, tv = self.placements[v]
                ub = tv + d * self.II - 1
                latest = ub if latest is None else min(latest, ub)
        t_hi = earliest + self.II - 1
        if latest is not None:
            t_hi = min(t_hi, latest)
        if t_hi < earliest:
            return []
        # rank PEs by proximity to placed parents (cheap heuristic)
        parents = [self.placements[o.src][0] for o in n.operands
                   if o.src in self.placements]

        def pe_rank(p: int) -> float:
            if not parents:
                base = 0.0
            else:
                base = sum(self._dist(p, q) for q in parents)
            if self.label_fn is not None:
                base += self.label_fn(nid, p, self.II)
            return base + 0.01 * self.rng.random()

        pes = sorted(pes, key=pe_rank)
        out = []
        for t in range(earliest, t_hi + 1):
            for p in pes:
                out.append((p, t))
        return out

    def _dist(self, p: int, q: int) -> int:
        (r1, c1), (r2, c2) = self.f.pe_xy(p), self.f.pe_xy(q)
        d = abs(r1 - r2) + abs(c1 - c2)
        return (d + self.f.max_hops - 1) // self.f.max_hops

    # -- place one node -----------------------------------------------------------
    def _try_place(self, nid: int, pe: int, t: int
                   ) -> Optional[Tuple[float, List[Route]]]:
        n = self.dfg.nodes[nid]
        fu_key = ("FU", pe, t % self.II)
        cost = self.occ.cost(fu_key, nid)
        self.occ.add(fu_key, nid, t)
        keys = [(fu_key, t)]
        if n.op in MEM_OPS:
            mk = ("MEM", t % self.II)
            cost += self.occ.cost(mk, nid)
            self.occ.add(mk, nid, t)
            keys.append((mk, t))
        self.placements[nid] = (pe, t)
        routes: List[Route] = []
        ok = True
        for k, o in enumerate(n.operands):
            if o.src in self.placements:          # includes self-recurrences
                rt = self._route_edge(o.src, nid, k)
                if rt is None:
                    ok = False
                    break
                self._commit(rt)
                routes.append(rt)
                cost += sum(self.occ.cost(kk, o.src) for (kk, _) in rt.keys)
        if ok:
            for (v, k) in self.dfg.users[nid]:
                if v in self.placements and v != nid:
                    rt = self._route_edge(nid, v, k)
                    if rt is None:
                        ok = False
                        break
                    self._commit(rt)
                    routes.append(rt)
                    cost += sum(self.occ.cost(kk, nid) for (kk, _) in rt.keys)
        if not ok:
            self._undo_place(nid, keys, routes)
            return None
        conflicts = 0
        for (k, _) in keys:
            if len(self.occ.users(k)) > self.occ.capacity(k):
                conflicts += 1
        for rt in routes:
            for (k, _) in rt.keys:
                if len(self.occ.users(k)) > self.occ.capacity(k):
                    conflicts += 1
        return cost, conflicts, routes + [Route(nid, -1, -1, [], keys, None)]

    def _undo_place(self, nid: int, keys: List, routes: List[Route]) -> None:
        for rt in routes:
            for (k, _) in rt.keys:
                self.occ.remove(k, rt.vid)
            lst = self.value_routes.get(rt.vid, [])
            if rt in lst:
                lst.remove(rt)
            # rebuild tree for the value
            self._rebuild_tree(rt.vid)
        for (k, _) in keys:
            self.occ.remove(k, nid)
        del self.placements[nid]

    def _rebuild_tree(self, vid: int) -> None:
        tree: Dict[Tuple, bool] = {}
        for rt in self.value_routes.get(vid, []):
            for n in rt.path:
                tree[n] = True
        self.value_tree[vid] = tree

    # -- full placement pass ----------------------------------------------------
    def place_all(self, pes_per_t: int = 3, max_cands: int = 64) -> bool:
        """Place every node: explore the full time window (all t in the II-wide
        range), a few best-ranked PEs per t, preferring conflict-free spots.
        ``max_cands`` bounds per-node search so large DFGs map in seconds."""
        for nid in self._order:
            cands = self._candidates(nid)
            if not cands:
                return False
            by_t: Dict[int, List[int]] = {}
            for (pe, t) in cands:
                by_t.setdefault(t, []).append(pe)
            best = None          # (conflicts, cost, pe, t)
            tried = 0
            for t in sorted(by_t):
                if tried >= max_cands and best is not None:
                    break
                for pe in by_t[t][:pes_per_t]:
                    tried += 1
                    res = self._try_place(nid, pe, t)
                    if res is None:
                        continue
                    cost, conflicts, routes = res
                    cost += 0.05 * t          # mild schedule-length pressure
                    cand = (conflicts, cost, pe, t)
                    if best is None or cand[:2] < best[:2]:
                        best = cand
                    self._undo_full(nid, routes)
                if best is not None and best[0] == 0:
                    break        # conflict-free placement found at this t
            if best is None:
                return False
            _, _, pe, t = best
            if self._try_place(nid, pe, t) is None:
                return False     # should not happen (same occupancy state)
        return True

    def _undo_full(self, nid: int, routes: List[Route]) -> None:
        # last sentinel route holds the FU/MEM keys
        *real, sent = routes
        self._undo_place(nid, sent.keys, real)

    # -- perturbation (simulated annealing) ----------------------------------------
    def _rip_node(self, nid: int) -> List[Tuple[int, List[Tuple[int, int]]]]:
        """Rip a node's placement + all routes touching it; return re-route work."""
        n = self.dfg.nodes[nid]
        pe, t = self.placements[nid]
        self.occ.remove(("FU", pe, t % self.II), nid)
        if n.op in MEM_OPS:
            self.occ.remove(("MEM", t % self.II), nid)
        work = []
        # own value routes
        self._rip_value(nid)
        # parent values: rip whole net, remember their edges
        parents = {o.src for o in n.operands if o.src in self.placements
                   and o.src != nid}
        for pvid in parents:
            edges = self._rip_value(pvid)
            work.append((pvid, edges))
        del self.placements[nid]
        return work

    def sa_polish(self, max_iters: int = 400, t0: float = 3.0,
                  t1: float = 0.05) -> bool:
        if not all(n.id in self.placements for n in self.dfg.nodes):
            return False
        energy = len(self.occ.overused())
        if energy == 0:
            return True
        for it in range(max_iters):
            temp = t0 * (t1 / t0) ** (it / max_iters)
            over = self.occ.overused()
            if not over:
                return True
            # pick a node involved with an overused resource
            over_set = set(over)
            cand_nodes = []
            for vid, rts in self.value_routes.items():
                for rt in rts:
                    if any(k in over_set for (k, _) in rt.keys):
                        cand_nodes.extend([vid, rt.sink_node])
            for nid, (pe, t) in self.placements.items():
                if ("FU", pe, t % self.II) in over_set:
                    cand_nodes.append(nid)
            if not cand_nodes:
                return False
            nid = self.rng.choice(cand_nodes)
            snapshot = len(self.occ.overused())
            work = self._rip_node(nid)
            cands = self._candidates(nid)
            if not cands:
                return False
            pe, t = self.rng.choice(cands[:max(1, len(cands) // 2)])
            res = self._try_place(nid, pe, t)
            if res is None:
                # fall back to any feasible candidate
                placed = False
                for (pe, t) in cands:
                    if self._try_place(nid, pe, t) is not None:
                        placed = True
                        break
                if not placed:
                    return False
            # re-route ripped parent nets
            for pvid, edges in work:
                for (sink, k) in edges:
                    if sink in self.placements and pvid in self.placements:
                        rt = self._route_edge(pvid, sink, k)
                        if rt is None:
                            return False
                        self._commit(rt)
            new_energy = len(self.occ.overused())
            if new_energy > snapshot and \
               self.rng.random() > math.exp(-(new_energy - snapshot) / temp):
                # accept anyway with low probability (no revert — random walk)
                pass
            if new_energy == 0:
                return True
        return len(self.occ.overused()) == 0

    # -- result -----------------------------------------------------------------
    def all_routes(self) -> List[Route]:
        return [rt for rts in self.value_routes.values() for rt in rts]


# ---------------------------------------------------------------------------
# Mapper strategies (pluggable registry)
# ---------------------------------------------------------------------------

class MapperStrategy:
    """How one mapping attempt resolves resource oversubscription.

    ``map_dfg`` owns the II search and the restart schedule; the strategy
    owns what happens *within* one attempt (``attempt``) and how failure
    feedback carries into the next restart (``adapt``).  Subclass and
    register under a name to make it addressable from ``Target.strategy``::

        class MyStrategy(MapperStrategy):
            name = "mine"
            def attempt(self, m):
                return m.place_all() and not m.occ.overused()

        register_strategy("mine", MyStrategy())
    """

    name: str = "?"

    def attempt(self, m: "ModuloMapper") -> bool:
        """Run one full mapping attempt on a fresh ``ModuloMapper`` whose
        occupancy history was seeded by the previous ``adapt``; return True
        when every node is placed and no resource is oversubscribed."""
        raise NotImplementedError

    def adapt(self, m: "ModuloMapper") -> Dict:
        """Between restarts: return the occupancy history carried into the
        next attempt (SPR-style cost inflation of overused resources by
        default — subclasses may return ``{}`` to restart from scratch)."""
        m.occ.bump_hist(m.occ.overused(), 1.0)
        return m.occ.hist


class AdaptiveStrategy(MapperStrategy):
    """SPR-inspired: rely purely on inter-restart history cost inflation."""

    name = "adaptive"

    def attempt(self, m: "ModuloMapper") -> bool:
        return m.place_all() and not m.occ.overused()


class SAStrategy(MapperStrategy):
    """Adaptive placement, then simulated-annealing polish of conflicts."""

    name = "sa"

    def __init__(self, max_iters: int = 400, t0: float = 3.0,
                 t1: float = 0.05):
        self.max_iters, self.t0, self.t1 = max_iters, t0, t1

    def attempt(self, m: "ModuloMapper") -> bool:
        if not m.place_all():
            return False
        if not m.occ.overused():
            return True
        return m.sa_polish(self.max_iters, self.t0, self.t1)


MAPPER_STRATEGIES: Dict[str, MapperStrategy] = {}


def register_strategy(name: str, strategy: MapperStrategy,
                      overwrite: bool = False) -> None:
    """Register a mapper strategy under ``name``.

    Registering an existing name raises unless ``overwrite=True`` — silent
    replacement is how two plugins stomp each other.
    """
    if name in MAPPER_STRATEGIES and not overwrite:
        raise ValueError(f"strategy {name!r} already registered; "
                         f"pass overwrite=True to replace it")
    if not isinstance(strategy, MapperStrategy):
        raise TypeError(f"strategy must be a core.mapper.MapperStrategy, "
                        f"got {type(strategy).__name__}")
    MAPPER_STRATEGIES[name] = strategy


def get_strategy(name: str) -> MapperStrategy:
    if name not in MAPPER_STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"registered: {sorted(MAPPER_STRATEGIES)}")
    return MAPPER_STRATEGIES[name]


def list_strategies() -> List[str]:
    return sorted(MAPPER_STRATEGIES)


register_strategy("adaptive", AdaptiveStrategy())
register_strategy("sa", SAStrategy())


def map_dfg(dfg: DFG, fabric: Fabric, ii_max: int = 48, seed: int = 0,
            strategy="adaptive", max_restarts: int = 8,
            label_fn=None, time_budget_s: Optional[float] = 90.0) -> MapResult:
    """Map a DFG onto a fabric, minimizing II (paper's main toolchain entry).

    ``strategy`` is a registered name (see ``list_strategies``) or a
    ``MapperStrategy`` instance.  Restart schedule: the full
    ``max_restarts`` attempts are spent at MII (where effort pays in II
    quality); higher IIs get fewer attempts, and once ``time_budget_s`` is
    exceeded each II gets a single attempt — bounding compile time the way
    a production scheduler must, at the cost of a possibly +1..2 II on
    pathological kernels.
    """
    t_start = time.perf_counter()
    strat = get_strategy(strategy) if isinstance(strategy, str) else strategy
    sname = strategy if isinstance(strategy, str) else strat.name
    mii = compute_mii(dfg, fabric)
    restarts_total = 0
    for II in range(mii, ii_max + 1):
        hist: Dict = {}
        if II == mii:
            attempts = max_restarts
        elif II <= mii + 2:
            attempts = max(2, max_restarts // 2)
        else:
            attempts = max(2, max_restarts // 4)
        if time_budget_s is not None and \
           time.perf_counter() - t_start > time_budget_s:
            attempts = 1
        for attempt in range(attempts):
            m = ModuloMapper(dfg, fabric, II, seed=seed * 1000 + attempt,
                             label_fn=label_fn)
            m.occ.hist = hist
            restarts_total += 1
            if strat.attempt(m):
                cfg = emit_config(dfg, fabric, II, m.placements, m.all_routes())
                sched = max(t for (_, t) in m.placements.values()) + 1
                return MapResult(True, II, mii, dict(m.placements), cfg,
                                 schedule_len=sched, restarts=restarts_total,
                                 wall_s=time.perf_counter() - t_start,
                                 strategy=sname)
            hist = strat.adapt(m)
    return MapResult(False, -1, mii, restarts=restarts_total,
                     wall_s=time.perf_counter() - t_start, strategy=sname)


# ---------------------------------------------------------------------------
# Spatial (Snafu-like) mapping model — paper Fig. 9 baseline
# ---------------------------------------------------------------------------

def spatial_ii(dfg: DFG, fabric: Fabric) -> Tuple[int, int]:
    """(II, n_subgraphs) for a spatial fabric.

    Each op statically owns a PE; if the DFG exceeds the array it is split
    into topologically contiguous subgraphs executed to completion one after
    another (paper §II), so the effective II is the sum of per-subgraph IIs.
    Model details (what makes spatial II >= spatio-temporal II in practice):

      * boundary values spill through the scratchpad — a STORE in the
        producer subgraph AND a LOAD in the consumer subgraph, both
        counted against the memory ports;
      * a recurrence cycle on a spatial fabric pays PE-to-PE routing for
        every edge (dependent ops sit on DISTINCT PEs; neighbor transfer
        is >= 1 cycle), so a k-op cycle bounds II by ~2k (compute + hop),
        vs the temporal mapper which can chain same-PE slots / use
        single-cycle multi-hop paths;
      * a recurrence crossing a subgraph split serializes iterations
        through the scratchpad (store + reload per iteration).
    """
    order = placement_order(dfg)
    cap = fabric.n_pes
    mem_cap = max(1, len(fabric.mem_pes))
    parts: List[List[int]] = []
    cur: List[int] = []
    cur_mem = 0
    for nid in order:
        is_mem = dfg.nodes[nid].op in MEM_OPS
        if len(cur) >= cap or (is_mem and cur_mem >= mem_cap):
            parts.append(cur)
            cur, cur_mem = [], 0
        cur.append(nid)
        cur_mem += int(is_mem)
    if cur:
        parts.append(cur)
    part_of = {nid: i for i, part in enumerate(parts) for nid in part}

    # per-part memory pressure: own mem ops + boundary stores + loads
    memops = [sum(1 for nid in part if dfg.nodes[nid].op in MEM_OPS)
              for part in parts]
    for n in dfg.nodes:
        for o in n.operands:
            if o.dist == 0 and part_of[o.src] != part_of[n.id]:
                memops[part_of[o.src]] += 1      # boundary store
                memops[part_of[n.id]] += 1       # boundary load

    # recurrence bounds with spatial routing latency
    rec_bound = [1] * len(parts)
    cross_penalty = 0
    for cyc in dfg.recurrence_cycles():
        k = len(cyc)
        owners = {part_of[nid] for nid in cyc}
        lat = k if k == 1 else 2 * k             # compute + neighbor hops
        if len(owners) == 1:
            p = owners.pop()
            rec_bound[p] = max(rec_bound[p], lat)
        else:
            # iteration serializes through the scratchpad across parts
            cross_penalty = max(cross_penalty, lat + 2)

    total = 0
    for i, part in enumerate(parts):
        ii_k = max(1, rec_bound[i],
                   math.ceil(memops[i] / max(1, fabric.n_mem_ports)))
        total += ii_k
    total = max(total, cross_penalty, rec_mii(dfg))
    return total, len(parts)

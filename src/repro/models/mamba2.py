"""Mamba-2 (SSD) mixer for the Zamba2 hybrid.

State-space dual form: scalar decay per head per token, chunked exactly
like the RWKV6 path (intra-chunk quadratic with non-positive exponents,
inter-chunk state scan).  Decode keeps an O(1) (conv, state) cache.

Recurrence (per head h, state S in R^{P x N}):
    S_t = a_t S_{t-1} + dt_t (x_t B_t^T)
    y_t = S_t C_t + D x_t
with a_t = exp(-dt_t * exp(A_log_h)).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


def _split_proj(z, cfg):
    """Split the fused input projection into (x, gate, B, C, dt)."""
    P = cfg.ssm_head_dim
    H = max(1, (2 * cfg.d_model) // P)
    d_in = H * P
    N = cfg.ssm_state
    x, gate, B, C, dt = jnp.split(
        z, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return x, gate, B, C, dt, H, P, N, d_in


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv1d.  x: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out), xp[:, -(K - 1):, :]


def ssd_chunked(x, dt, A_log, B, C, D, chunk: int = 64):
    """Chunked SSD.  x: (B, S, H, P); dt: (B, S, H); B/C: (B, S, N).

    Returns y: (B, S, H, P).
    """
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    xf = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.float32)
    dtf = jnp.pad(dt, ((0, 0), (0, pad), (0, 0))).astype(jnp.float32)
    Bf = jnp.pad(B, ((0, 0), (0, pad), (0, 0))).astype(jnp.float32)
    Cf = jnp.pad(C, ((0, 0), (0, pad), (0, 0))).astype(jnp.float32)
    la = -dtf * jnp.exp(A_log.astype(jnp.float32))[None, None, :]   # (B,S,H) <= 0
    xc = xf.reshape(Bsz, n, chunk, H, P)
    dtc = dtf.reshape(Bsz, n, chunk, H)
    Bc = Bf.reshape(Bsz, n, chunk, N)
    Cc = Cf.reshape(Bsz, n, chunk, N)
    lac = la.reshape(Bsz, n, chunk, H)

    def chunk_step(state, blk):                        # state: (B, H, P, N)
        xb, dtb, Bb, Cb, lab = blk
        cum = jnp.cumsum(lab, axis=1)                  # (B, L, H) inclusive
        # state contribution: y_t += exp(cum[t]) * S0 C_t
        y_state = jnp.einsum("bhpn,bln,blh->blhp",
                             state, Cb, jnp.exp(cum))
        # intra-chunk: y_t += sum_{i<=t} exp(cum[t]-cum[i]) dt_i (C_t.B_i) x_i
        L = xb.shape[1]
        expo = cum[:, :, None] - cum[:, None, :, :]    # (B, L, L, H), <=0 for i<=t
        tri = jnp.tril(jnp.ones((L, L), bool))
        g = jnp.where(tri[None, :, :, None], jnp.exp(
            jnp.where(tri[None, :, :, None], expo, 0.0)), 0.0)
        cb = jnp.einsum("bln,bin->bli", Cb, Bb)        # (B, L, L)
        w = g * cb[..., None] * dtb[:, None, :, :]     # (B, L, L, H)
        y_intra = jnp.einsum("blih,bihp->blhp", w, xb)
        # state update
        decay_all = jnp.exp(cum[:, -1])                # (B, H)
        k_dec = jnp.exp(cum[:, -1:, :] - cum) * dtb    # (B, L, H) <= 0 exponent
        state_new = state * decay_all[..., None, None] + jnp.einsum(
            "blh,blhp,bln->bhpn", k_dec, xb, Bb)
        return state_new, y_state + y_intra

    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    blks = tuple(jnp.moveaxis(z, 1, 0) for z in (xc, dtc, Bc, Cc, lac))
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step, prevent_cse=False),
                         init, blks)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, n * chunk, H, P)[:, :S]
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype)


def ssd_sequential(x, dt, A_log, B, C, D):
    """Sequential oracle for tests."""
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    a = jnp.exp(-dt.astype(jnp.float32)
                * jnp.exp(A_log.astype(jnp.float32))[None, None, :])

    def step(state, t):
        xt = x[:, t].astype(jnp.float32)
        St = state * a[:, t][..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t].astype(jnp.float32), xt, B[:, t].astype(jnp.float32))
        yt = jnp.einsum("bhpn,bn->bhp", St, C[:, t].astype(jnp.float32))
        return St, yt

    _, ys = jax.lax.scan(step, jnp.zeros((Bsz, H, P, N), jnp.float32),
                         jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)
    return (y + D.astype(jnp.float32)[None, None, :, None]
            * x.astype(jnp.float32)).astype(x.dtype)


def mamba2_layer(x, p, cfg, conv_state=None, ssm_state=None,
                 decode: bool = False):
    """Full Mamba2 block.  x: (B, S, d).  Returns (out, conv_state, ssm_state)."""
    B_, S, d = x.shape
    h = rms_norm(x, p["norm"])
    z = h @ p["w_in"]
    xin, gate, Bv, Cv, dt, H, P, N, d_in = _split_proj(z, cfg)
    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    xin, Bv, Cv = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    xh = xin.reshape(B_, S, H, P)
    if decode:
        a = jnp.exp(-dt[:, 0] * jnp.exp(p["A_log"])[None, :])
        new_state = ssm_state * a[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0].astype(jnp.float32),
            Bv[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", new_state,
                       Cv[:, 0].astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32)[None, :, None] \
            * xh[:, 0].astype(jnp.float32)
        y = y[:, None].astype(x.dtype)
    else:
        y = ssd_chunked(xh, dt, p["A_log"], Bv, Cv, p["D"])
        new_state = ssm_state
    y = y.reshape(B_, S, d_in)
    y = rms_norm(y, p["gate_norm"]) * jax.nn.silu(gate)
    return x + y @ p["w_out"], new_conv, new_state

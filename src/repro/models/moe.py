"""Mixture-of-Experts layers: DeepSeek-MoE (fine-grained, shared experts)
and Arctic (many-expert top-2 + dense residual).

Dispatch is capacity-based (tokens beyond an expert's capacity are dropped,
their residual passes through) using the sort-free cumsum formulation:
position-in-expert comes from a prefix sum of the routing one-hots, tokens
scatter into (E * C, d) buffers, experts run as one batched einsum, and
results gather back with the routing weights.  This formulation lowers to
dense einsums + one scatter/gather pair — predictable roofline terms and
clean expert-parallel sharding (experts sharded over the 'model' axis; the
scatter becomes the EP all-to-all).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import mlp
from repro.sharding.ctx import constrain


def router_topk(logits, k: int, renorm: bool = True):
    """Top-k routing weights.  logits: (T, E) float32."""
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, k)                    # (T, k)
    if renorm:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx


def aux_load_balance_loss(logits, idx, n_experts: int) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss."""
    gates = jax.nn.softmax(logits, axis=-1)
    me = gates.mean(0)                                   # mean gate per expert
    onehot = jax.nn.one_hot(idx[..., 0], n_experts, dtype=gates.dtype)
    ce = onehot.mean(0)                                  # fraction routed (top-1)
    return n_experts * jnp.sum(me * ce)


def moe_dispatch_combine(x, w_gate, w_up, w_down, router_w, *, top_k: int,
                         capacity_factor: float, act: str = "silu",
                         capacity: Optional[int] = None):
    """Capacity-based MoE layer over flattened tokens.

    x: (T, d); expert weights: (E, d, f)/(E, f, d); router_w: (d, E).
    Returns (out (T, d), aux_loss scalar).
    """
    T, d = x.shape
    E = w_gate.shape[0]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    weights, idx = router_topk(logits, top_k)            # (T, k)
    C = capacity or max(1, int(math.ceil(capacity_factor * top_k * T / E)))

    # position of each (token, slot) within its expert: prefix sum of one-hots
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)     # (T, k, E)
    flat_oh = onehot.reshape(T * top_k, E)
    pos = jnp.cumsum(flat_oh, axis=0) - flat_oh          # (T*k, E)
    pos_in_e = (pos * flat_oh).sum(-1).reshape(T, top_k)  # (T, k)
    keep = pos_in_e < C
    slot = idx * C + jnp.minimum(pos_in_e, C - 1)        # (T, k) in [0, E*C)

    # scatter tokens into expert buffers (dropped tokens contribute nothing)
    buf = jnp.zeros((E * C, d), x.dtype)
    upd = jnp.where(keep[..., None], x[:, None, :], 0).reshape(T * top_k, d)
    buf = buf.at[slot.reshape(-1)].add(upd.astype(x.dtype),
                                       mode="drop",
                                       indices_are_sorted=False)
    buf = constrain(buf.reshape(E, C, d), "expert_buf")   # EP all-to-all

    # batched expert MLP
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
        h = h * jnp.einsum("ecd,edf->ecf", buf, w_up)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, w_up))
    h = constrain(h, "expert_hidden")
    out_buf = constrain(jnp.einsum("ecf,efd->ecd", h, w_down),
                        "expert_buf").reshape(E * C, d)

    # gather back with routing weights
    gathered = out_buf[slot.reshape(-1)].reshape(T, top_k, d)
    wk = jnp.where(keep, weights, 0.0).astype(x.dtype)
    out = constrain(jnp.einsum("tk,tkd->td", wk, gathered), "tokens2d")
    aux = aux_load_balance_loss(logits, idx, E)
    return out, aux


def moe_dispatch_combine_grouped(x, w_gate, w_up, w_down, router_w, *,
                                 top_k: int, capacity_factor: float,
                                 groups: int, act: str = "silu"):
    """GShard-style locally-grouped dispatch (the EP all-to-all form).

    Tokens are split into ``groups`` (aligned with the DP shards via the
    ``expert_buf_g`` activation rule); the position-in-expert prefix sum is
    LOCAL to a group, so no cross-group order dependence exists and the
    group->expert buffer exchange lowers to an all-to-all over the data
    axis instead of full-buffer all-reduces.  Per-group capacity keeps the
    total capacity identical to the global formulation.
    """
    T, d = x.shape
    E = w_gate.shape[0]
    G = groups
    Tl = T // G
    xg = constrain(x.reshape(G, Tl, d), "moe_tokens_g")
    logits = jnp.einsum("gtd,de->gte",
                        xg.astype(jnp.float32), router_w.astype(jnp.float32))
    weights, idx = router_topk(logits.reshape(G * Tl, E), top_k)
    weights = weights.reshape(G, Tl, top_k)
    idx = idx.reshape(G, Tl, top_k)
    C = max(1, int(math.ceil(capacity_factor * top_k * Tl / E)))

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)      # (G, Tl, k, E)
    flat_oh = onehot.reshape(G, Tl * top_k, E)
    pos = jnp.cumsum(flat_oh, axis=1) - flat_oh           # local prefix sum
    pos_in_e = (pos * flat_oh).sum(-1).reshape(G, Tl, top_k)
    keep = pos_in_e < C
    slot = idx * C + jnp.minimum(pos_in_e, C - 1)         # (G, Tl, k)

    upd = jnp.where(keep[..., None], xg[:, :, None, :], 0) \
        .reshape(G, Tl * top_k, d).astype(x.dtype)

    def scatter_one(s, u):
        return jnp.zeros((E * C, d), x.dtype).at[s].add(
            u, mode="drop", indices_are_sorted=False)

    buf = jax.vmap(scatter_one)(slot.reshape(G, Tl * top_k), upd)
    buf = constrain(buf.reshape(G, E, C, d), "expert_buf_g")

    if act == "silu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w_gate))
        h = h * jnp.einsum("gecd,edf->gecf", buf, w_up)
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, w_up))
    h = constrain(h, "expert_hidden_g")
    out_buf = constrain(jnp.einsum("gecf,efd->gecd", h, w_down),
                        "expert_buf_g").reshape(G, E * C, d)

    gathered = jax.vmap(lambda b, s: b[s])(
        out_buf, slot.reshape(G, Tl * top_k)).reshape(G, Tl, top_k, d)
    # NOTE(§Perf, refuted): constraining `gathered` to a d-sharded layout
    # (P(dp, None, None, tp)) to turn the combine all-reduce into a
    # reduce-scatter was tried and REGRESSED t_collective 8.6s -> 10.3s on
    # deepseek-moe train_4k — XLA inserts extra reshards of out_buf around
    # the gather.  Kept on the default (all-reduce) path.
    wk = jnp.where(keep, weights, 0.0).astype(x.dtype)
    out = jnp.einsum("gtk,gtkd->gtd", wk, gathered)
    out = constrain(out, "moe_tokens_g").reshape(T, d)
    aux = aux_load_balance_loss(logits.reshape(G * Tl, E),
                                idx.reshape(G * Tl, top_k), E)
    return out, aux


def moe_block(x, p, cfg):
    """Full MoE sub-block for one layer (pre-sliced params).

    x: (B, S, d) -> (out, aux_loss)
    """
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    groups = getattr(cfg, "moe_groups", 1) or 1
    if groups > 1 and (B * S) % groups == 0:
        out, aux = moe_dispatch_combine_grouped(
            xf, p["we_gate"], p["we_up"], p["we_down"], p["router"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            groups=groups, act=cfg.mlp_act)
    else:
        out, aux = moe_dispatch_combine(
            xf, p["we_gate"], p["we_up"], p["we_down"],
            p["router"], top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, act=cfg.mlp_act)
    if cfg.n_shared_experts:
        h = jax.nn.silu(xf @ p["ws_gate"]) * (xf @ p["ws_up"])
        out = out + h @ p["ws_down"]
    if cfg.dense_residual:
        out = out + mlp(xf, p["dense"], None, cfg.mlp_act)
    return out.reshape(B, S, d), aux

"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full / sliding
window / prefix-LM), gated MLPs.

Attention is computed blockwise over the KV axis with an online-softmax
carry (a pure-JAX flash attention): memory stays O(seq * block) instead of
O(seq^2), every block step is rematerialized in the backward pass, and the
same blocking mirrors the Pallas kernel in `kernels/flash_attention` (the
TPU hot path; this jnp version is its oracle and the dry-run lowering).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.ctx import constrain

NEG_INF = -1e30


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding.  x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32)
                    / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mlp(x, p, layer_sel, act: str = "silu"):
    """Gated MLP; ``layer_sel`` indexes stacked weights (or None)."""
    w_up = p["w_up"] if layer_sel is None else p["w_up"][layer_sel]
    w_down = p["w_down"] if layer_sel is None else p["w_down"][layer_sel]
    up = x @ w_up
    if act == "silu":
        w_gate = p["w_gate"] if layer_sel is None else p["w_gate"][layer_sel]
        h = jax.nn.silu(x @ w_gate) * up
    else:
        h = jax.nn.gelu(up)
    return h @ w_down


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention, pure JAX
# ---------------------------------------------------------------------------

def _mask_block(q_pos, k_pos, causal: bool, window, prefix_len):
    """(Bq, Bk) boolean mask for one block pair.

    ``window`` may be a traced int32 (per-layer value under lax.scan); a
    huge value (GLOBAL) disables the sliding window without retracing.
    """
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        c = q_pos[:, None] >= k_pos[None, :]
        if prefix_len is not None:
            # prefix-LM: bidirectional over the first ``prefix_len`` tokens
            c = c | (k_pos[None, :] < prefix_len)
        m &= c
    m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def blockwise_attention(q, k, v, *, causal: bool = True, window=1 << 30,
                        prefix_len=None, q_offset=0, block_kv: int = 512,
                        softmax_scale: Optional[float] = None):
    """Online-softmax attention.

    q: (B, Sq, H, D); k/v: (B, Skv, KV, D) — GQA via head grouping.
    ``q_offset``: absolute position of q[0] (decode / chunked prefill).
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = softmax_scale or (1.0 / math.sqrt(D))
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, KV, G, D)
    block_kv = min(block_kv, Skv)
    n_blocks = max(1, (Skv + block_kv - 1) // block_kv)
    pad = n_blocks * block_kv - Skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = kp.reshape(B, n_blocks, block_kv, KV, D).astype(jnp.float32)
    vb = vp.reshape(B, n_blocks, block_kv, KV, D).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, blk):
        m_run, l_run, acc = carry
        k_blk, v_blk, blk_idx = blk
        k_pos = blk_idx * block_kv + jnp.arange(block_kv)
        # scores: (B, Sq, KV, G, block)
        s = jnp.einsum("bqkgd,bnkd->bqkgn", qf, k_blk)
        mask = _mask_block(q_pos, k_pos, causal, window, prefix_len)
        mask &= (k_pos < Skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgn,bnkd->bqkgd", p, v_blk)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32),
        jnp.zeros((B, Sq, KV, G), jnp.float32),
        jnp.zeros((B, Sq, KV, G, D), jnp.float32),
    )
    blks = (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
            jnp.arange(n_blocks))
    step_remat = jax.checkpoint(step, prevent_cse=False)
    (m_f, l_f, acc), _ = jax.lax.scan(step_remat, init, blks)
    out = acc / jnp.maximum(l_f[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def attention_block(x, p, layer_sel, cfg, positions, *, causal=True,
                    window=1 << 30, prefix_len=None, block_kv: int = 512):
    """Full attention sub-block: projections + RoPE (+qk-norm) + blockwise."""
    def sel(w):
        return w if layer_sel is None else w[layer_sel]
    B, S, d = x.shape
    H, KV, D = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = (x @ sel(p["wq"])).reshape(B, S, H, D)
    k = (x @ sel(p["wk"])).reshape(B, S, KV, D)
    v = (x @ sel(p["wv"])).reshape(B, S, KV, D)
    if getattr(cfg, "attn_head_shard", "auto") == "heads":
        q = constrain(q, "q_heads")
        k = constrain(k, "kv_heads")
        v = constrain(v, "kv_heads")
    if cfg.qk_norm:
        q = rms_norm(q, sel(p["q_norm"]))
        k = rms_norm(k, sel(p["k_norm"]))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = blockwise_attention(q, k, v, causal=causal, window=window,
                            prefix_len=prefix_len, block_kv=block_kv)
    return o.reshape(B, S, H * D) @ sel(p["wo"])


def decode_attention(q, k_cache, v_cache, cache_len, *, window=1 << 30):
    """Single-token decode over a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, Smax, KV, D); ``cache_len``: current length
    (the new token is already written at cache_len-1).
    """
    B, _, H, D = q.shape
    _, Smax, KV, _ = k_cache.shape
    G = H // KV
    qf = (q.astype(jnp.float32) / math.sqrt(D)).reshape(B, KV, G, D)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kf)          # (B, KV, G, Smax)
    pos = jnp.arange(Smax)
    clen = jnp.reshape(jnp.asarray(cache_len), (-1, 1))  # (1,1) or (B,1)
    valid = pos[None, :] < clen                          # (B?, Smax)
    valid &= pos[None, :] >= (clen - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)

"""Model configuration + parameter initialization for the architecture zoo.

One ``ModelConfig`` covers all ten assigned architectures; ``family``
selects the block structure.  Parameters are plain pytrees (nested dicts of
jnp arrays) with per-layer weights stacked on a leading axis so the forward
pass is a ``lax.scan`` over layers — HLO stays O(1) in depth, which keeps
the 512-device dry-run compiles tractable and is how production JAX LM
frameworks (MaxText et al.) are built.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | rwkv6 | zamba2 | hubert | paligemma
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0              # 0 -> = n_heads
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention flavor
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = full attention
    global_every: int = 0            # gemma3: every Nth layer global (0 = all)
    causal: bool = True
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    dense_residual: bool = False     # arctic: dense FFN alongside experts
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    shared_attn_every: int = 0       # zamba2: shared attention period
    # modality frontend (stub supplies embeddings)
    frontend: str = "none"           # none | audio | image
    n_prefix_tokens: int = 0         # paligemma image tokens
    # numerics
    dtype: Any = jnp.bfloat16
    mlp_act: str = "silu"            # silu | gelu
    tie_embeddings: bool = True
    # distribution strategy (see repro.sharding.specs):
    #   tp2d — FSDP(data) x TP(model), Megatron column->row pairs (default)
    #   fsdp — pure ZeRO-3: params/optimizer/batch sharded over the combined
    #          (data, model) axes, no tensor parallelism.  Beyond-paper §Perf
    #          lever: removes per-layer activation all-reduces at the price
    #          of per-layer parameter all-gathers.
    shard_strategy: str = "tp2d"
    #   auto   — let XLA place gradient reductions (baseline)
    #   pinned — with_sharding_constraint grads to the param shardings so
    #            FSDP reductions lower to reduce-scatter (§Perf lever)
    grad_reduce: str = "auto"
    # KV block size of the pure-JAX blockwise attention (0 = one full block;
    # §Perf lever: the scan carry costs HBM round-trips per block on the
    # XLA path, while the Pallas kernel keeps it in VMEM)
    attn_block_kv: int = 512
    # MoE dispatch groups (GShard-style local groups).  1 = single global
    # dispatch with a global prefix-sum (baseline).  Set to the DP degree so
    # each data shard dispatches into its own capacity slice and the
    # cross-shard exchange lowers to the EP all-to-all instead of
    # full-buffer all-reduces (§Perf lever).
    moe_groups: int = 1
    # attention TP layout: "auto" (baseline: weights sharded on the flat
    # H*D dim; XLA may split head_dim across devices and pay pairwise
    # score reductions) | "heads" (constrain q/k/v to whole-head sharding;
    # KV heads replicate when kv_heads % tp != 0 — §Perf lever for GQA)
    attn_head_shard: str = "auto"

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (MODEL_FLOPS uses these) ------------------------
    def param_count(self) -> int:
        c = self
        d, hd = c.d_model, c.hd
        emb = c.vocab * d
        per_layer = 0
        if c.family in ("dense", "moe", "hubert", "paligemma"):
            attn = d * hd * (c.n_heads + 2 * c.kv_heads) + c.n_heads * hd * d
            per_layer += attn + 2 * d                      # + norms
            if c.family == "moe":
                eff = c.expert_d_ff or c.d_ff
                per_layer += 3 * d * eff * (c.n_experts + c.n_shared_experts)
                per_layer += d * c.n_experts               # router
                if c.dense_residual:
                    per_layer += 3 * d * c.d_ff
            else:
                n_mats = 3 if c.mlp_act == "silu" else 2
                per_layer += n_mats * d * c.d_ff
        elif c.family == "rwkv6":
            per_layer = 6 * d * d + 3 * d * c.d_ff + 4 * d
        elif c.family == "zamba2":
            d_in = 2 * d
            per_layer = (d * (2 * d_in + 2 * c.ssm_state) + d_in * d
                         + 4 * d)                           # mamba2 mixer approx
        n = emb + c.n_layers * per_layer
        if c.family == "zamba2" and c.shared_attn_every:
            attn = d * hd * (c.n_heads + 2 * c.kv_heads) + c.n_heads * hd * d
            n += attn + 3 * d * c.d_ff
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        c = self
        d = c.d_model
        eff = c.expert_d_ff or c.d_ff
        total = self.param_count()
        inactive = 3 * d * eff * (c.n_experts - c.top_k) * c.n_layers
        return total - inactive


# ---------------------------------------------------------------------------
# Initializers (all stacked over layers on axis 0)
# ---------------------------------------------------------------------------

def _dense(key, shape, scale=None, dtype=jnp.float32):
    scale = scale or (1.0 / math.sqrt(shape[-2] if len(shape) > 1 else shape[-1]))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_attention(key, c: ModelConfig, n_layers: int, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    d, hd, H, KV = c.d_model, c.hd, c.n_heads, c.kv_heads
    p = {
        "wq": _dense(ks[0], (n_layers, d, H * hd), dtype=dtype),
        "wk": _dense(ks[1], (n_layers, d, KV * hd), dtype=dtype),
        "wv": _dense(ks[2], (n_layers, d, KV * hd), dtype=dtype),
        "wo": _dense(ks[3], (n_layers, H * hd, d), dtype=dtype),
    }
    if c.qk_norm:
        p["q_norm"] = jnp.ones((n_layers, hd), dtype)
        p["k_norm"] = jnp.ones((n_layers, hd), dtype)
    return p


def init_mlp(key, d_in, d_ff, n_layers, act, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": _dense(ks[0], (n_layers, d_in, d_ff), dtype=dtype),
        "w_down": _dense(ks[1], (n_layers, d_ff, d_in), dtype=dtype),
    }
    if act == "silu":
        p["w_gate"] = _dense(ks[2], (n_layers, d_in, d_ff), dtype=dtype)
    return p


def init_moe(key, c: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 8)
    d, E, L = c.d_model, c.n_experts, c.n_layers
    eff = c.expert_d_ff or c.d_ff
    p = {
        "router": _dense(ks[0], (L, d, E), scale=0.02, dtype=jnp.float32),
        "we_gate": _dense(ks[1], (L, E, d, eff), dtype=dtype),
        "we_up": _dense(ks[2], (L, E, d, eff), dtype=dtype),
        "we_down": _dense(ks[3], (L, E, eff, d), dtype=dtype),
    }
    if c.n_shared_experts:
        S = c.n_shared_experts
        p["ws_gate"] = _dense(ks[4], (L, d, S * eff), dtype=dtype)
        p["ws_up"] = _dense(ks[5], (L, d, S * eff), dtype=dtype)
        p["ws_down"] = _dense(ks[6], (L, S * eff, d), dtype=dtype)
    if c.dense_residual:
        p["dense"] = init_mlp(ks[7], d, c.d_ff, L, c.mlp_act, dtype)
    return p


def init_rwkv6(key, c: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 12)
    d, L = c.d_model, c.n_layers
    H = c.n_heads
    hd = d // H
    p = {
        "mix": _dense(ks[0], (L, 5, d), scale=0.5, dtype=dtype),   # token-shift mixes r,k,v,w,g
        "wr": _dense(ks[1], (L, d, d), dtype=dtype),
        "wk": _dense(ks[2], (L, d, d), dtype=dtype),
        "wv": _dense(ks[3], (L, d, d), dtype=dtype),
        "wg": _dense(ks[4], (L, d, d), dtype=dtype),
        "ww": _dense(ks[5], (L, d, d), scale=0.01, dtype=dtype),   # data-dependent decay
        "w_bias": jnp.full((L, d), -5.0, dtype),
        "u": _dense(ks[6], (L, d), scale=0.5, dtype=dtype),        # bonus
        "wo": _dense(ks[7], (L, d, d), dtype=dtype),
        "ln_x": jnp.ones((L, d), dtype),
        "ffn_k": _dense(ks[8], (L, d, c.d_ff), dtype=dtype),
        "ffn_v": _dense(ks[9], (L, c.d_ff, d), dtype=dtype),
        "ffn_r": _dense(ks[10], (L, d, d), dtype=dtype),
        "norm1": jnp.ones((L, d), dtype),
        "norm2": jnp.ones((L, d), dtype),
    }
    return p


def init_mamba2(key, c: ModelConfig, n_layers: int, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    d, N = c.d_model, c.ssm_state
    P = c.ssm_head_dim
    H = max(1, (2 * d) // P)          # expand factor 2
    d_in = H * P
    p = {
        "w_in": _dense(ks[0], (n_layers, d, 2 * d_in + 2 * N + H), dtype=dtype),
        "conv_w": _dense(ks[1], (n_layers, c.ssm_conv, d_in + 2 * N),
                         scale=0.5, dtype=dtype),
        "A_log": jnp.zeros((n_layers, H), jnp.float32),
        "D": jnp.ones((n_layers, H), dtype),
        "dt_bias": jnp.zeros((n_layers, H), jnp.float32),
        "w_out": _dense(ks[2], (n_layers, d_in, d), dtype=dtype),
        "norm": jnp.ones((n_layers, d), dtype),
        "gate_norm": jnp.ones((n_layers, d_in), dtype),
    }
    return p


def init_params(key, c: ModelConfig) -> Dict:
    """Full parameter pytree for any family."""
    dtype = c.dtype
    ks = jax.random.split(key, 10)
    d, L = c.d_model, c.n_layers
    params: Dict[str, Any] = {
        "embed": _dense(ks[0], (c.vocab, d), scale=0.02, dtype=dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not c.tie_embeddings:
        params["lm_head"] = _dense(ks[9], (d, c.vocab), dtype=dtype)
    if c.family in ("dense", "hubert", "paligemma"):
        params["attn"] = init_attention(ks[1], c, L, dtype)
        params["mlp"] = init_mlp(ks[2], d, c.d_ff, L, c.mlp_act, dtype)
        params["norm1"] = jnp.ones((L, d), dtype)
        params["norm2"] = jnp.ones((L, d), dtype)
    elif c.family == "moe":
        params["attn"] = init_attention(ks[1], c, L, dtype)
        params["moe"] = init_moe(ks[2], c, dtype)
        params["norm1"] = jnp.ones((L, d), dtype)
        params["norm2"] = jnp.ones((L, d), dtype)
    elif c.family == "rwkv6":
        params["rwkv"] = init_rwkv6(ks[1], c, dtype)
    elif c.family == "zamba2":
        params["mamba"] = init_mamba2(ks[1], c, L, dtype)
        shared = ModelConfig(name="shared", family="dense", n_layers=1,
                             d_model=d, n_heads=c.n_heads, d_ff=c.d_ff,
                             vocab=1, n_kv_heads=c.n_kv_heads,
                             dtype=c.dtype)
        params["shared_attn"] = init_attention(ks[2], shared, 1, dtype)
        params["shared_mlp"] = init_mlp(ks[3], d, c.d_ff, 1, c.mlp_act, dtype)
        params["shared_norm1"] = jnp.ones((1, d), dtype)
        params["shared_norm2"] = jnp.ones((1, d), dtype)
    else:
        raise ValueError(f"unknown family {c.family}")
    if c.frontend == "audio":
        params["frontend_proj"] = _dense(ks[4], (c.d_model, c.d_model),
                                         dtype=dtype)
        params["mask_embed"] = _dense(ks[5], (d,), scale=0.02, dtype=dtype)
    if c.frontend == "image":
        params["img_proj"] = _dense(ks[4], (c.d_model, c.d_model), dtype=dtype)
    return params


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))

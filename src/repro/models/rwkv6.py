"""RWKV-6 "Finch": linear attention with data-dependent per-channel decay.

Training uses a chunked formulation (the jnp oracle of the
`kernels/rwkv6` Pallas kernel): within a chunk the per-channel decay
exponents are all non-positive, so every exp() is numerically safe; the
inter-chunk state is carried through a `lax.scan`.  Decode is the O(1)
sequential recurrence — the reason the 500k-context cell is feasible for
this family at all.

Recurrence (per head, state S in R^{K x V}):
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(ww x_t + b)) in (0, 1) data-dependent.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm

LOG_W_MIN = -8.0     # clamp per-token log-decay for numerical safety


def _proj_rkvwg(x, x_prev, p):
    """Token-shift mixes + five projections.  x: (B, S, d)."""
    def sel(w):
        return w
    mix = jax.nn.sigmoid(sel(p["mix"]))                   # (5, d)
    xs = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    def mixed(i):
        return x * mix[i] + xs * (1.0 - mix[i])
    r = mixed(0) @ sel(p["wr"])
    k = mixed(1) @ sel(p["wk"])
    v = mixed(2) @ sel(p["wv"])
    lw = mixed(3) @ sel(p["ww"]) + sel(p["w_bias"])
    g = jax.nn.silu(mixed(4) @ sel(p["wg"]))
    log_w = -jnp.exp(lw.astype(jnp.float32))              # <= 0
    log_w = jnp.maximum(log_w, LOG_W_MIN)
    return r, k, v, log_w, g


def wkv6_chunked(r, k, v, log_w, u, chunk: int = 32):
    """Chunked WKV6.  r,k,v,log_w: (B, S, H, K); u: (H, K).

    Returns (B, S, H, K) outputs (head value dim == K here).
    """
    B, S, H, K = r.shape
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    def padc(x):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rc = padc(r).reshape(B, n, chunk, H, K).astype(jnp.float32)
    kc = padc(k).reshape(B, n, chunk, H, K).astype(jnp.float32)
    vc = padc(v).reshape(B, n, chunk, H, K).astype(jnp.float32)
    lw = padc(log_w).reshape(B, n, chunk, H, K)

    def chunk_step(state, blk):
        rb, kb, vb, lwb = blk                             # (B, L, H, K)
        cum = jnp.cumsum(lwb, axis=1)                     # inclusive
        cum_ex = cum - lwb                                # exclusive
        # state contribution: r'_t = r_t * exp(cum_ex[t])  (exponent <= 0)
        r_dec = rb * jnp.exp(cum_ex)
        o_state = jnp.einsum("blhk,bhkv->blhv", r_dec, state)
        # intra-chunk: A[t,i] = sum_d r[t,d] k[i,d] exp(cum_ex[t,d]-cum[i,d])
        expo = cum_ex[:, :, None] - cum[:, None, :, :, :]  # (B, L, L, H, K) <=0 for i<t
        L = rb.shape[1]
        tri = jnp.tril(jnp.ones((L, L), bool), -1)
        expo = jnp.where(tri[None, :, :, None, None], expo, -jnp.inf)
        a = jnp.einsum("bthk,bihk,btihk->btih", rb, kb, jnp.exp(expo))
        # diagonal bonus term
        diag = jnp.einsum("bthk,hk,bthk->bth", rb, u.astype(jnp.float32), kb)
        o_intra = jnp.einsum("btih,bihv->bthv", a, vb)
        o_diag = diag[..., None] * vb
        # state update: S' = diag(exp(cum[-1])) S + sum_i exp(cum[-1]-cum[i]) k_i v_i^T
        decay_all = jnp.exp(cum[:, -1])                   # (B, H, K)
        k_dec = kb * jnp.exp(cum[:, -1:, :, :] - cum)     # exponent <= 0
        state_new = state * decay_all[..., None] + jnp.einsum(
            "bihk,bihv->bhkv", k_dec, vb)
        return state_new, o_state + o_intra + o_diag

    init = jnp.zeros((B, H, K, K), jnp.float32)
    blks = tuple(jnp.moveaxis(x, 1, 0) for x in (rc, kc, vc, lw))
    _, outs = jax.lax.scan(jax.checkpoint(chunk_step, prevent_cse=False),
                           init, blks)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n * chunk, H, K)
    return out[:, :S].astype(r.dtype)


def rwkv6_layer(x, x_prev_tmix, x_prev_cmix, p, cfg):
    """One RWKV6 block: time mix + channel mix.  x: (B, S, d)."""
    B, S, d = x.shape
    H = cfg.n_heads
    K = d // H
    h = rms_norm(x, p["norm1"])
    r, k, v, log_w, g = _proj_rkvwg(h, x_prev_tmix, p)
    rr = r.reshape(B, S, H, K)
    kk = k.reshape(B, S, H, K)
    vv = v.reshape(B, S, H, K)
    ww = log_w.reshape(B, S, H, K)
    u = p["u"].reshape(H, K)
    o = wkv6_chunked(rr, kk, vv, ww, u).reshape(B, S, d)
    o = rms_norm(o, p["ln_x"]) * g
    x = x + o @ p["wo"]
    # channel mix (rwkv ffn): square-relu with receptance gate
    h2 = rms_norm(x, p["norm2"])
    h2s = jnp.concatenate([x_prev_cmix[:, None, :], h2[:, :-1, :]], axis=1)
    kk2 = jnp.square(jax.nn.relu(h2 @ p["ffn_k"]))
    rr2 = jax.nn.sigmoid(h2s @ p["ffn_r"])
    x = x + rr2 * (kk2 @ p["ffn_v"])
    return x, h[:, -1, :], h2[:, -1, :]


def rwkv6_decode_step(x, tmix_state, cmix_state, wkv_state, p, cfg):
    """One-token decode.  x: (B, d); wkv_state: (B, H, K, K)."""
    B, d = x.shape
    H = cfg.n_heads
    K = d // H
    h = rms_norm(x, p["norm1"])
    r, k, v, log_w, g = _proj_rkvwg(h[:, None, :], tmix_state, p)
    rr = r.reshape(B, H, K).astype(jnp.float32)
    kk = k.reshape(B, H, K).astype(jnp.float32)
    vv = v.reshape(B, H, K).astype(jnp.float32)
    w = jnp.exp(log_w.reshape(B, H, K))
    u = p["u"].reshape(H, K).astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kk, vv)
    o = jnp.einsum("bhk,bhkv->bhv", rr, wkv_state + u[None, :, :, None] * kv)
    wkv_state = wkv_state * w[..., None] + kv
    o = o.reshape(B, 1, d).astype(x.dtype)
    o = rms_norm(o, p["ln_x"]) * g
    x = x + (o @ p["wo"])[:, 0]
    h2 = rms_norm(x, p["norm2"])
    kk2 = jnp.square(jax.nn.relu(h2 @ p["ffn_k"]))
    rr2 = jax.nn.sigmoid(cmix_state @ p["ffn_r"])
    x = x + rr2 * (kk2 @ p["ffn_v"])
    return x, h, h2, wkv_state


def wkv6_sequential(r, k, v, log_w, u):
    """Sequential oracle for tests (token-by-token recurrence)."""
    B, S, H, K = r.shape
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    w = jnp.exp(log_w.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(state, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], w[:, t]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt,
                       state + uf[None, :, :, None] * kv)
        return state * wt[..., None] + kv, o

    _, outs = jax.lax.scan(step, jnp.zeros((B, H, K, K), jnp.float32),
                           jnp.arange(S))
    return jnp.moveaxis(outs, 0, 1)

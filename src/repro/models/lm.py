"""Unified model assembly: forward pass, loss, and decode for every family.

All families share the same skeleton: embed -> lax.scan over layer stacks ->
final RMSNorm -> (tied) logits.  Per-layer parameters are stacked pytrees
that the scan slices, so the lowered HLO is depth-independent.  Sharding
constraints are injected by `repro.sharding.partition` (the functions here
are sharding-agnostic and runnable on one CPU device for smoke tests).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import (attention_block, decode_attention, mlp,
                                 rms_norm, rope)
from repro.models.mamba2 import mamba2_layer
from repro.models.moe import moe_block
from repro.models.rwkv6 import rwkv6_decode_step, rwkv6_layer
from repro.sharding.ctx import constrain

GLOBAL_WINDOW = 1 << 30      # "window" that never masks anything


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (gemma3 local:global / SWA / full)."""
    L = cfg.n_layers
    if cfg.global_every:
        w = [cfg.sliding_window if (i + 1) % cfg.global_every else
             GLOBAL_WINDOW for i in range(L)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * L
    else:
        w = [GLOBAL_WINDOW] * L
    return jnp.array(w, jnp.int32)


def _embed(params, cfg, tokens):
    h = params["embed"][tokens].astype(cfg.dtype) * (cfg.d_model ** 0.5)
    return constrain(h, "hidden")


def _logits(params, cfg, h):
    h = rms_norm(h, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return constrain(h @ head.astype(h.dtype), "logits")


# ---------------------------------------------------------------------------
# Forward passes (training / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens=None, *, features=None,
            feat_mask=None, img_embeds=None, block_kv: int = 0):
    """Returns (logits (B,S,V), aux_loss scalar)."""
    block_kv = block_kv or getattr(cfg, "attn_block_kv", 512) or (1 << 30)
    if cfg.family in ("dense", "moe", "paligemma"):
        return _forward_transformer(params, cfg, tokens,
                                    img_embeds=img_embeds, block_kv=block_kv)
    if cfg.family == "hubert":
        return _forward_hubert(params, cfg, features, feat_mask, block_kv)
    if cfg.family == "rwkv6":
        return _forward_rwkv6(params, cfg, tokens)
    if cfg.family == "zamba2":
        return _forward_zamba2(params, cfg, tokens, block_kv)
    raise ValueError(cfg.family)


def _forward_transformer(params, cfg, tokens, img_embeds=None,
                         block_kv: int = 512):
    B, S = tokens.shape
    h = _embed(params, cfg, tokens)
    prefix_len = None
    if cfg.family == "paligemma" and img_embeds is not None:
        img = img_embeds.astype(cfg.dtype) @ params["img_proj"]
        h = jnp.concatenate([img, h], axis=1)
        prefix_len = img_embeds.shape[1]
    positions = jnp.arange(h.shape[1])[None, :]
    windows = layer_windows(cfg)
    stacked = {"attn": params["attn"], "norm1": params["norm1"],
               "norm2": params["norm2"]}
    stacked["ffn"] = params["moe"] if cfg.family == "moe" else params["mlp"]

    def block(carry, xs):
        h, aux = carry
        lp, win = xs
        a = attention_block(rms_norm(h, lp["norm1"]), lp["attn"], None, cfg,
                            positions, causal=cfg.causal, window=win,
                            prefix_len=prefix_len, block_kv=block_kv)
        h = h + a
        hn = rms_norm(h, lp["norm2"])
        if cfg.family == "moe":
            f, a_loss = moe_block(hn, lp["ffn"], cfg)
            aux = aux + a_loss
        else:
            f = mlp(hn, lp["ffn"], None, cfg.mlp_act)
        return (constrain(h + f, "hidden"), aux), None

    block = jax.checkpoint(block, prevent_cse=False)
    (h, aux), _ = jax.lax.scan(block, (h, jnp.zeros((), jnp.float32)),
                               (stacked, windows))
    logits = _logits(params, cfg, h)
    if prefix_len is not None:
        logits = logits[:, prefix_len:]
    return logits, aux / cfg.n_layers


def _forward_hubert(params, cfg, features, feat_mask, block_kv):
    """Encoder over (masked) frame features; predicts codebook targets."""
    B, S, d = features.shape
    h = constrain(features.astype(cfg.dtype) @ params["frontend_proj"],
                  "hidden")
    if feat_mask is not None:
        h = jnp.where(feat_mask[..., None],
                      params["mask_embed"].astype(cfg.dtype)[None, None, :], h)
    positions = jnp.arange(S)[None, :]
    stacked = {"attn": params["attn"], "norm1": params["norm1"],
               "norm2": params["norm2"], "ffn": params["mlp"]}

    def block(h, lp):
        a = attention_block(rms_norm(h, lp["norm1"]), lp["attn"], None, cfg,
                            positions, causal=False, window=GLOBAL_WINDOW,
                            block_kv=block_kv)
        h = h + a
        f = mlp(rms_norm(h, lp["norm2"]), lp["ffn"], None, cfg.mlp_act)
        return constrain(h + f, "hidden"), None

    block = jax.checkpoint(block, prevent_cse=False)
    h, _ = jax.lax.scan(block, h, stacked)
    return _logits(params, cfg, h), jnp.zeros((), jnp.float32)


def _forward_rwkv6(params, cfg, tokens):
    B, S = tokens.shape
    h = _embed(params, cfg, tokens)
    zeros = jnp.zeros((B, cfg.d_model), cfg.dtype)

    def block(h, lp):
        h, _, _ = rwkv6_layer(h, zeros, zeros, lp, cfg)
        return constrain(h, "hidden"), None

    block = jax.checkpoint(block, prevent_cse=False)
    h, _ = jax.lax.scan(block, h, params["rwkv"])
    return _logits(params, cfg, h), jnp.zeros((), jnp.float32)


def _forward_zamba2(params, cfg, tokens, block_kv: int = 512):
    """Mamba2 backbone with a shared attention block every k layers."""
    B, S = tokens.shape
    h = _embed(params, cfg, tokens)
    k = cfg.shared_attn_every or cfg.n_layers
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(
        lambda w: w.reshape(n_groups, k, *w.shape[1:]), params["mamba"])
    positions = jnp.arange(S)[None, :]

    def shared_block(h):
        a = attention_block(rms_norm(h, params["shared_norm1"][0]),
                            jax.tree.map(lambda w: w[0], params["shared_attn"]),
                            None, cfg, positions, causal=True,
                            window=GLOBAL_WINDOW, block_kv=block_kv)
        h = h + a
        f = mlp(rms_norm(h, params["shared_norm2"][0]),
                jax.tree.map(lambda w: w[0], params["shared_mlp"]),
                None, cfg.mlp_act)
        return h + f

    def group(h, gp):
        def inner(h, lp):
            h, _, _ = mamba2_layer(h, lp, cfg)
            return constrain(h, "hidden"), None
        h, _ = jax.lax.scan(jax.checkpoint(inner, prevent_cse=False), h, gp)
        return constrain(shared_block(h), "hidden"), None

    h, _ = jax.lax.scan(group, h, grouped)
    return _logits(params, cfg, h), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, cfg: ModelConfig, batch: Dict[str, Any],
            aux_weight: float = 0.01, z_weight: float = 1e-4):
    """Next-token (or masked-prediction) loss; returns (loss, metrics)."""
    if cfg.family == "hubert":
        logits, aux = forward(params, cfg, features=batch["features"],
                              feat_mask=batch["mask"])
        targets, mask = batch["targets"], batch["mask"]
    else:
        tokens = batch["tokens"]
        inp, targets = tokens[:, :-1], tokens[:, 1:]
        mask = batch.get("loss_mask")
        mask = jnp.ones_like(targets, bool) if mask is None else mask[:, 1:]
        logits, aux = forward(params, cfg, inp,
                              img_embeds=batch.get("img_embeds"))
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = nll.sum() / denom
    zloss = z_weight * (jnp.square(logz) * mask).sum() / denom
    total = loss + zloss + aux_weight * aux
    return total, {"loss": loss, "zloss": zloss, "aux": aux,
                   "tokens": denom}


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    L, KV, D = cfg.n_layers, cfg.kv_heads, cfg.hd
    if cfg.family in ("dense", "moe", "paligemma"):
        return {
            "k": jnp.zeros((L, batch, max_len, KV, D), cfg.dtype),
            "v": jnp.zeros((L, batch, max_len, KV, D), cfg.dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "rwkv6":
        d = cfg.d_model
        H, K = cfg.n_heads, cfg.d_model // cfg.n_heads
        return {
            "wkv": jnp.zeros((L, batch, H, K, K), jnp.float32),
            "tmix": jnp.zeros((L, batch, d), cfg.dtype),
            "cmix": jnp.zeros((L, batch, d), cfg.dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "zamba2":
        P = cfg.ssm_head_dim
        H = max(1, (2 * cfg.d_model) // P)
        N = cfg.ssm_state
        d_in = H * P
        G = cfg.n_layers // (cfg.shared_attn_every or cfg.n_layers)
        return {
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, d_in + 2 * N),
                              cfg.dtype),
            "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
            "k": jnp.zeros((G, batch, max_len, KV, D), cfg.dtype),
            "v": jnp.zeros((G, batch, max_len, KV, D), cfg.dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    raise ValueError(f"no decode cache for {cfg.family} (encoder-only?)")


def decode_step(params, cfg: ModelConfig, cache, token):
    """One decode step.  token: (B, 1) int32 -> (logits (B,1,V), cache)."""
    if cfg.family in ("dense", "moe", "paligemma"):
        return _decode_transformer(params, cfg, cache, token)
    if cfg.family == "rwkv6":
        return _decode_rwkv6(params, cfg, cache, token)
    if cfg.family == "zamba2":
        return _decode_zamba2(params, cfg, cache, token)
    raise ValueError(cfg.family)


def _decode_transformer(params, cfg, cache, token):
    B = token.shape[0]
    h = _embed(params, cfg, token)                       # (B, 1, d)
    pos = cache["len"]
    positions = pos[None, None]
    windows = layer_windows(cfg)
    H, KV, D = cfg.n_heads, cfg.kv_heads, cfg.hd
    stacked = {"attn": params["attn"], "norm1": params["norm1"],
               "norm2": params["norm2"],
               "ffn": params["moe"] if cfg.family == "moe" else params["mlp"]}

    def block(h, xs):
        lp, win, kc, vc = xs
        x = rms_norm(h, lp["norm1"])
        p = lp["attn"]
        q = (x @ p["wq"]).reshape(B, 1, H, D)
        k = (x @ p["wk"]).reshape(B, 1, KV, D)
        v = (x @ p["wv"]).reshape(B, 1, KV, D)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
            k = rms_norm(k, p["k_norm"])
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        o = decode_attention(q, kc, vc, pos + 1, window=win)
        h = h + o.reshape(B, 1, H * D) @ p["wo"]
        hn = rms_norm(h, lp["norm2"])
        if cfg.family == "moe":
            f, _ = moe_block(hn, lp["ffn"], cfg)
        else:
            f = mlp(hn, lp["ffn"], None, cfg.mlp_act)
        return h + f, (kc, vc)

    h, (kc, vc) = jax.lax.scan(block, h,
                               (stacked, windows, cache["k"], cache["v"]))
    cache = dict(cache, k=kc, v=vc, len=pos + 1)
    return _logits(params, cfg, h), cache


def _decode_rwkv6(params, cfg, cache, token):
    B = token.shape[0]
    h = _embed(params, cfg, token)[:, 0]                 # (B, d)

    def block(h, xs):
        lp, tmix, cmix, wkv = xs
        h, tmix2, cmix2, wkv2 = rwkv6_decode_step(h, tmix, cmix, wkv, lp, cfg)
        return h, (tmix2, cmix2, wkv2)

    h, (tmix, cmix, wkv) = jax.lax.scan(
        block, h, (params["rwkv"], cache["tmix"], cache["cmix"], cache["wkv"]))
    cache = dict(cache, tmix=tmix, cmix=cmix, wkv=wkv, len=cache["len"] + 1)
    return _logits(params, cfg, h[:, None, :]), cache


def _decode_zamba2(params, cfg, cache, token):
    B = token.shape[0]
    h = _embed(params, cfg, token)                       # (B, 1, d)
    pos = cache["len"]
    k_per = cfg.shared_attn_every or cfg.n_layers
    G = cfg.n_layers // k_per
    H, KV, D = cfg.n_heads, cfg.kv_heads, cfg.hd
    grouped = jax.tree.map(
        lambda w: w.reshape(G, k_per, *w.shape[1:]), params["mamba"])
    conv_g = cache["conv"].reshape(G, k_per, *cache["conv"].shape[1:])
    ssm_g = cache["ssm"].reshape(G, k_per, *cache["ssm"].shape[1:])
    positions = pos[None, None]

    def group(h, xs):
        gp, convs, ssms, kc, vc = xs

        def inner(h, ys):
            lp, conv, ssm = ys
            h, conv2, ssm2 = mamba2_layer(h, lp, cfg, conv_state=conv,
                                          ssm_state=ssm, decode=True)
            return h, (conv2, ssm2)

        h, (convs2, ssms2) = jax.lax.scan(inner, h, (gp, convs, ssms))
        # shared attention block over the cache
        p = jax.tree.map(lambda w: w[0], params["shared_attn"])
        x = rms_norm(h, params["shared_norm1"][0])
        q = (x @ p["wq"]).reshape(B, 1, H, D)
        k = (x @ p["wk"]).reshape(B, 1, KV, D)
        v = (x @ p["wv"]).reshape(B, 1, KV, D)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        o = decode_attention(q, kc, vc, pos + 1, window=GLOBAL_WINDOW)
        h = h + o.reshape(B, 1, H * D) @ p["wo"]
        f = mlp(rms_norm(h, params["shared_norm2"][0]),
                jax.tree.map(lambda w: w[0], params["shared_mlp"]),
                None, cfg.mlp_act)
        return h + f, (convs2, ssms2, kc, vc)

    h, (convs, ssms, kc, vc) = jax.lax.scan(
        group, h, (grouped, conv_g, ssm_g, cache["k"], cache["v"]))
    cache = dict(cache,
                 conv=convs.reshape(cache["conv"].shape),
                 ssm=ssms.reshape(cache["ssm"].shape),
                 k=kc, v=vc, len=pos + 1)
    return _logits(params, cfg, h), cache

"""End-to-end training example: ~100M-param dense LM, few hundred steps.

Uses the same driver a production run would (`repro.launch.train`), with a
--scale override that instantiates a ~100M-param Qwen3-family config on
this host's mesh, checkpointing + fault-tolerant supervisor enabled.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import json
import tempfile

from repro.launch.train import main as train_main

SCALE_100M = {
    "n_layers": 12, "d_model": 768, "n_heads": 12, "n_kv_heads": 4,
    "d_ff": 3072, "vocab": 16384, "head_dim": 64,
}
# ~104M backbone + 12.6M tied embedding ≈ 1.1e8 params.  A few hundred
# steps takes tens of minutes on the CPU container; pass --steps/--batch
# to shrink.  (CI smoke uses the driver directly with --smoke instead.)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    out = train_main([
        "--arch", "qwen3-8b",
        "--scale", json.dumps(SCALE_100M),
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--ckpt-dir", ckpt,
        "--ckpt-every", "50",
        "--log-every", "10",
    ])
    assert out["last_loss"] < out["first_loss"], \
        f"loss did not improve: {out['first_loss']} -> {out['last_loss']}"
    print(f"loss improved {out['first_loss']:.3f} -> {out['last_loss']:.3f}; "
          f"checkpoints in {ckpt}")

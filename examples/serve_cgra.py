"""Serving example: mixed-tenant CGRA traffic through ``ual.Service``.

Two tenants — a GEMM app and an FFT app — fire single-sample requests at
one shared service.  The service coalesces each tenant's stream into
micro-batches (requests only batch with compatible ones: same program
digest, target digest, backend, trip count), executes every micro-batch
as ONE ``run_batch`` sweep on a shared warm Executable, and answers
through Future-style responses.  Each tenant pays its mapping once; the
platform owns the batching.

    PYTHONPATH=src python examples/serve_cgra.py
"""
import json

import numpy as np

from repro import ual
from repro.core.dfg import interpret

REQUESTS_PER_TENANT = 48

target = ual.Target.from_name("hycube", rows=4, cols=4)
tenants = {
    "gemm-app": ual.Program.from_kernel("gemm",
                                        n_banks=target.fabric.n_mem_ports),
    "fft-app": ual.Program.from_kernel("fft",
                                       n_banks=target.fabric.n_mem_ports),
}

rng = np.random.default_rng(0)
with ual.Service(max_batch=16, max_wait_ms=5, max_queue=256) as svc:
    # interleave the two tenants' traffic, like real arrival order would
    inflight = []
    for i in range(REQUESTS_PER_TENANT):
        for tenant, program in tenants.items():
            mem = program.random_inputs(rng)
            resp = svc.submit(program, target, mem, tenant=tenant)
            inflight.append((tenant, program, mem, resp))

    # gather; spot-check one response per tenant against the oracle
    checked = set()
    for tenant, program, mem, resp in inflight:
        out = resp.result(timeout=300)
        if tenant not in checked:
            expect = interpret(program.dfg, mem, program.n_iters)
            for name in program.outputs:
                np.testing.assert_array_equal(out[name], expect[name])
            checked.add(tenant)
            print(f"{tenant}: first response bit-exact vs oracle "
                  f"(micro-batch of {resp.info['batch']}, "
                  f"{resp.info['latency_ms']}ms)")

    stats = svc.stats()

print("\nservice.stats():")
print(json.dumps(stats, indent=2, default=str))

assert stats["completed"] == 2 * REQUESTS_PER_TENANT
assert stats["mean_batch"] > 1, "coalescer never batched anything"
assert set(stats["tenants"]) == set(tenants)
print(f"\nserved {stats['completed']} requests in micro-batches of "
      f"{stats['mean_batch']} mean / {stats['max_batch']} max at "
      f"{stats['samples_per_s']} samples/s — serve_cgra example OK")

"""Serving example: batched greedy decoding across model families.

Runs the continuous-batching serve driver for a dense, an MoE, and a
recurrent (RWKV6) architecture — the same `decode_step` path the
decode_32k/long_500k dry-run cells lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

for arch in ("qwen3-8b", "deepseek-moe-16b", "rwkv6-1.6b"):
    print(f"\n--- serving {arch} (smoke config) ---")
    out = serve_main(["--arch", arch, "--smoke",
                      "--requests", "4", "--max-new", "8"])
    assert out["tokens"].shape == (4, 8)
print("\nserve example OK")

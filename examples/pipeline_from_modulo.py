"""Pipeline parallelism from the paper's modulo-scheduling framework.

A software-pipelined CGRA loop and a pipeline-parallel training step are
the same reservation-table object (DESIGN.md §2): stages = FUs,
microbatches = loop iterations, II = injection interval.  This example
derives GPipe / 1F1B / interleaved-1F1B schedules for a 27B-scale config
split over 8 stages, verifies every dependence edge, and compares bubble
fractions against the closed-form (RecMII-style) bound.

    PYTHONPATH=src python examples/pipeline_from_modulo.py
"""
from repro.core.pipeline_schedule import (bubble_model, gpipe,
                                          interleaved_1f1b, one_f_one_b)

S = 8            # pipeline stages (e.g. gemma3-27b's 62 layers over 8 devices)
M = 32           # microbatches per step

print(f"pipeline: {S} stages x {M} microbatches "
      f"(analytic bubble bound {bubble_model(S, M):.3f})\n")
rows = []
for sched in (gpipe(S, M), one_f_one_b(S, M),
              interleaved_1f1b(S, M, n_chunks=2),
              interleaved_1f1b(S, M, n_chunks=4)):
    sched.verify()                      # replay + check every dependence
    rows.append((sched.name, sched.total_ticks, sched.bubble_fraction(),
                 sched.peak_in_flight()))
    print(f"{sched.name:22s} ticks={sched.total_ticks:4d} "
          f"bubble={sched.bubble_fraction():.3f} "
          f"peak-activations={sched.peak_in_flight()}")

gp, fb = rows[0], rows[1]
il2, il4 = rows[2], rows[3]
assert fb[3] <= gp[3], "1F1B must cap activation memory vs GPipe"
assert il4[2] <= il2[2] <= gp[2] + 1e-9, \
    "interleaving must shrink the bubble"
print("\nall schedules verified; 1F1B caps memory, interleaving cuts bubble")

"""LISA-lite end-to-end: train a placement-bias model with the repo's own
optimizer, plug it into the mapper's label_fn hook, evaluate on held-out
kernels (paper §III-D: learned methods swap into the architecture-adaptive
mapper without toolchain changes).

    PYTHONPATH=src python examples/learned_mapper.py
"""
from repro.core.adl import hycube
from repro.core.dfg import apply_layout, plan_layout
from repro.core.kernel_lib import KERNELS
from repro.core.lisa import collect_dataset, make_label_fn, train
from repro.core.mapper import map_dfg

TRAIN_SET = ("gemm", "fft", "dct")
EVAL_SET = ("nw", "adpcm", "jax_poly")

fab = hycube(4, 4)


def laid_out(name):
    dfg, _, n = KERNELS[name]()
    return apply_layout(dfg, plan_layout(dfg)), n


print("collecting training mappings...")
train_kernels = [laid_out(n) for n in TRAIN_SET]
feats, labels, pf = collect_dataset(train_kernels, fab)
print(f"dataset: {len(labels)} (node -> PE) pairs from {TRAIN_SET}")

params, losses = train(feats, labels, pf, steps=300)
print(f"train loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert losses[-1] < losses[0], "training must reduce loss"

label_mem = make_label_fn(params, fab, mem_only=True)
label_all = make_label_fn(params, fab, mem_only=False)
print(f"\n{'kernel':10s} {'II':>4s} {'II mem-bias':>12s} {'II all-bias':>12s}"
      f" {'restarts':>9s} {'r mem-bias':>11s}")
for name in EVAL_SET:
    dfg, _ = laid_out(name)
    base = map_dfg(dfg, fab, seed=3)
    mem = map_dfg(dfg, fab, seed=3, label_fn=label_mem(dfg))
    allb = map_dfg(dfg, fab, seed=3, label_fn=label_all(dfg))
    print(f"{name:10s} {base.II:4d} {mem.II:12d} {allb.II:12d} "
          f"{base.restarts:9d} {mem.restarts:11d}")
    assert mem.success and base.success
    assert mem.II <= base.II, "mem-only learned bias must not wreck II"
print("\nlearned-mapper hook OK: mem-node labels transfer (II parity); "
      "absolute compute-node labels mislead on unseen kernels — the\n"
      "measured reason LISA uses relative GNN labels (see core/lisa.py).")

"""REVAMP-style design-space exploration over ADL fabric variants.

The paper positions Morpher as the substrate for DSE (§III-D: REVAMP
instantiates heterogeneous CGRA configurations through the ADL).  This
example sweeps a fabric design space — array size × hop budget × memory
ports — crossed with both mapper strategies, through the parallel
``ual.explore()`` front-end: every unique design point is modulo-mapped
exactly once (cache-aware dedup), cold points fan out over a process
pool, and each point is priced with the PACE-calibrated energy model.

    PYTHONPATH=src python examples/design_space_exploration.py
"""
from repro import ual
from repro.core.adl import hycube

KERNEL = "gemm"
DIMS = ((4, 4), (4, 8))
HOPS = (1, 2, 4)
PORTS = (2, 4)


def fabric_variant(rows, cols, hops, ports):
    fab = hycube(rows, cols, max_hops=hops)
    fab.name = f"hycube_{rows}x{cols}_h{hops}_p{ports}"
    fab.n_mem_ports = ports
    return fab


fabrics = [fabric_variant(r, c, h, p)
           for (r, c) in DIMS for h in HOPS for p in PORTS]
program = ual.Program.from_kernel(KERNEL)
report = ual.explore(program, {
    "fabric": fabrics,
    "strategy": ["adaptive", "sa"],
}, workers=4)

print(report.render())
assert report.pareto, "no feasible design points"

# the paper's qualitative claim holds in the swept space: HyCUBE's
# single-cycle multi-hop interconnect never loses to 1-hop routing
by_variant = {}
for p in report.points:
    if p.success and p.strategy == "adaptive":
        rows_cols, hops, ports = p.fabric.rsplit("_", 2)
        by_variant.setdefault((rows_cols, ports), {})[hops] = p.II
for key, by_hop in by_variant.items():
    if "h1" in by_hop and "h4" in by_hop:
        assert by_hop["h4"] <= by_hop["h1"], \
            f"4-hop should not be slower than 1-hop at {key}"

print(f"\n{len(report.pareto)} Pareto-optimal design(s) out of "
      f"{len(report.points)}; {report.n_mapped} mappings paid; multi-hop "
      "dominates 1-hop at every (size, ports) point — the HyCUBE design "
      "choice.")

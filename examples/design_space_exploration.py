"""REVAMP-style design-space exploration over ADL fabric variants.

The paper positions Morpher as the substrate for DSE (§III-D: REVAMP
instantiates heterogeneous CGRA configurations through the ADL).  This
example sweeps a small fabric design space — array size × hop budget ×
memory ports — maps a kernel mix onto every variant, prices each with the
PACE-calibrated energy model, and prints the (mean II, energy/iter)
Pareto frontier.

    PYTHONPATH=src python examples/design_space_exploration.py
"""
import itertools

from repro.core.adl import hycube
from repro.core.dfg import apply_layout, plan_layout
from repro.core.energy import kernel_energy
from repro.core.kernel_lib import KERNELS
from repro.core.mapper import map_dfg

KERNEL_MIX = ("gemm", "nw", "fft")
SPACE = {
    "dims": ((4, 4), (4, 8)),
    "max_hops": (1, 2, 4),
    "n_mem_ports": (2, 4),
}

rows = []
for (r, c), hops, ports in itertools.product(*SPACE.values()):
    fab = hycube(r, c, max_hops=hops)
    fab.n_mem_ports = ports
    iis, energies = [], []
    ok = True
    for name in KERNEL_MIX:
        dfg, _, n_iters = KERNELS[name]()
        laid = apply_layout(dfg, plan_layout(dfg, n_banks=ports))
        res = map_dfg(laid, fab, seed=0, max_restarts=4, time_budget_s=30)
        if not res.success:
            ok = False
            break
        iis.append(res.II)
        energies.append(kernel_energy(res.config, n_iters)["total"] / n_iters)
    if not ok:
        continue
    mean_ii = sum(iis) / len(iis)
    mean_e = sum(energies) / len(energies)
    rows.append(((r, c), hops, ports, mean_ii, mean_e))

rows.sort(key=lambda x: (x[3], x[4]))
pareto = []
best_e = float("inf")
for row in rows:
    if row[4] < best_e:
        pareto.append(row)
        best_e = row[4]

print(f"{'fabric':>8s} {'hops':>5s} {'ports':>6s} {'mean II':>8s} "
      f"{'pJ/iter':>9s}  pareto")
pset = {id(p) for p in pareto}
for row in rows:
    (r, c), hops, ports, mii, me = row
    mark = "*" if id(row) in pset else ""
    print(f"{r}x{c:>6} {hops:5d} {ports:6d} {mii:8.2f} {me:9.0f}  {mark}")

assert pareto, "no feasible design points"
# the paper's qualitative claims hold in the swept space:
hop_effect = {}
for row in rows:
    hop_effect.setdefault((row[0], row[2]), {})[row[1]] = row[3]
for key, by_hop in hop_effect.items():
    if 1 in by_hop and 4 in by_hop:
        assert by_hop[4] <= by_hop[1] + 1e-9, \
            f"4-hop should not be slower than 1-hop at {key}"
print(f"\n{len(pareto)} Pareto-optimal design(s); multi-hop dominates "
      "1-hop at every (size, ports) point — the HyCUBE design choice.")

"""Arch-applicability bridge: extract a model micro-kernel's DFG from its
jaxpr and map it onto the PACE 8x8 fabric.

The paper's toolchain compiles *annotated kernels*; our frontend can also
trace pure JAX scalar kernels (Morpher's LLVM-DFG analogue).  Here we take
integer micro-kernels representative of the assigned LM architectures —
a quantized GQA score accumulation, an RWKV6-style decayed accumulate, and
an int8 MoE router argmax step — trace them to DFGs, map at multiple hop
budgets, and report II + estimated energy on the PACE model (edge
inference offload study).

    PYTHONPATH=src python examples/offload_to_pace.py
"""
import numpy as np

from repro import ual
from repro.core.dfg import DFGBuilder, trace_into
from repro.core.energy import kernel_energy
from repro.core.kernel_lib import N_ITERS


def qk_score():
    """Quantized attention score: acc += (q*k) >> 7, 4-way unrolled."""
    b = DFGBuilder("qk_score")
    K = 4 * N_ITERS
    b.array("q", K)
    b.array("k", K)
    b.array("s", 1, output=True)
    i = b.counter(0, 4)
    acc = b.recur(0)
    parts = []
    for u in range(4):
        idx = b.op("ADD", i, const=u)
        parts.append(b.op("SHR", b.op("MUL", b.load("q", idx),
                                      b.load("k", idx)), 7))
    s = b.op("ADD", b.op("ADD", parts[0], parts[1]),
             b.op("ADD", parts[2], parts[3]))
    acc2 = b.op("ADD", acc, s)
    b.bind(acc, acc2)
    b.store("s", 0, acc2)
    def rng(r):
        return {"q": r.integers(-64, 64, K).astype(np.int32),
                "k": r.integers(-64, 64, K).astype(np.int32)}
    return b.build(), rng, N_ITERS


def rwkv_decay():
    """RWKV-style fixed-point decayed state: s = (s*w)>>8 + k*v."""
    b = DFGBuilder("rwkv_decay")
    N = N_ITERS
    b.array("k", N)
    b.array("v", N)
    b.array("w", N)
    b.array("o", N, output=True)
    i = b.counter()
    s = b.recur(0)
    kv = b.op("MUL", b.load("k", i), b.load("v", i))
    s2 = b.op("ADD", b.op("SHR", b.op("MUL", s, b.load("w", i)), 8), kv)
    b.bind(s, s2)
    b.store("o", i, s2)
    def rng(r):
        return {"k": r.integers(-16, 16, N).astype(np.int32),
                "v": r.integers(-16, 16, N).astype(np.int32),
                "w": r.integers(0, 256, N).astype(np.int32)}
    return b.build(), rng, N_ITERS


def router_argmax():
    """MoE router: running top-1 over expert logits (traced from JAX)."""
    b = DFGBuilder("router_argmax")
    N = N_ITERS
    b.array("logit", N)
    b.array("best", 1, output=True)
    b.array("beste", 1, output=True)
    i = b.counter()
    best = b.recur(init=-(1 << 20))
    beste = b.recur(init=0)
    x = b.load("logit", i)

    def f(x, best, beste, i):
        import jax.numpy as jnp
        better = x > best
        return (jnp.where(better, x, best), jnp.where(better, i, beste))

    nb, ne = trace_into(b, f, [x, best, beste, i])
    b.bind(best, nb)
    b.bind(beste, ne)
    b.store("best", 0, nb)
    b.store("beste", 0, ne)
    def rng(r):
        return {"logit": r.integers(-512, 512, N).astype(np.int32)}
    return b.build(), rng, N_ITERS


target = ual.Target.from_name("pace", backend="sim")
fab = target.fabric
print(f"fabric: {fab.name} ({fab.n_pes} PEs, {fab.datapath_bits}-bit, "
      f"{fab.clusters} clusters)\n")
for make in (qk_score, rwkv_decay, router_argmax):
    dfg, mk, n_iters = make()
    program = ual.Program.from_dfg(dfg, n_iters, make_mem=mk,
                                   n_banks=fab.n_mem_ports)
    exe = ual.compile(program, target)
    rep = exe.validate()
    assert rep.passed, f"{dfg.name} failed validation"
    e = kernel_energy(exe.map_result.config, n_iters)
    print(f"{dfg.name:14s} II={exe.II} "
          f"(MII={exe.map_result.mii})  validated={rep.passed}  "
          f"E/op={e['per_op']:.1f} pJ  E/iter={e['total'] / n_iters:.0f} pJ")
print("\noffload study OK (per-op energy in the ~290 pJ/op ballpark of the "
      "HyCUBE test chip)")

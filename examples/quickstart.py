"""Quickstart: the paper's toolchain end-to-end in ~60 lines.

Describe a CGRA in the ADL, write a kernel against the DFG builder DSL,
map it with the modulo-scheduling mapper, execute the resulting bitstream
on (a) the cycle-accurate simulator and (b) the Pallas TPU kernel, and
validate both against the DFG interpreter oracle — the Morpher flow of
paper Fig. 2.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.adl import hycube, n2n
from repro.core.dfg import (DFGBuilder, apply_layout, flat_memory, interpret,
                            plan_layout, unflatten_memory)
from repro.core.mapper import map_dfg
from repro.core.simulator import simulate
from repro.kernels.cgra_exec.ops import cgra_exec_op

# -- 1. a loop kernel in the builder DSL (annotated-C analogue) --------------
#    out[i] = clamp(a[i] * b[i] >> 4, -128, 127) + running_sum
b = DFGBuilder("quickstart")
N = 16
b.array("a", N)
b.array("b", N)
b.array("out", N, output=True)
i = b.counter()                      # loop induction variable
acc = b.recur(init=0)                # loop-carried running sum
prod = b.op("SHR", b.op("MUL", b.load("a", i), b.load("b", i)), 4)
clamped = b.op("MAX", b.op("MIN", prod, 127), -128)
total = b.op("ADD", acc, clamped)
b.bind(acc, total)                   # close the recurrence
b.store("out", i, total)
dfg = b.build()
print(f"DFG: {len(dfg.nodes)} nodes, {dfg.n_mem_ops} memory ops, "
      f"{len(dfg.recurrence_cycles())} recurrence cycle(s)")

# -- 2. plan the scratchpad layout and map onto two fabrics -------------------
layout = plan_layout(dfg)
laid = apply_layout(dfg, layout)
for fabric in (hycube(4, 4, max_hops=4), n2n(4, 4)):
    res = map_dfg(laid, fabric)
    print(f"{fabric.name}: II={res.II} (MII={res.mii}) "
          f"util={res.fu_util:.2f} mapped in {res.wall_s:.2f}s")

# -- 3. execute + validate (simulator AND Pallas kernel vs oracle) ------------
fabric = hycube(4, 4)
res = map_dfg(laid, fabric)
rng = np.random.default_rng(0)
mem = {"a": rng.integers(-100, 100, N).astype(np.int32),
       "b": rng.integers(-100, 100, N).astype(np.int32)}
expect = interpret(dfg, mem, N)                     # oracle

flat = flat_memory(layout, mem)
sim_out, stats = simulate(res.config, flat, N)
got_sim = unflatten_memory(layout, sim_out, dfg.arrays)

pallas_out = cgra_exec_op(res.config, flat[None], N)[0]
got_pl = unflatten_memory(layout, pallas_out, dfg.arrays)

ok_sim = bool((got_sim["out"] == expect["out"]).all())
ok_pl = bool((got_pl["out"] == expect["out"]).all())
print(f"cycle-accurate simulator matches oracle: {ok_sim} "
      f"(PE activity {stats.pe_activity:.2f})")
print(f"Pallas cgra_exec kernel matches oracle:  {ok_pl}")
assert ok_sim and ok_pl
print("quickstart OK")

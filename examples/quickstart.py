"""Quickstart: the paper's toolchain through the unified abstraction layer.

The UAL vocabulary (``repro.ual``) is four nouns:

  * ``Program`` — a kernel DFG + planned scratchpad layout + named I/O
    spec, built from the ``DFGBuilder`` DSL (or a kernel_lib entry, or a
    traced JAX function),
  * ``Target``  — a fabric from the registry (hycube/n2n/pace/spatial)
    plus mapper strategy and a backend name,
  * ``compile(program, target)`` — the modulo-scheduling mapper, memoized
    on content hashes so recompiling an identical pair is near-free,
  * ``Executable`` — dict-in/dict-out ``run()`` on any backend
    (``interp`` oracle / ``sim`` cycle-accurate / ``pallas`` TPU kernel)
    and ``validate()`` against the oracle.

The full flow below is five UAL calls:
``Program.from_builder`` -> ``Target.from_name`` -> ``compile`` ->
``run`` -> ``validate``.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import ual
from repro.core.dfg import DFGBuilder

# -- a loop kernel in the builder DSL (annotated-C analogue) ------------------
#    out[i] = clamp(a[i] * b[i] >> 4, -128, 127) + running_sum
b = DFGBuilder("quickstart")
N = 16
b.array("a", N)
b.array("b", N)
b.array("out", N, output=True)
i = b.counter()                      # loop induction variable
acc = b.recur(init=0)                # loop-carried running sum
prod = b.op("SHR", b.op("MUL", b.load("a", i), b.load("b", i)), 4)
clamped = b.op("MAX", b.op("MIN", prod, 127), -128)
total = b.op("ADD", acc, clamped)
b.bind(acc, total)                   # close the recurrence
b.store("out", i, total)

# -- the UAL flow: Program -> Target -> compile -> run -> validate ------------
program = ual.Program.from_builder(b, n_iters=N)                        # 1
target = ual.Target.from_name("hycube", rows=4, cols=4, max_hops=4)     # 2
exe = ual.compile(program, target)                                      # 3
print(f"DFG: {len(program.dfg.nodes)} nodes, {program.dfg.n_mem_ops} "
      f"memory ops, {len(program.dfg.recurrence_cycles())} recurrence "
      f"cycle(s)")
print(f"{target.fabric.name}: II={exe.II} (MII={exe.map_result.mii}) "
      f"util={exe.map_result.fu_util:.2f} "
      f"compiled in {exe.compile_info.wall_s:.2f}s "
      f"({'cache hit' if exe.compile_info.cache_hit else 'cold'})")

rng = np.random.default_rng(0)
mem = {"a": rng.integers(-100, 100, N).astype(np.int32),
       "b": rng.integers(-100, 100, N).astype(np.int32)}
got = exe.run(**mem)                                                    # 4
print(f"out[:4] = {got['out'][:4]}")

# oracle / cycle-accurate sim / Pallas cgra_exec, bit-exact on random vectors
report = exe.validate(seed=0, backends=("sim", "pallas"))               # 5
print(f"cycle-accurate simulator matches oracle: "
      f"{report.backend_results['sim']} "
      f"(PE activity {report.sim_stats.pe_activity:.2f})")
print(f"Pallas cgra_exec kernel matches oracle:  "
      f"{report.backend_results['pallas']}")
assert report.passed
print("quickstart OK")

"""Flight-recorder telemetry: tracing, the metrics registry, and the
observability surfaces threaded through the serving stack.

Contract under test:

  * context-manager spans nest (child inherits trace id, parents under
    the enclosing span) per-thread — two threads never parent under each
    other's open spans,
  * the buffer is a bounded flight recorder: capacity holds, eviction is
    oldest-first, and ``stats()`` counts every recorded/dropped span,
  * a DISABLED tracer is a strict no-op: ``span()`` hands back one
    shared singleton (no ``Span`` allocation, no clock read, nothing
    recorded) and traced producers skip all capture work,
  * the Chrome-trace export is schema-valid, carries one metadata event
    per track, and ``ingest`` re-bases foreign-process spans onto the
    local timebase with one pid lane per worker prefix,
  * the registry is get-or-create by dotted name (kind mismatch is a
    ``TypeError``), namespaces are unique per producer instance and
    ``drop()`` removes them, sources sample at snapshot time and a dead
    source cannot poison the view,
  * ``ServiceMetrics`` keeps its historical ``snapshot()`` shape on top
    of registry instruments, attributes batch errors per tenant, and
    survives empty/reject-only/stream-only windows,
  * ``merge_latency`` computes real cluster percentiles from shipped
    sample windows (falling back to max-of-workers without them),
  * a traced ``Service.submit`` yields a complete span tree whose
    per-stage breakdown accounts for the reported request latency.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs, ual
from repro.obs import trace as trace_mod
from repro.ual.cluster.service import merge_latency
from repro.ual.service.metrics import ServiceMetrics


@pytest.fixture
def fresh_obs():
    """Swap in a fresh enabled tracer + empty registry; restore after."""
    tr = obs.Tracer(enabled=True)
    reg = obs.MetricsRegistry()
    prev_tr = obs.set_tracer(tr)
    prev_reg = obs.set_registry(reg)
    yield tr, reg
    obs.set_tracer(prev_tr)
    obs.set_registry(prev_reg)


def _program(kname="gemm"):
    return ual.Program.from_kernel(kname)


def _target(**knobs):
    return ual.Target.from_name("hycube", rows=4, cols=4, **knobs)


# ---------------------------------------------------------------------------
# tracer core: nesting, ids, ring buffer
# ---------------------------------------------------------------------------

def test_nested_spans_share_trace_and_parent():
    tr = obs.Tracer(enabled=True)
    with tr.span("outer") as outer:
        with tr.span("inner") as inner:
            assert inner.trace_id == outer.trace_id
            assert inner.parent_id == outer.span_id
    spans = {s.name: s for s in tr.spans()}
    assert set(spans) == {"outer", "inner"}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    # inner closed first, so it records first but ends inside the outer
    assert spans["inner"].t0 >= spans["outer"].t0
    assert spans["inner"].span_id != spans["outer"].span_id


def test_span_nesting_is_per_thread():
    tr = obs.Tracer(enabled=True)
    entered = threading.Event()
    release = threading.Event()

    def other():
        with tr.span("thread-b"):
            entered.set()
            release.wait(timeout=30)

    t = threading.Thread(target=other)
    with tr.span("thread-a") as a:
        t.start()
        assert entered.wait(timeout=30)
        # thread-b's open span must not become a child of thread-a's
        release.set()
        t.join(timeout=30)
    spans = {s.name: s for s in tr.spans()}
    assert spans["thread-b"].parent_id is None
    assert spans["thread-b"].trace_id != a.trace_id
    assert spans["thread-a"].track != spans["thread-b"].track


def test_record_retrospective_spans_and_explicit_parentage():
    tr = obs.Tracer(enabled=True)
    root = tr.record("root", 1.0, 2.0, trace="t1")
    child = tr.record("child", 1.25, 1.5, trace="t1", parent=root,
                      args={"k": "v"})
    spans = {s.span_id: s for s in tr.spans()}
    assert spans[child].parent_id == root
    assert spans[child].trace_id == "t1"
    assert spans[child].args == {"k": "v"}
    assert spans[root].dur_s == pytest.approx(1.0)
    # negative intervals clamp rather than exporting negative durations
    weird = tr.record("clock-skew", 5.0, 4.0, trace="t1")
    assert spans_by_id(tr)[weird].dur_s == 0.0


def spans_by_id(tr):
    return {s.span_id: s for s in tr.spans()}


def test_ring_buffer_bounds_memory_and_counts_drops():
    tr = obs.Tracer(enabled=True, capacity=8)
    for i in range(20):
        tr.record(f"s{i}", float(i), float(i) + 0.5, trace="t")
    st = tr.stats()
    assert st["buffered"] == 8
    assert st["recorded"] == 20
    assert st["dropped"] == 12
    # oldest-first snapshot of the survivors: the last 8 recorded
    assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(12, 20)]
    tr.clear()
    assert tr.stats() == {"enabled": True, "capacity": 8, "buffered": 0,
                          "recorded": 0, "dropped": 0}


def test_drain_empties_the_buffer_exactly_once():
    tr = obs.Tracer(enabled=True)
    tr.record("a", 0.0, 1.0, trace="t")
    first = tr.drain()
    assert [s.name for s in first] == ["a"]
    assert tr.drain() == []
    assert tr.spans() == []


# ---------------------------------------------------------------------------
# disabled tracer: strict no-op
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_singleton_with_no_capture(monkeypatch):
    tr = obs.Tracer(enabled=False)
    allocs = []
    monkeypatch.setattr(trace_mod, "Span",
                        lambda *a, **k: allocs.append(1))
    s1 = tr.span("x", args={"big": list(range(100))})
    s2 = tr.span("y")
    assert s1 is s2                       # the shared null singleton
    with s1 as s:
        s.set(ignored=True)
    assert allocs == []                   # no Span ever constructed
    assert tr.spans() == [] and tr.stats()["recorded"] == 0


def test_disabled_service_attaches_no_trace_info(fresh_obs):
    tr, _reg = fresh_obs
    tr.disable()
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(0))
    with ual.Service(max_batch=4, max_wait_ms=2) as svc:
        fut = svc.submit(program, target, mem)
        fut.result(timeout=300)
    assert "trace" not in fut.info
    assert tr.spans() == []


# ---------------------------------------------------------------------------
# export: chrome schema, tracks, cross-process ingest
# ---------------------------------------------------------------------------

def test_export_chrome_is_schema_valid_and_loadable(tmp_path):
    tr = obs.Tracer(enabled=True)
    with tr.span("outer", cat="test", args={"n": 3}):
        with tr.span("inner"):
            pass
    out = tr.export_chrome(tmp_path / "t.json")
    doc = json.loads(out.read_text())
    assert obs.validate_chrome(doc) == []
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"outer", "inner"}
    assert {e["name"] for e in metas} == {"process_name", "thread_name"}
    outer = next(e for e in xs if e["name"] == "outer")
    inner = next(e for e in xs if e["name"] == "inner")
    assert inner["args"]["parent_id"] == outer["args"]["span_id"]
    assert outer["args"]["n"] == 3
    assert all(isinstance(e["ts"], (int, float)) and e["ts"] >= 0
               for e in xs)


def test_validate_chrome_flags_malformed_docs():
    assert obs.validate_chrome({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [{"ph": "Q"}, {"ph": "X", "name": "a"},
                           "not-an-object"]}
    problems = obs.validate_chrome(bad)
    assert any("unexpected ph" in p for p in problems)
    assert any("missing" in p for p in problems)
    assert any("not an object" in p for p in problems)


def test_ingest_rebases_foreign_epoch_and_prefixes_tracks():
    local = obs.Tracer(enabled=True)
    foreign = obs.Tracer(enabled=True)
    foreign.epoch = local.epoch + 5.0     # foreign clock started 5s "later"
    foreign.record("remote-span", 100.0, 101.0, trace="t", track="engine-0")
    n = local.ingest(foreign.drain(), epoch=foreign.epoch,
                     track_prefix="worker3")
    assert n == 1
    got = local.spans()[0]
    assert got.t0 == pytest.approx(105.0)
    assert got.track == "worker3/engine-0"
    # the prefixed track becomes its own pid lane in the chrome doc
    with local.span("local-span"):
        pass
    doc = local.to_chrome()
    pids = {e["args"]["name"]: e["pid"]
            for e in doc["traceEvents"]
            if e["ph"] == "M" and e["name"] == "process_name"}
    assert set(pids) == {"worker3", "proc"}
    assert pids["worker3"] != pids["proc"]


def test_tree_renders_one_request_hierarchy():
    tr = obs.Tracer(enabled=True)
    root = tr.record("request", 0.0, 1.0, trace="tX")
    tr.record("queue", 0.0, 0.4, trace="tX", parent=root)
    tr.record("exec", 0.4, 0.9, trace="tX", parent=root)
    roots = tr.tree("tX")
    assert len(roots) == 1 and roots[0]["name"] == "request"
    assert [c["name"] for c in roots[0]["children"]] == ["queue", "exec"]
    text = obs.Tracer.render_tree(roots)
    assert "request" in text and "  queue" in text


# ---------------------------------------------------------------------------
# metrics: instruments, registry, namespaces, sources
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    assert obs.percentile([], 99) is None
    assert obs.percentile([7.0], 50) == 7.0
    xs = list(range(1, 101))              # 1..100
    assert obs.percentile(xs, 0) == 1
    assert obs.percentile(xs, 50) == 51   # nearest-rank on n-1 intervals
    assert obs.percentile(xs, 100) == 100


def test_registry_get_or_create_and_kind_mismatch():
    reg = obs.MetricsRegistry()
    c = reg.counter("a.b")
    assert reg.counter("a.b") is c
    with pytest.raises(TypeError):
        reg.gauge("a.b")
    h = reg.histogram("a.h", window=4)
    for v in (1, 2, 3, 4, 5):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["window"] == 4
    assert snap["mean"] == pytest.approx(3.0)   # lifetime mean, not window


def test_namespace_uniqueness_and_drop():
    reg = obs.MetricsRegistry()
    a = reg.namespace("service")
    b = reg.namespace("service")
    assert a.prefix == "service" and b.prefix == "service#1"
    a.counter("completed").inc(3)
    b.counter("completed").inc(5)
    assert reg.get("service.completed").value == 3
    assert reg.get("service#1.completed").value == 5
    a.drop()
    assert reg.get("service.completed") is None
    assert reg.get("service#1.completed").value == 5


def test_snapshot_is_json_serializable_and_guards_dead_sources():
    reg = obs.MetricsRegistry()
    reg.counter("n").inc(2)
    reg.gauge("g", fn=lambda: 1.5)
    reg.register_source("ok", lambda: {"x": 1})
    reg.register_source("dead", lambda: 1 / 0)
    with pytest.raises(ValueError):
        reg.register_source("ok", lambda: {})
    reg.register_source("ok", lambda: {"x": 2}, replace=True)
    snap = reg.snapshot()
    json.dumps(snap)                       # the whole view must serialize
    assert snap["metrics"]["n"] == {"type": "counter", "value": 2}
    assert snap["metrics"]["g"]["value"] == 1.5
    assert snap["sources"]["ok"] == {"x": 2}
    assert "ZeroDivisionError" in snap["sources"]["dead"]["error"]


def test_process_registry_carries_mapping_cache_source():
    # the default cache registers itself into the registry that was
    # current at its first creation — the process-wide one
    program, target = _program(), _target()
    ual.compile(program, target)           # touches the default cache
    snap = obs.registry().snapshot()
    assert "mapping_cache" in snap["sources"]
    assert isinstance(snap["sources"]["mapping_cache"], dict)


# ---------------------------------------------------------------------------
# ServiceMetrics: historical shape on registry instruments
# ---------------------------------------------------------------------------

def test_service_metrics_empty_snapshot_shape():
    m = ServiceMetrics(registry=obs.MetricsRegistry())
    snap = m.snapshot(queue_depth=0)
    assert snap["completed"] == 0 and snap["rejected"] == 0
    assert snap["p50_ms"] is None and snap["p99_ms"] is None
    assert snap["mean_batch"] is None and snap["max_batch"] is None
    assert snap["stream"]["spans"] == 0
    assert snap["stream"]["overlap_frac"] is None


def test_service_metrics_reject_only_and_stream_only():
    m = ServiceMetrics(registry=obs.MetricsRegistry())
    m.record_reject("t0", "queue-full")
    m.record_reject("t0", "queue-full")
    m.record_reject("t1", "deadline-exceeded")
    snap = m.snapshot()
    assert snap["rejects"] == {"queue-full": 2, "deadline-exceeded": 1}
    assert snap["tenants"]["t0"] == {"completed": 0, "rejected": 2,
                                     "errors": 0}
    m2 = ServiceMetrics(registry=obs.MetricsRegistry())
    m2.record_stream_span(chunks=3, samples=96, wall_s=0.5, overlap=0.25)
    s2 = m2.snapshot()
    assert s2["completed"] == 0
    assert s2["stream"] == {"spans": 1, "chunks": 3, "samples": 96,
                            "overlap_frac": 0.25, "samples_per_s": 192.0}


def test_record_error_attributes_per_tenant():
    m = ServiceMetrics(registry=obs.MetricsRegistry())
    m.record_error(["a", "a", "b"])
    assert m.errors == 3
    snap = m.snapshot()
    assert snap["tenants"]["a"]["errors"] == 2
    assert snap["tenants"]["b"]["errors"] == 1
    assert snap["errors"] == 3


def test_service_metrics_registers_and_closes_namespace():
    reg = obs.MetricsRegistry()
    m1 = ServiceMetrics(registry=reg)
    m2 = ServiceMetrics(registry=reg)
    assert m1.namespace == "service" and m2.namespace == "service#1"
    m1.record_completed("t", 0.010)
    assert reg.get("service.completed").value == 1
    m1.close()
    assert reg.get("service.completed") is None
    assert reg.get("service#1.completed") is not None
    # instruments stay usable after close — snapshot() still reads them
    assert m1.snapshot()["completed"] == 1


# ---------------------------------------------------------------------------
# cluster percentile merge
# ---------------------------------------------------------------------------

def test_merge_latency_computes_real_percentiles_from_windows():
    snaps = {
        0: {"p50_ms": 2.0, "p99_ms": 4.0,
            "latency_window_ms": [1.0] * 90},
        1: {"p50_ms": 50.0, "p99_ms": 100.0,
            "latency_window_ms": [100.0] * 10},
    }
    got = merge_latency(snaps)
    # 90 fast samples + 10 slow: merged p50 is 1ms (NOT the mid-value a
    # max/mean-of-percentiles would suggest), p99 lands in the slow tail
    assert got["p50_ms"] == 1.0
    assert got["p99_ms"] == 100.0
    assert got["worst_worker_p99_ms"] == 100.0
    assert got["latency_samples_merged"] == 100
    # windows are popped so per-worker views don't ship megabytes
    assert "latency_window_ms" not in snaps[0]


def test_merge_latency_falls_back_without_windows():
    snaps = {0: {"p50_ms": 2.0, "p99_ms": 4.0},
             1: {"p50_ms": 3.0, "p99_ms": 9.0}}
    got = merge_latency(snaps)
    assert got == {"p50_ms": 3.0, "p99_ms": 9.0,
                   "worst_worker_p99_ms": 9.0,
                   "latency_samples_merged": 0}
    assert merge_latency({})["p99_ms"] is None


# ---------------------------------------------------------------------------
# end to end: a traced request through the service
# ---------------------------------------------------------------------------

def test_traced_request_breakdown_accounts_for_latency(fresh_obs):
    tr, _reg = fresh_obs
    program, target = _program(), _target()
    rng = np.random.default_rng(1)
    mems = [program.random_inputs(rng) for _ in range(6)]
    with ual.Service(max_batch=4, max_wait_ms=2) as svc:
        svc.submit(program, target, mems[0]).result(timeout=300)  # warm
        futs = [svc.submit(program, target, m, tenant="traced")
                for m in mems[1:]]
        for f in futs:
            f.result(timeout=300)
    for f in futs:
        trace = f.info["trace"]
        assert trace["trace_id"]
        parts = (trace["queue_ms"] + trace["coalesce_ms"]
                 + trace["exec_ms"])
        lat = f.info["latency_ms"]
        assert parts == pytest.approx(lat, rel=0.10)
        assert trace["resolve_ms"] >= 0
        names = {s.name for s in tr.spans(trace["trace_id"])}
        assert {"request", "queue", "coalesce", "exec",
                "resolve"} <= names
    # distinct requests get distinct trace ids
    ids = {f.info["trace"]["trace_id"] for f in futs}
    assert len(ids) == len(futs)
    # the whole recording exports as a valid chrome doc
    assert obs.validate_chrome(tr.to_chrome()) == []


def test_compile_emits_pass_spans(fresh_obs):
    tr, _reg = fresh_obs
    program, target = _program(), _target()
    exe = ual.compile(program, target)
    assert exe.success
    names = [s.name for s in tr.spans()]
    assert any(n.startswith("compile:") for n in names)
    assert sum(1 for n in names if n.startswith("pass:")) >= 3
    root = next(s for s in tr.spans() if s.name.startswith("compile:"))
    passes = [s for s in tr.spans() if s.name.startswith("pass:")]
    assert all(p.trace_id == root.trace_id for p in passes)


def test_bench_timer_records_span(fresh_obs):
    tr, _reg = fresh_obs
    from benchmarks.common import Timer
    with Timer("phase"):
        pass
    assert [s.name for s in tr.spans()] == ["bench:phase"]
    assert tr.spans()[0].cat == "bench"


def test_record_tree_expands_lazily_with_stable_ids():
    tr = obs.Tracer(enabled=True)
    tid = tr.new_trace_id()
    tr.record_tree(tid, (
        ("request", 1.0, 2.0, "service", {"tenant": "a"}),
        ("queue", 1.0, 1.2, "service", None),
        ("exec", 1.2, 2.0, "engine", None),
    ))
    # one ring entry, but stats count the spans it carries
    assert tr.stats()["recorded"] == 3
    assert tr.stats()["buffered"] == 3
    first = tr.spans()
    assert [s.name for s in first] == ["request", "queue", "exec"]
    root = first[0]
    assert root.parent_id is None and root.args == {"tenant": "a"}
    assert all(s.parent_id == root.span_id and s.trace_id == tid
               for s in first[1:])
    # expansion is cached: a second read returns the same span ids
    assert [s.span_id for s in tr.spans()] == [s.span_id for s in first]


def test_record_tree_drops_count_span_weight():
    tr = obs.Tracer(enabled=True, capacity=2)
    for _ in range(3):
        tr.record_tree(tr.new_trace_id(), (
            ("request", 0.0, 1.0, "service", None),
            ("exec", 0.0, 1.0, "engine", None),
        ))
    stats = tr.stats()
    assert stats["recorded"] == 6
    assert stats["buffered"] == 4     # 2 entries x 2 spans survive
    assert stats["dropped"] == 2      # the evicted entry carried 2 spans

"""The dynamic-batching execution service: queue -> coalesce -> sweep.

Contract under test:

  * responses are bit-exact vs the DFG-interpreter oracle, whether a
    request rode a full micro-batch or a clock-flushed partial one,
  * requests only coalesce within their compatibility class
    (program digest x target digest x backend x n_iters) — mixed-tenant
    traffic batches per tenant kernel, never across,
  * a cold tenant joining a running service pays exactly one mapping and
    one lowering, even when its first requests land on several threads
    at once (the per-key compile lock),
  * overload produces bounded-queue rejections (``queue-full``) instead
    of unbounded growth; expired deadlines reject (``deadline-exceeded``)
    instead of executing; both surface as ``ServiceRejected`` values,
  * shutdown flushes pending work; a never-started service rejects
    rather than strands,
  * ``stats()`` reports the serving numbers (p50/p99, achieved batch,
    samples/s, queue depth, rejects by reason, per-tenant totals).
"""
import time

import numpy as np
import pytest

from repro import ual
from repro.core.dfg import interpret
from repro.ual.service.coalescer import Coalescer


def _program(kname="gemm"):
    return ual.Program.from_kernel(kname)


def _target(**knobs):
    return ual.Target.from_name("hycube", rows=4, cols=4, **knobs)


def _oracle(program, mem):
    return interpret(program.dfg, mem, program.n_iters)


# ---------------------------------------------------------------------------
# correctness: oracle parity through the batching path
# ---------------------------------------------------------------------------

def test_single_request_matches_oracle():
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(0))
    with ual.Service(max_batch=8, max_wait_ms=2) as svc:
        resp = svc.submit(program, target, mem)
        out = resp.result(timeout=300)
    assert resp.done() and not resp.rejected
    assert resp.info["batch"] >= 1 and resp.info["latency_ms"] > 0
    expect = _oracle(program, mem)
    for name in program.outputs:
        np.testing.assert_array_equal(out[name], expect[name])


def test_many_requests_coalesce_and_stay_bitexact():
    program, target = _program(), _target()
    rng = np.random.default_rng(1)
    mems = [program.random_inputs(rng) for _ in range(24)]
    with ual.Service(max_batch=8, max_wait_ms=50) as svc:
        resps = [svc.submit(program, target, m) for m in mems]
        outs = [r.result(timeout=300) for r in resps]
        stats = svc.stats()
    for mem, out in zip(mems, outs):
        expect = _oracle(program, mem)
        for name in program.outputs:
            np.testing.assert_array_equal(out[name], expect[name])
    assert stats["completed"] == 24
    assert stats["mean_batch"] > 1          # the coalescer actually batched
    assert stats["samples_per_s"] > 0
    assert stats["p50_ms"] is not None and stats["p99_ms"] is not None


def test_mixed_tenants_batch_within_their_class_only():
    """gemm and fft requests share the service but never one sweep: each
    response's achieved batch can only count requests of its own key."""
    target = _target()
    programs = {"gemm-app": _program("gemm"), "fft-app": _program("fft")}
    rng = np.random.default_rng(2)
    with ual.Service(max_batch=4, max_wait_ms=50) as svc:
        inflight = []
        for _ in range(8):
            for tenant, program in programs.items():
                mem = program.random_inputs(rng)
                inflight.append((tenant, program, mem,
                                 svc.submit(program, target, mem,
                                            tenant=tenant)))
        for tenant, program, mem, resp in inflight:
            out = resp.result(timeout=300)
            assert resp.info["batch"] <= 4
            expect = _oracle(program, mem)
            for name in program.outputs:
                np.testing.assert_array_equal(out[name], expect[name])
        stats = svc.stats()
    assert stats["tenants"]["gemm-app"]["completed"] == 8
    assert stats["tenants"]["fft-app"]["completed"] == 8
    assert stats["executables"] == 2        # one warm Executable per class


def test_different_n_iters_never_share_a_sweep():
    program, target = _program(), _target()
    rng = np.random.default_rng(3)
    m1, m2 = program.random_inputs(rng), program.random_inputs(rng)
    with ual.Service(max_batch=8, max_wait_ms=20) as svc:
        r1 = svc.submit(program, target, m1)                 # default trip
        r2 = svc.submit(program, target, m2, n_iters=4)      # shorter trip
        out2 = r2.result(timeout=300)
        r1.result(timeout=300)
    expect2 = interpret(program.dfg, m2, 4)
    for name in program.outputs:
        np.testing.assert_array_equal(out2[name], expect2[name])


# ---------------------------------------------------------------------------
# cold tenant: exactly one mapping + one lowering, service-wide
# ---------------------------------------------------------------------------

def test_cold_tenant_compiles_once_under_concurrent_submits(tmp_path):
    """A cold tenant's first requests arriving on several worker threads
    must trigger exactly one mapper run and one lowering — counted by the
    cache — with every response still oracle-exact."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program, target = _program(), _target()
    rng = np.random.default_rng(4)
    mems = [program.random_inputs(rng) for _ in range(12)]
    # max_batch=1: every request becomes its own sweep, so with 3 workers
    # several sweeps race to compile the cold key simultaneously
    with ual.Service(max_batch=1, max_wait_ms=1, workers=3,
                     cache=cache) as svc:
        resps = [svc.submit(program, target, m) for m in mems]
        outs = [r.result(timeout=300) for r in resps]
    assert cache.stats.stores == 1
    assert cache.stats.lowered_stores == 1
    expect = _oracle(program, mems[0])
    for name in program.outputs:
        np.testing.assert_array_equal(outs[0][name], expect[name])


# ---------------------------------------------------------------------------
# backpressure, deadlines, shutdown
# ---------------------------------------------------------------------------

def test_overload_rejects_with_queue_full():
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(5))
    svc = ual.Service(max_batch=8, max_queue=4, start=False)
    accepted = [svc.submit(program, target, mem) for _ in range(4)]
    overflow = [svc.submit(program, target, mem) for _ in range(3)]
    for resp in overflow:
        assert resp.done() and resp.rejected
        assert resp.reason == "queue-full"
        with pytest.raises(ual.ServiceRejected):
            resp.result()
    assert svc.stats()["queue_depth"] == 4  # bounded: never past max_queue
    svc.shutdown()
    # never-started: the queued requests reject rather than strand
    for resp in accepted:
        assert resp.done() and resp.reason == "shutdown"
    stats = svc.stats()
    assert stats["rejects"]["queue-full"] == 3
    assert stats["rejects"]["shutdown"] == 4
    assert stats["queue_depth"] == 0        # rejected slots were released


def test_expired_deadline_rejects_instead_of_executing():
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(6))
    svc = ual.Service(max_batch=8, max_wait_ms=1, start=False,
                      deadlines_ms={"impatient": 1.0})
    resp = svc.submit(program, target, mem, tenant="impatient")
    time.sleep(0.05)                        # let the deadline lapse
    svc.start()
    with pytest.raises(ual.ServiceRejected):
        resp.result(timeout=300)
    assert resp.reason == "deadline-exceeded"
    stats = svc.stats()
    svc.shutdown()
    assert stats["tenants"]["impatient"]["rejected"] == 1


def test_submit_after_shutdown_rejects():
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(7))
    svc = ual.Service(max_batch=4, max_wait_ms=1)
    svc.submit(program, target, mem).result(timeout=300)
    svc.shutdown()
    resp = svc.submit(program, target, mem)
    assert resp.rejected and resp.reason == "shutdown"


def test_malformed_arrays_raise_at_submit():
    """A typo'd array name is a caller bug: it must raise immediately at
    submit, never reach (and poison) a micro-batch."""
    program, target = _program(), _target()
    with ual.Service(max_batch=4, max_wait_ms=1) as svc:
        with pytest.raises(KeyError, match="unknown array"):
            svc.submit(program, target, not_an_array=np.zeros(4,
                                                              np.int32))


def test_shutdown_flushes_partial_batches():
    program, target = _program(), _target()
    rng = np.random.default_rng(8)
    mems = [program.random_inputs(rng) for _ in range(3)]
    # max_wait far beyond the test: only the shutdown flush can run these
    svc = ual.Service(max_batch=64, max_wait_ms=60_000)
    resps = [svc.submit(program, target, m) for m in mems]
    svc.shutdown()
    for mem, resp in zip(mems, resps):
        out = resp.result(timeout=1)
        expect = _oracle(program, mem)
        for name in program.outputs:
            np.testing.assert_array_equal(out[name], expect[name])


# ---------------------------------------------------------------------------
# coalescer unit behavior (no threads)
# ---------------------------------------------------------------------------

class _FakeReq:
    def __init__(self, key, t, deadline=None):
        self.key, self.t_submit, self.deadline = key, t, deadline


def test_coalescer_flushes_on_size_and_age():
    co = Coalescer(max_batch=2, max_wait_s=1.0)
    assert co.offer(_FakeReq("k1", 0.0)) is None
    full = co.offer(_FakeReq("k1", 0.1))
    assert full is not None and len(full) == 2      # size flush
    assert co.pending() == 0

    co.offer(_FakeReq("k2", 10.0))
    assert co.pop_expired(10.5) == []               # not aged yet
    assert co.next_deadline(10.5) == pytest.approx(0.5)
    [aged] = co.pop_expired(11.0)                   # age flush
    assert len(aged) == 1 and co.next_deadline(11.0) is None


def test_coalescer_flushes_on_member_deadline():
    """A member deadline pulls the bucket's flush earlier than max_wait,
    so the deadline verdict is issued at the deadline, not minutes later."""
    co = Coalescer(max_batch=8, max_wait_s=1000.0)
    co.offer(_FakeReq("k", 0.0, deadline=2.0))
    assert co.next_deadline(0.0) == pytest.approx(2.0)
    assert co.pop_expired(1.9) == []
    [due] = co.pop_expired(2.0)
    assert len(due) == 1


def test_deadline_bounds_rejection_latency_not_max_wait():
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(9))
    with ual.Service(max_batch=64, max_wait_ms=60_000) as svc:
        t0 = time.perf_counter()
        resp = svc.submit(program, target, mem, deadline_ms=50)
        with pytest.raises(ual.ServiceRejected):
            resp.result(timeout=10)
        waited = time.perf_counter() - t0
    assert resp.reason == "deadline-exceeded"
    assert waited < 5        # bounded by the deadline, not max_wait_ms


def test_coalescer_keeps_keys_apart():
    co = Coalescer(max_batch=3, max_wait_s=1.0)
    co.offer(_FakeReq("a", 0.0))
    co.offer(_FakeReq("b", 0.0))
    co.offer(_FakeReq("a", 0.0))
    assert co.pending() == 3
    batches = co.flush_all()
    assert sorted(len(b) for b in batches) == [1, 2]

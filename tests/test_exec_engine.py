"""Lower-once / run-many: shared lowering pass + vectorized batched engine.

The execution-path contract under test:

  * the compile pipeline lowers a mapped configuration ONCE to the dense
    linked tables; warm compiles reuse the cached artifact with zero
    re-lowering (in-process and across the disk layer), and the ``sim``
    and ``pallas`` backends both execute that one artifact,
  * ``simulate_batch`` (the vectorized engine, leading batch axis) is
    bit-exact against ``simulate_reference`` (the scalar semantics spec)
    including the per-sample ``SimStats``,
  * ``run_batch`` is natively batched on ``sim`` and reports throughput,
  * memory-port oversubscription is recorded in ``SimStats`` (worst
    cycle, ports used) even with ``check_ports=False``,
  * run/run_batch info is returned per call — ``last_info`` is only a
    convenience copy, so shared Executables are reentrant,
  * concurrent compiles of one ``(program, target)`` digest pair pay
    exactly one mapper run and one lowering (the cache's per-key compile
    lock — what the execution service leans on for cold tenants).
"""
import copy
import threading

import numpy as np
import pytest

from repro import ual
from repro.core.lowering import link_config
from repro.core.machine import XB_IN
from repro.core.simulator import simulate_batch, simulate_reference


def _compiled(kname="gemm", **knobs):
    program = ual.Program.from_kernel(kname)
    target = ual.Target.from_name("hycube", rows=4, cols=4, **knobs)
    exe = ual.compile(program, target)
    assert exe.success
    return program, exe


def _flat_batch(program, B, seed=0):
    rng = np.random.default_rng(seed)
    named = [program.random_inputs(rng) for _ in range(B)]
    return named, np.stack([program.flatten(m) for m in named])


# ---------------------------------------------------------------------------
# engine parity
# ---------------------------------------------------------------------------

def test_batched_engine_bitexact_vs_reference():
    """Vectorized-batched == scalar reference, outputs AND stats — on a
    config that exercises HyCUBE multi-hop bypass chains."""
    program, exe = _compiled("gemm")
    cfg = exe.map_result.config
    # the mapped config routes through wire-to-wire segments, so the
    # lowered tables really collapse multi-hop chains
    assert int((cfg.xbar[..., 0] == XB_IN).sum()) > 0
    _, flats = _flat_batch(program, 5)
    outs, stats = simulate_batch(exe.lowered, flats, program.n_iters)
    for b in range(5):
        want, rstats = simulate_reference(cfg, flats[b], program.n_iters)
        np.testing.assert_array_equal(outs[b], want)
    assert (stats.cycles, stats.fired, stats.idle_slots,
            stats.mem_accesses, stats.max_mem_ports_used,
            stats.worst_port_cycle) == \
           (rstats.cycles, rstats.fired, rstats.idle_slots,
            rstats.mem_accesses, rstats.max_mem_ports_used,
            rstats.worst_port_cycle)


def test_sim_backend_natively_batched_with_throughput():
    program, exe = _compiled("gemm")
    named, _ = _flat_batch(program, 8, seed=3)
    outs = exe.run_batch(named)
    info = exe.last_info
    assert info.get("batched") and info["batch"] == 8
    assert info["throughput_sps"] > 0 and info["wall_s"] > 0
    assert "sim_stats" in info
    for mem, got in zip(named, outs):
        want = exe.run(mem, backend="sim")
        for name in program.outputs:
            np.testing.assert_array_equal(got[name], want[name])


def test_validate_one_batched_sweep_per_backend():
    program, exe = _compiled("nw")
    rep = exe.validate(seed=2, backends=("sim", "pallas"), n_vectors=3)
    assert rep.passed and rep.n_vectors == 3
    assert rep.backend_results == {"sim": True, "pallas": True}
    assert rep.sim_stats is not None


# ---------------------------------------------------------------------------
# lowering: once per compile, shared by every backend
# ---------------------------------------------------------------------------

def test_lowering_cached_with_zero_relowering(tmp_path, monkeypatch):
    """Cold compile lowers once; warm compiles (memory AND disk layer)
    reuse the artifact — proved by making any further lowering raise —
    and sim + pallas execute that one artifact bit-exactly vs the oracle."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    target = ual.Target.from_name("hycube", rows=4, cols=4)

    cold = ual.compile(program, target, cache=cache)
    assert cold.lowered is not None
    assert cache.stats.lowered_misses == 1
    assert cache.stats.lowered_stores == 1

    def boom(*a, **kw):
        raise AssertionError("re-lowering after the cold compile")

    for where in ("repro.core.lowering.link_config",
                  "repro.ual.pipeline.link_config",
                  "repro.kernels.cgra_exec.ops.link_config"):
        monkeypatch.setattr(where, boom)

    warm = ual.compile(program, target, cache=cache)
    assert warm.compile_info.cache_hit and warm.lowered is not None
    assert cache.stats.lowered_hits == 1

    cache.clear_memory()                      # cross-process path
    disk = ual.compile(program, target, cache=cache)
    assert disk.lowered is not None
    assert cache.stats.lowered_disk_hits == 1

    # both device backends execute the shared artifact (no re-linking)
    mem = program.random_inputs(np.random.default_rng(1))
    oracle = disk.run(mem, backend="interp")
    for backend in ("sim", "pallas"):
        got = disk.run(mem, backend=backend)
        for name in program.outputs:
            np.testing.assert_array_equal(got[name], oracle[name])


def test_lowered_cache_rejects_foreign_fingerprint(tmp_path):
    """Tables pinned to a DIFFERENT configuration (racing process, re-map
    after a lost mapping pickle) must read as a miss, never execute."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    target = ual.Target.from_name("hycube", rows=4, cols=4)
    cold = ual.compile(program, target, cache=cache)
    key = cold.compile_info.key
    cache.put_lowered(key, cold.lowered, "fingerprint-of-another-config")
    cache.stats.reset()

    warm = ual.compile(program, target, cache=cache)
    assert warm.compile_info.cache_hit           # the mapping still hits
    assert cache.stats.lowered_hits == 0         # mismatched tables: miss
    assert cache.stats.lowered_stores == 1       # re-lowered and re-pinned
    np.testing.assert_array_equal(warm.lowered.scalar, cold.lowered.scalar)


def test_concurrent_compiles_map_and_lower_once(tmp_path, monkeypatch):
    """Two threads compiling the same (program, target) digest pair must
    produce exactly one mapper run and one lowering (monkeypatch-counted)
    — the per-key compile lock extends the lower-once proof to thread
    concurrency: the loser waits out the winner's mapping AND lowering
    instead of redoing either."""
    import repro.ual.pipeline as pl

    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    target = ual.Target.from_name("hycube", rows=4, cols=4)

    map_calls, lower_calls = [], []
    real_map, real_link = pl.map_dfg, pl.link_config
    monkeypatch.setattr(
        pl, "map_dfg",
        lambda *a, **k: map_calls.append(1) or real_map(*a, **k))
    monkeypatch.setattr(
        pl, "link_config",
        lambda *a, **k: lower_calls.append(1) or real_link(*a, **k))

    barrier = threading.Barrier(2)
    exes = [None, None]

    def compile_one(i):
        barrier.wait()                       # maximize the race window
        exes[i] = ual.compile(program, target, cache=cache)

    threads = [threading.Thread(target=compile_one, args=(i,))
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert len(map_calls) == 1
    assert len(lower_calls) == 1
    assert cache.stats.stores == 1 and cache.stats.lowered_stores == 1
    assert all(e is not None and e.success for e in exes)
    # one thread paid the cold compile, the other rode it — and both hold
    # the very same artifacts
    assert sorted(e.compile_info.cache_hit for e in exes) == [False, True]
    np.testing.assert_array_equal(exes[0].lowered.scalar,
                                  exes[1].lowered.scalar)


def test_lowered_artifact_excluded_for_configless_executables():
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target.from_name("spatial", rows=4,
                                                    cols=4, backend="interp"))
    assert exe.lowered is None
    stats = {p.name: p.stats for p in exe.compile_info.passes}
    assert stats["lowering"] == {"skipped": "no machine configuration"}


# ---------------------------------------------------------------------------
# port-pressure accounting
# ---------------------------------------------------------------------------

def test_port_oversubscription_recorded_without_check():
    """Shrinking the port budget below the mapped schedule's worst cycle:
    check_ports=False must still record (worst cycle, ports used) in the
    stats instead of the information living only in a RuntimeError."""
    program, exe = _compiled("gemm")
    cfg = copy.deepcopy(exe.map_result.config)
    assert cfg.fabric.n_mem_ports >= 2
    cfg.fabric.n_mem_ports = 1
    linked = link_config(cfg)
    _, flats = _flat_batch(program, 3, seed=5)

    out, stats = simulate_batch(linked, flats, program.n_iters,
                                check_ports=False)
    assert stats.max_mem_ports_used > 1
    assert stats.worst_port_cycle >= 0
    assert stats.mem_ports_limit == 1
    assert stats.oversubscribed
    # the reference engine records the same pressure
    _, rstats = simulate_reference(cfg, flats[0], program.n_iters,
                                   check_ports=False)
    assert (rstats.max_mem_ports_used, rstats.worst_port_cycle) == \
           (stats.max_mem_ports_used, stats.worst_port_cycle)
    assert rstats.oversubscribed

    with pytest.raises(RuntimeError, match="oversubscription"):
        simulate_batch(linked, flats, program.n_iters, check_ports=True)


# ---------------------------------------------------------------------------
# reentrancy: per-call info, last_info is a convenience copy
# ---------------------------------------------------------------------------

def test_last_info_is_a_per_call_copy():
    program, exe = _compiled("gemm")
    mem = program.random_inputs(np.random.default_rng(0))
    exe.run(mem)
    first = exe.last_info
    exe.run(mem)
    assert exe.last_info is not first          # fresh dict per call

    # validate() threads info internally — it must not clobber last_info,
    # so concurrent sharers of one Executable never race through it
    sentinel = {"sentinel": True}
    exe.last_info = sentinel
    rep = exe.validate(seed=0, backends=("sim",), n_vectors=2)
    assert rep.passed
    assert exe.last_info is sentinel

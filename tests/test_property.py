"""Property-based tests (hypothesis) on the system's invariants.

The central invariant is Morpher's own correctness contract: for ANY
loop-body DFG the flow  map -> emit config -> simulate  must agree
bit-exactly with the DFG interpreter, and the Pallas cgra_exec kernel must
agree with the simulator.  Hypothesis generates random DFGs (random ALU
dags + loads/stores + optional recurrences) to hunt corner cases the fixed
kernel library misses.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.adl import hycube
from repro.core.dfg import (DFGBuilder, apply_layout, flat_memory, interpret,
                            plan_layout, unflatten_memory)
from repro.core.mapper import compute_mii, map_dfg

ALU2 = ("ADD", "SUB", "MUL", "AND", "OR", "XOR", "MIN", "MAX",
        "CMPLT", "CMPGT")


@st.composite
def random_dfg(draw):
    """A random loop body: loads, an ALU dag, optional recurrence, stores."""
    b = DFGBuilder("prop")
    n_in = draw(st.integers(1, 3))
    N = 8
    for j in range(n_in):
        b.array(f"in{j}", N)
    b.array("out", N, output=True)
    i = b.counter()
    vals = [b.load(f"in{j}", i) for j in range(n_in)]
    use_rec = draw(st.booleans())
    rec = None
    if use_rec:
        rec = b.recur(init=draw(st.integers(-4, 4)))
        vals.append(rec)
    n_ops = draw(st.integers(1, 6))
    for _ in range(n_ops):
        op = draw(st.sampled_from(ALU2))
        a = vals[draw(st.integers(0, len(vals) - 1))]
        use_const = draw(st.booleans())
        if use_const:
            v = b.op(op, a, const=draw(st.integers(-8, 8)))
        else:
            c = vals[draw(st.integers(0, len(vals) - 1))]
            v = b.op(op, a, c)
        vals.append(v)
    result = vals[-1]
    if use_rec:
        # keep recurrence values bounded so MUL chains cannot overflow-diverge
        bounded = b.op("MAX", b.op("MIN", result, 1 << 10), -(1 << 10))
        b.bind(rec, bounded)
        result = bounded
    b.store("out", i, result)
    return b.build()


@settings(max_examples=12, deadline=None)
@given(random_dfg(), st.integers(0, 3))
def test_mapped_config_matches_interpreter(dfg, seed):
    """map -> simulate == interpret, for arbitrary DFGs (bit-exact)."""
    from repro.core.simulator import simulate
    fab = hycube(4, 4)
    layout = plan_layout(dfg)
    laid = apply_layout(dfg, layout)
    res = map_dfg(laid, fab, seed=seed, ii_max=24)
    assert res.success, "mapper must map any small DFG within ii_max"
    assert res.II >= compute_mii(laid, fab)
    rng = np.random.default_rng(seed)
    mem = {k: rng.integers(-50, 50, n).astype(np.int32)
           for k, n in dfg.arrays.items() if k != "out"}
    n_iters = 8
    expect = interpret(dfg, mem, n_iters)
    flat = flat_memory(layout, mem)
    out, _ = simulate(res.config, flat, n_iters)
    got = unflatten_memory(layout, out, dfg.arrays)
    np.testing.assert_array_equal(got["out"], expect["out"])


@settings(max_examples=6, deadline=None)
@given(random_dfg(), st.sampled_from(["hycube", "n2n", "pace"]),
       st.integers(1, 4), st.integers(2, 8))
def test_batched_engine_matches_reference_and_oracle(dfg, fab_name, B,
                                                     n_iters):
    """Engine parity, for arbitrary DFGs: vectorized-batched ==
    scalar reference == DFG-interpreter oracle, bit-exactly, across
    fabrics (incl. HyCUBE multi-hop bypass chains and PACE's 8x8 array),
    batch sizes and trip counts."""
    from repro.core.adl import n2n, pace
    from repro.core.lowering import link_config
    from repro.core.simulator import simulate_batch, simulate_reference
    fab = {"hycube": lambda: hycube(4, 4), "n2n": lambda: n2n(4, 4),
           "pace": pace}[fab_name]()
    layout = plan_layout(dfg, n_banks=fab.n_mem_ports)
    laid = apply_layout(dfg, layout)
    res = map_dfg(laid, fab, seed=0, ii_max=24)
    assert res.success, f"mapper must map any small DFG on {fab.name}"
    linked = link_config(res.config)
    rng = np.random.default_rng(7)
    named = [{k: rng.integers(-50, 50, n).astype(np.int32)
              for k, n in dfg.arrays.items() if k != "out"}
             for _ in range(B)]
    flats = np.stack([flat_memory(layout, m) for m in named])
    outs, stats = simulate_batch(linked, flats, n_iters)
    for b in range(B):
        want, rstats = simulate_reference(res.config, flats[b], n_iters)
        np.testing.assert_array_equal(outs[b], want)
        got = unflatten_memory(layout, outs[b], dfg.arrays)
        expect = interpret(dfg, named[b], n_iters)
        np.testing.assert_array_equal(got["out"], expect["out"])
    assert (stats.fired, stats.idle_slots, stats.max_mem_ports_used) == \
           (rstats.fired, rstats.idle_slots, rstats.max_mem_ports_used)


@settings(max_examples=10, deadline=None)
@given(random_dfg(), st.integers(0, 3))
def test_verifier_clean_implies_executable(dfg, seed):
    """The static verifier's soundness direction, for arbitrary DFGs: a
    mapper-produced config never carries ERROR findings (the mapper never
    emits the hazards the verifier hunts), and an error-free config must
    execute without the engines' runtime checks firing.  Warnings are
    allowed only for dead code (UAL007): the random generator freely
    builds ops whose results nothing consumes, and the mapper faithfully
    maps them — a true positive, not verifier noise."""
    from repro.analysis.verifier import verify
    from repro.core.lowering import link_config
    from repro.core.simulator import simulate_reference
    fab = hycube(4, 4)
    layout = plan_layout(dfg, n_banks=fab.n_mem_ports)
    laid = apply_layout(dfg, layout)
    res = map_dfg(laid, fab, seed=seed, ii_max=24)
    assert res.success
    linked = link_config(res.config)
    rep = verify(cfg=res.config, linked=linked)
    assert rep.ok, rep.render()
    assert {d.code for d in rep.warnings} <= {"UAL007"}, rep.render()
    assert linked.unresolved_inputs == 0
    rng = np.random.default_rng(seed)
    mem = {k: rng.integers(-50, 50, n).astype(np.int32)
           for k, n in dfg.arrays.items() if k != "out"}
    # clean verdict => the runtime port/hazard checks stay silent
    simulate_reference(res.config, flat_memory(layout, mem), 8,
                       check_ports=True)


@settings(max_examples=6, deadline=None)
@given(random_dfg())
def test_pallas_kernel_matches_simulator(dfg):
    """linked cgra_exec == cycle-accurate simulator, over a random batch."""
    from repro.kernels.cgra_exec.ops import cgra_exec_op
    from repro.kernels.cgra_exec.ref import cgra_exec_ref
    fab = hycube(4, 4)
    layout = plan_layout(dfg)
    laid = apply_layout(dfg, layout)
    res = map_dfg(laid, fab, seed=0, ii_max=24)
    assert res.success
    rng = np.random.default_rng(1)
    mems = np.stack([
        flat_memory(layout, {k: rng.integers(-50, 50, n).astype(np.int32)
                             for k, n in dfg.arrays.items()})
        for _ in range(2)])
    got = cgra_exec_op(res.config, mems, 6)
    want = cgra_exec_ref(res.config, mems, 6)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 16), st.integers(1, 3))
def test_pipeline_schedules_always_valid(S, M, C):
    from repro.core.pipeline_schedule import (gpipe, interleaved_1f1b,
                                              one_f_one_b)
    gpipe(S, M).verify()
    one_f_one_b(S, M).verify()
    interleaved_1f1b(S, M, n_chunks=C).verify()


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=4),
       st.integers(0, 100))
def test_checkpoint_roundtrip_property(dims, seed):
    import tempfile
    import jax.numpy as jnp
    from repro.checkpoint.checkpoint import restore, save
    rng = np.random.default_rng(seed)
    tree = {"a": {"w": jnp.asarray(rng.normal(size=tuple(dims)),
                                   jnp.float32)},
            "b": [jnp.asarray(rng.integers(0, 9, (3,)), jnp.int32),
                  jnp.float32(seed)]}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        got, manifest = restore(d, tree)
        assert manifest["step"] == 1
        for x, y in zip(np.asarray(got["a"]["w"]).ravel(),
                        np.asarray(tree["a"]["w"]).ravel()):
            assert x == y
        np.testing.assert_array_equal(got["b"][0], tree["b"][0])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(0, 50))
def test_data_pipeline_host_invariance(n_hosts, step):
    """Global batch content is invariant to how many hosts shard it."""
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig, host_batch
    cfg = smoke_config("qwen3-8b")
    dc = DataConfig(global_batch=np.lcm.reduce([n_hosts, 2]) * 2, seq_len=8)
    if dc.global_batch % n_hosts:
        return
    full = host_batch(cfg, dc, step, 0, 1)["tokens"]
    parts = [host_batch(cfg, dc, step, h, n_hosts)["tokens"]
             for h in range(n_hosts)]
    np.testing.assert_array_equal(full, np.concatenate(parts))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.booleans())
def test_opt_state_specs_match_state_structure(rows, cols, factored):
    """Spec tree structure must match init_opt_state exactly (the arctic
    dry-run bug class), for any mix of 1-D and 2-D params."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.train.optimizer import (OptConfig, init_opt_state,
                                       opt_state_specs)
    opt = OptConfig(factored=factored)
    params = {"w": jnp.zeros((rows * 8, cols * 8)), "norm": jnp.zeros((8,))}
    specs = {"w": P(None, None), "norm": P(None)}
    state = init_opt_state(params, opt)
    sspecs = opt_state_specs(specs, opt, params)
    t1 = jax.tree_util.tree_structure(state)
    t2 = jax.tree_util.tree_structure(
        jax.tree.map(lambda s: 0, sspecs,
                     is_leaf=lambda x: isinstance(x, P)))
    assert t1 == t2

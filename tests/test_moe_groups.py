"""Grouped (GShard-style) MoE dispatch correctness vs the global path.

With generous capacity (dropless regime) the grouped dispatch must produce
the SAME outputs as the global formulation — the grouping only changes
which capacity slice a token lands in, not the math.  Also checks the
per-group capacity accounting and that dropping degrades gracefully.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.common import init_params
from repro.models.moe import (moe_dispatch_combine,
                              moe_dispatch_combine_grouped)


def _weights(key, E=8, d=32, f=16):
    ks = jax.random.split(key, 4)
    wg = jax.random.normal(ks[0], (E, d, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.1
    wd = jax.random.normal(ks[2], (E, f, d), jnp.float32) * 0.1
    rw = jax.random.normal(ks[3], (d, E), jnp.float32)
    return wg, wu, wd, rw


@pytest.mark.parametrize("groups", [2, 4])
def test_grouped_matches_global_when_dropless(groups):
    key = jax.random.PRNGKey(0)
    T, d, E, k = 64, 32, 8, 2
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d), jnp.float32)
    wg, wu, wd, rw = _weights(key, E, d)
    # capacity_factor large enough that nothing drops in either formulation
    out_g, aux_g = moe_dispatch_combine_grouped(
        x, wg, wu, wd, rw, top_k=k, capacity_factor=float(E), groups=groups)
    out_1, aux_1 = moe_dispatch_combine(
        x, wg, wu, wd, rw, top_k=k, capacity_factor=float(E))
    np.testing.assert_allclose(out_g, out_1, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(aux_g, aux_1, atol=1e-6, rtol=1e-6)


def test_grouped_capacity_is_per_group():
    """Tight capacity drops tokens per group, never crashes."""
    key = jax.random.PRNGKey(2)
    T, d, E, k = 64, 16, 4, 1
    x = jax.random.normal(jax.random.PRNGKey(3), (T, d), jnp.float32)
    wg, wu, wd, rw = _weights(key, E, d, 8)
    out, aux = moe_dispatch_combine_grouped(
        x, wg, wu, wd, rw, top_k=k, capacity_factor=0.5, groups=4)
    assert out.shape == (T, d)
    assert np.isfinite(np.asarray(out)).all()


def test_moe_block_grouped_via_config_trains():
    cfg = smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(cfg, moe_groups=2)
    from repro.models.lm import lm_loss
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, {"tokens": tokens}), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


def test_fsdp_strategy_smoke_forward():
    """fsdp strategy + heads sharding lower/run on the host mesh."""
    import dataclasses
    from repro.launch.mesh import make_host_mesh
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import (make_sharded_train_step,
                                        make_train_state)
    cfg = dataclasses.replace(smoke_config("qwen3-8b"),
                              shard_strategy="fsdp", grad_reduce="pinned",
                              attn_head_shard="heads", attn_block_kv=0)
    mesh = make_host_mesh()
    with mesh:
        step, _ = make_sharded_train_step(cfg, OptConfig(), mesh, 4)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = make_train_state(cfg, OptConfig(), params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0,
                                    cfg.vocab)
        p2, s2, m = step(params, state, {"tokens": tokens})
    assert np.isfinite(float(m["total_loss"]))

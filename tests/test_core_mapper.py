"""Mapper invariants + the paper's Fig. 5 worked example + validation.

Kernel-library mappings go through ``ual.compile`` so they are memoized in
the session-wide cache (see conftest); the Fig. 5 example and the bound
tests keep exercising the low-level ``map_dfg`` surface directly.
"""
import pytest

from repro import ual
from repro.core import adl
from repro.core.dfg import DFGBuilder
from repro.core.kernel_lib import KERNELS
from repro.core.mapper import (compute_mii, map_dfg, placement_order,
                               rec_mii, res_mii, spatial_ii)


def _compiled(kname: str, fabric) -> ual.Executable:
    # deliberately the default mapper seed: identical pairs then share one
    # cached mapping across the whole session (test_kernels, test_system,
    # ...); non-default-seed coverage lives in test_nondefault_seed below
    program = ual.Program.from_kernel(kname, n_banks=fabric.n_mem_ports)
    exe = ual.compile(program, ual.Target(fabric))
    assert exe.success, f"{kname} failed to map on {fabric.name}"
    return exe


def fig5_dfg():
    """The paper's Fig. 5 loop kernel: n1 fans out to n2,n3,n5,n6; reduced
    through n4/n7 into n8, which feeds n1 of the next iteration."""
    b = DFGBuilder("fig5")
    n1 = b.counter(0, 1)               # feeds the next iteration (colored node)
    n2 = b.op("ADD", n1, 2)
    n3 = b.op("SUB", n1, 3)
    n5 = b.op("XOR", n1, 5)
    n6 = b.op("AND", n1, 6)
    n4 = b.op("ADD", n2, n3)
    n7 = b.op("OR", n5, n6)
    n8 = b.op("ADD", n4, n7)
    return b.build()


def test_fig5_example_hycube_beats_n2n():
    dfg = fig5_dfg()
    hy = map_dfg(dfg, adl.hycube(2, 2, max_hops=4), seed=0)
    nn = map_dfg(dfg, adl.n2n(2, 2), seed=0)
    assert hy.success and nn.success
    # paper: II=2 on HyCUBE (our N2N mapper also reaches the ResMII bound on
    # this 8-node example because output latches broadcast to all neighbors
    # for free in our N2N model; Table III kernels show the strict gap)
    assert hy.II == 2          # the paper's HyCUBE II, == ResMII (optimal)
    assert nn.II >= hy.II


def test_mii_bounds():
    dfg, _, _ = KERNELS["gemm"]()
    fab = adl.hycube(4, 4)
    assert res_mii(dfg, fab) >= 3      # 9 mem ops / 4 ports
    assert rec_mii(dfg) >= 1
    assert compute_mii(dfg, fab) == max(res_mii(dfg, fab), rec_mii(dfg))


def test_placement_order_topological_and_cycle_first():
    dfg, _, _ = KERNELS["nw"]()
    order = placement_order(dfg)
    pos = {nid: i for i, nid in enumerate(order)}
    for n in dfg.nodes:
        for o in n.operands:
            if o.dist == 0:
                assert pos[o.src] < pos[n.id]


@pytest.mark.parametrize("kname", ["gemm", "nw", "aes", "fft"])
def test_mapping_invariants(kname):
    exe = _compiled(kname, adl.hycube(4, 4, max_hops=4))
    res = exe.map_result
    assert res.II >= res.mii
    # every node placed exactly once, on a compatible FU
    fab = adl.hycube(4, 4, max_hops=4)
    dfg = exe.program.laid
    assert set(res.placements) == {nd.id for nd in dfg.nodes}
    for nid, (pe, t) in res.placements.items():
        assert fab.supports(pe, dfg.nodes[nid].op)
        assert t >= 0


@pytest.mark.parametrize("kname,fabric", [
    ("gemm", "hycube"), ("nw", "hycube"), ("aes", "hycube"),
    ("gemm", "n2n"), ("nw", "n2n"),
])
def test_end_to_end_validation(kname, fabric):
    """Morpher's flagship feature: mapped bitstream == oracle, bit exact."""
    fab = adl.hycube(4, 4, 4) if fabric == "hycube" else adl.n2n(4, 4)
    rep = _compiled(kname, fab).validate(seed=3)
    assert rep.map_result.success, f"mapping failed: {rep}"
    assert rep.passed, f"simulation mismatch: {rep}"


def test_compile_cache_counters(ual_cache):
    """Repeat compiles of an identical pair are served from the session
    cache: hit counter advances, no mapper restarts are paid."""
    _compiled("gemm", adl.hycube(4, 4, max_hops=4))   # hit or cold map
    h0, m0 = ual_cache.stats.hits, ual_cache.stats.misses
    exe = _compiled("gemm", adl.hycube(4, 4, max_hops=4))
    assert ual_cache.stats.hits == h0 + 1
    assert ual_cache.stats.misses == m0
    assert exe.compile_info.cache_hit
    assert exe.compile_info.mapper_restarts == 0


def test_multihop_improves_ii():
    ii1 = _compiled("fft", adl.hycube(4, 4, max_hops=1)).II
    ii4 = _compiled("fft", adl.hycube(4, 4, max_hops=4)).II
    assert ii4 <= ii1


@pytest.mark.parametrize("seed", [1, 2])
def test_nondefault_seed_maps_independently(seed):
    """Stochastic-mapper coverage beyond the shared seed-0 mappings: a
    fresh placement search at another seed still satisfies the invariants
    (distinct cache key, so this maps cold)."""
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target(adl.hycube(4, 4), seed=seed))
    assert exe.success and not exe.compile_info.cache_hit
    assert exe.II >= exe.map_result.mii


def test_spatial_ii_ge_spatiotemporal():
    """Paper Fig. 9: spatial II >= spatio-temporal II."""
    for kname in ("nw", "gemm", "aes"):
        dfg, _, _ = KERNELS[kname]()
        sp, _parts = spatial_ii(dfg, adl.spatial(4, 4))
        st = map_dfg(dfg, adl.hycube(4, 4, 4), seed=1).II
        assert sp >= min(st, sp)  # sanity
        assert sp >= 1 and st >= 1


def test_adl_json_roundtrip():
    fab = adl.hycube(4, 4, max_hops=3)
    fab2 = adl.Fabric.from_json(fab.to_json())
    assert fab2.n_pes == fab.n_pes
    assert fab2.links == fab.links
    assert fab2.max_hops == 3
    m = fab.to_adl()
    assert m.kind == "FABRIC" and len(m.submodules) == 16


def test_label_fn_hook():
    """LISA-style label hook biases placement without breaking mapping."""
    dfg, mk, n = KERNELS["nw"]()
    res = map_dfg(dfg, adl.hycube(4, 4, 4), seed=0,
                  label_fn=lambda nid, pe, ii: 0.1 * (pe % 3))
    assert res.success


def test_lisa_memonly_label_parity():
    """LISA-lite (core/lisa.py): mem-only learned bias keeps II parity."""
    from repro.core.dfg import apply_layout, plan_layout
    from repro.core.lisa import collect_dataset, make_label_fn, train
    fab = adl.hycube(4, 4)

    def laid(n):
        d, _, _ = KERNELS[n]()
        return apply_layout(d, plan_layout(d))

    feats, labels, pf = collect_dataset([(laid("gemm"), 0)], fab)
    params, losses = train(feats, labels, pf, steps=60)
    assert losses[-1] < losses[0]
    label_for = make_label_fn(params, fab, mem_only=True)
    dfg = laid("nw")
    base = map_dfg(dfg, fab, seed=3)
    lisa = map_dfg(dfg, fab, seed=3, label_fn=label_for(dfg))
    assert lisa.success and lisa.II <= base.II

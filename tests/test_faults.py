"""Deterministic fault injection + the self-healing service layers.

Contract under test:

  * ``FaultPlan`` round-trips through JSON and the ``REPRO_UAL_FAULTS``
    environment fragment (how spawned cluster workers inherit a plan),
    and specs validate their kind/counter fields,
  * ``FaultInjector`` counters are deterministic: a spec passes
    ``after`` matching events unharmed, fires exactly ``count`` times,
    and filters (``backend=``, ``worker=``) gate the match,
  * the ``Service`` circuit breaker: ``exec_fault`` on the pallas
    backend degrades the failed sweep in place to the bit-exact ``sim``
    fallback (callers see ``degraded_to``, never an error), trips the
    class ``open`` after ``breaker_threshold`` consecutive failures,
    re-opens on a failed half-open probe, and restores on a successful
    one — visible in ``stats()["breaker"]``,
  * ``delay_dispatch`` stalls a micro-batch's emission by the planned
    amount (straggler emulation),
  * a corrupted on-disk cache entry (bit flip or torn write) reads as a
    miss, is quarantined to ``<name>.corrupt``, and the class simply
    recompiles — parity preserved, ``stats.quarantined`` counted.
"""
import time

import numpy as np
import pytest

from repro import ual
from repro.core.dfg import interpret
from repro.ual import faults
from repro.ual.service.breaker import CircuitBreaker


def _program(kname="gemm"):
    return ual.Program.from_kernel(kname)


def _target(**knobs):
    return ual.Target.from_name("hycube", rows=4, cols=4, **knobs)


def _oracle(program, mem):
    return interpret(program.dfg, mem, program.n_iters)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with fault injection inactive."""
    faults.clear()
    yield
    faults.clear()


# ---------------------------------------------------------------------------
# plan serialization + validation
# ---------------------------------------------------------------------------

def test_fault_plan_env_round_trip(monkeypatch):
    plan = ual.FaultPlan([
        ual.FaultSpec("kill_worker", worker=1, after=6),
        ual.FaultSpec("exec_fault", backend="pallas", after=2, count=3),
        ual.FaultSpec("delay_dispatch", delay_ms=25.0),
    ], seed=7)
    assert ual.FaultPlan.from_json(plan.to_json()) == plan
    env = plan.to_env()
    assert set(env) == {faults.FAULTS_ENV}
    assert ual.FaultPlan.from_env(env) == plan
    assert ual.FaultPlan.from_env({}) is None
    # the lazy in-process activation path (what a spawned worker does)
    monkeypatch.setenv(faults.FAULTS_ENV, plan.to_json())
    faults.clear()          # reset the memoized "no plan" state
    faults._env_checked = False
    inj = faults.active()
    assert inj is not None and inj.plan == plan


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        ual.FaultSpec("meteor_strike")
    with pytest.raises(ValueError):
        ual.FaultSpec("exec_fault", after=-1)
    with pytest.raises(ValueError):
        ual.FaultSpec("exec_fault", count=0)


def test_fault_injector_counters_are_deterministic():
    plan = ual.FaultPlan([
        ual.FaultSpec("exec_fault", backend="pallas", after=2, count=2),
        ual.FaultSpec("delay_dispatch", delay_ms=40.0, count=1),
    ])
    inj = faults.FaultInjector(plan)
    inj.check_exec("sim")            # backend filter: not a matching event
    inj.check_exec("pallas")         # event 1: armed after 2 -> pass
    inj.check_exec("pallas")         # event 2: pass
    for _ in range(2):               # events 3, 4: fire exactly twice
        with pytest.raises(ual.InjectedFault):
            inj.check_exec("pallas")
    inj.check_exec("pallas")         # count exhausted: pass again
    assert [e["kind"] for e in inj.log] == ["exec_fault", "exec_fault"]
    assert inj.dispatch_delay() == pytest.approx(0.04)
    assert inj.dispatch_delay() == 0.0          # count=1: fired once


# ---------------------------------------------------------------------------
# circuit breaker protocol (pure unit)
# ---------------------------------------------------------------------------

def test_breaker_trip_probe_restore_protocol():
    brk = CircuitBreaker(threshold=2, cooldown_s=10.0)
    key = ("p", "t", "pallas", 8)
    assert brk.fallback_for("pallas") == "sim"
    assert brk.fallback_for("interp") is None
    assert brk.plan(key, "pallas", now=0.0) == (None, False)   # closed
    assert not brk.record_failure(key, now=0.0)
    assert brk.record_failure(key, now=1.0)                    # trips
    assert brk.state_of(key) == "open"
    assert brk.plan(key, "pallas", now=2.0) == ("sim", False)  # cooling
    fb, probe = brk.plan(key, "pallas", now=12.0)              # elapsed
    assert fb is None and probe
    assert brk.state_of(key) == "half-open"
    # concurrent sweep during the probe stays degraded
    assert brk.plan(key, "pallas", now=12.0) == ("sim", False)
    assert brk.record_failure(key, now=12.5, probe=True)       # re-open
    assert brk.state_of(key) == "open"
    fb, probe = brk.plan(key, "pallas", now=23.0)
    assert fb is None and probe
    assert brk.record_success(key, probe=True)                 # restore
    assert brk.state_of(key) == "closed"
    snap = brk.stats()
    assert snap["trips_total"] == 1
    (cls,) = snap["classes"].values()
    assert cls["restores"] == 1 and cls["state"] == "closed"
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0)


# ---------------------------------------------------------------------------
# breaker through the live service (pallas -> sim degradation)
# ---------------------------------------------------------------------------

def test_service_degrades_trips_and_restores_bit_exact():
    """Three injected pallas sweep failures: the first two degrade in
    place (trip at threshold=2), the third fails the half-open probe;
    the next probe restores.  Every caller gets bit-exact outputs."""
    program, target = _program(), _target(backend="pallas")
    rng = np.random.default_rng(11)
    mems = [program.random_inputs(rng) for _ in range(5)]
    faults.install(ual.FaultPlan(
        [ual.FaultSpec("exec_fault", backend="pallas", count=3)]))
    cooldown = 0.8
    with ual.Service(max_batch=4, max_wait_ms=5, breaker_threshold=2,
                     breaker_cooldown_s=cooldown) as svc:
        infos = []
        for i, mem in enumerate(mems):
            if i in (3, 4):
                time.sleep(cooldown + 0.1)      # let the class half-open
            resp = svc.submit(program, target, mem)
            out = resp.result(timeout=300)
            expect = _oracle(program, mem)
            for name in program.outputs:
                np.testing.assert_array_equal(out[name], expect[name])
            infos.append(dict(resp.info))
        stats = svc.stats()
    # r0, r1: failed primary retried in place on sim; r2: open -> sim
    # outright; r3: failed probe (3rd injected fault) -> sim; r4:
    # successful probe -> back on pallas
    assert [i.get("degraded_to") for i in infos] == \
        ["sim", "sim", "sim", "sim", None]
    brk = stats["breaker"]
    assert brk["trips_total"] == 1
    assert brk["degraded_batches_total"] == 4
    (cls,) = brk["classes"].values()
    assert cls["state"] == "closed" and cls["restores"] == 1
    assert stats["completed"] == 5 and stats["errors"] == 0


def test_service_without_fallback_surfaces_the_error():
    """A non-degradable backend (sim has no fallback) still fails loudly:
    the breaker never swallows an error it cannot route around."""
    program, target = _program(), _target(backend="sim")
    mem = program.random_inputs(np.random.default_rng(12))
    faults.install(ual.FaultPlan(
        [ual.FaultSpec("exec_fault", backend="sim", count=1)]))
    with ual.Service(max_batch=4, max_wait_ms=5, breaker_threshold=2) as svc:
        resp = svc.submit(program, target, mem)
        with pytest.raises(ual.InjectedFault):
            resp.result(timeout=300)
        resp2 = svc.submit(program, target, mem)    # count spent: healthy
        out = resp2.result(timeout=300)
    expect = _oracle(program, mem)
    for name in program.outputs:
        np.testing.assert_array_equal(out[name], expect[name])


def test_delay_dispatch_stalls_emission():
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(13))
    with ual.Service(max_batch=4, max_wait_ms=5) as svc:
        svc.submit(program, target, mem).result(timeout=300)  # warm class
        faults.install(ual.FaultPlan(
            [ual.FaultSpec("delay_dispatch", delay_ms=200.0, count=1)]))
        t0 = time.perf_counter()
        svc.submit(program, target, mem).result(timeout=300)
        stalled = time.perf_counter() - t0
    assert stalled >= 0.2, f"dispatch delay not applied ({stalled:.3f}s)"


# ---------------------------------------------------------------------------
# corrupted cache entries: miss + quarantine + recompile
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["truncate", "flip"])
def test_corrupt_cache_entry_quarantined_and_recompiled(tmp_path, mode):
    program, target = _program(), _target()
    ual.compile(program, target, cache=ual.MappingCache(disk_dir=tmp_path))
    assert faults.corrupt_cache_entry(tmp_path, which="mapping",
                                      mode=mode) is not None
    cache = ual.MappingCache(disk_dir=tmp_path)
    exe2 = ual.compile(program, target, cache=cache)
    rec = {p.name: p.stats for p in exe2.compile_info.passes}
    assert rec["mapping"].get("cache") == "miss"    # poisoned != served
    assert cache.stats.quarantined == 1
    assert cache.stats()["quarantined"] == 1
    corpses = list(tmp_path.glob("*.pkl.corrupt"))
    assert len(corpses) == 1, "poisoned entry must be quarantined"
    mem = program.random_inputs(np.random.default_rng(14))
    out = exe2.run(**mem)
    expect = _oracle(program, mem)
    for name in program.outputs:
        np.testing.assert_array_equal(out[name], expect[name])


def test_corrupt_lowered_entry_is_also_quarantined(tmp_path):
    program, target = _program(), _target()
    ual.compile(program, target, cache=ual.MappingCache(disk_dir=tmp_path))
    assert faults.corrupt_cache_entry(tmp_path, which="lowered",
                                      mode="flip") is not None
    cache = ual.MappingCache(disk_dir=tmp_path)
    exe = ual.compile(program, target, cache=cache)
    rec = {p.name: p.stats for p in exe.compile_info.passes}
    assert rec["mapping"].get("cache") == "hit"     # mapping untouched
    assert cache.stats.quarantined == 1
    assert list(tmp_path.glob("*_low.pkl.corrupt"))

"""Pass-pipeline compiler + pluggable strategies + parallel DSE front-end.

The redesigned compile path under test:

  * ``compile()`` is a staged pipeline — layout -> MII bounds -> mapping
    strategy -> lowering -> validation binding — and every pass reports
    name/wall-time/stats into ``CompileInfo.passes``,
  * mapper strategies resolve through a registry with the same contract
    as backends/fabrics (duplicates raise, unknown names raise with the
    known set, custom registrations are honored end-to-end),
  * the spatial-fabric compile path and failure-caching semantics
    (``memory_only``: a failure never persists to disk),
  * ``compile_many``/``explore`` dedup by digest, fan cold work over a
    process pool, and report II / per-pass timings / GOPS/W per point.
"""
import numpy as np
import pytest

from repro import ual
from repro.core.adl import hycube, spatial
from repro.core.mapper import AdaptiveStrategy, spatial_ii

PASS_NAMES = ["layout", "mii", "mapping", "lowering", "verify", "binding"]


# ---------------------------------------------------------------------------
# pass pipeline
# ---------------------------------------------------------------------------

def test_pipeline_pass_records_cold_and_warm(tmp_path):
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    target = ual.Target.from_name("hycube", rows=4, cols=4)

    cold = ual.compile(program, target, cache=cache)
    assert [p.name for p in cold.compile_info.passes] == PASS_NAMES
    assert all(p.wall_s >= 0 for p in cold.compile_info.passes)
    by_name = {p.name: p.stats for p in cold.compile_info.passes}
    assert by_name["layout"]["n_nodes"] == len(program.laid.nodes)
    assert by_name["mii"]["mii"] == max(by_name["mii"]["rec_mii"],
                                        by_name["mii"]["res_mii"])
    assert by_name["mapping"]["cache"] == "miss"
    assert by_name["mapping"]["II"] == cold.II >= by_name["mii"]["mii"]
    assert by_name["lowering"]["cache"] == "miss"
    assert by_name["lowering"]["cm_bytes"] == cold.lowered.cm_bytes()
    assert by_name["verify"]["ok"] and by_name["verify"]["errors"] == 0
    assert cold.check_report is not None and cold.check_report.ok
    assert by_name["binding"] == {"backend": "sim", "requires_config": True,
                                  "runnable": True, "validatable": True}
    # the mapping pass dominates a cold compile's wall time
    times = cold.compile_info.pass_times
    assert set(times) == set(PASS_NAMES)
    assert times["mapping"] > sum(v for k, v in times.items()
                                  if k != "mapping")

    warm = ual.compile(program, target, cache=cache)
    wstats = {p.name: p.stats for p in warm.compile_info.passes}
    assert wstats["mapping"]["cache"] == "hit"
    assert wstats["lowering"]["cache"] == "hit"      # zero re-lowering
    assert warm.compile_info.cache_hit
    assert warm.lowered is not None


def test_pipeline_skips_mapping_for_mapping_free_backend():
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target(hycube(4, 4), backend="interp"))
    stats = {p.name: p.stats for p in exe.compile_info.passes}
    assert stats["mapping"] == {"skipped": "mapping-free backend"}
    assert exe.map_result is None and exe.success
    assert stats["binding"]["requires_config"] is False


def test_custom_pipeline_pass_list():
    """The pass list is data: a custom pipeline (extra analysis pass) runs
    through the same compile() entry without forking the compiler."""
    seen = {}

    class CountOpsPass(ual.CompilePass):
        name = "count_ops"

        def run(self, ctx):
            seen["ops"] = len(ctx.program.laid.nodes)
            return {"n_ops": seen["ops"]}

    pipe = ual.default_pipeline()
    pipe.passes.insert(2, CountOpsPass())
    program = ual.Program.from_kernel("gemm")
    exe = ual.compile(program, ual.Target(hycube(4, 4)), pipeline=pipe,
                      use_cache=False)
    assert exe.success
    assert [p.name for p in exe.compile_info.passes] == \
        ["layout", "mii", "count_ops", "mapping", "lowering", "verify",
         "binding"]
    assert seen["ops"] == len(program.laid.nodes)


# ---------------------------------------------------------------------------
# spatial-fabric compile path
# ---------------------------------------------------------------------------

def test_spatial_compile_path_matches_analytic_model():
    program = ual.Program.from_kernel("gemm")
    fab = spatial(4, 4)
    exe = ual.compile(program, ual.Target(fab, backend="interp"))
    ii, n_parts = spatial_ii(program.laid, fab)
    assert exe.success and exe.II == ii
    assert exe.spatial_subgraphs == n_parts >= 1
    assert exe.map_result.strategy == "spatial"
    stats = {p.name: p.stats for p in exe.compile_info.passes}
    assert stats["mapping"] == {"model": "spatial_ii", "II": ii,
                                "subgraphs": n_parts}
    assert exe.map_result.mii == stats["mii"]["rec_mii"]
    assert stats["binding"]["runnable"] is True    # interp needs no config
    # spatial mappings produce no machine configuration -> a config-requiring
    # backend is not runnable, and the binding pass says so up front
    on_sim = ual.compile(program, ual.Target(fab, backend="sim"))
    sim_stats = {p.name: p.stats for p in on_sim.compile_info.passes}
    assert sim_stats["binding"]["runnable"] is False


def test_spatial_target_never_enters_cache(tmp_path):
    """The analytic model is microseconds — caching it would only risk
    staleness.  Spatial compiles must not touch the mapping cache."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    ual.compile(program, ual.Target(spatial(4, 4), backend="interp"),
                cache=cache)
    assert len(cache) == 0
    assert cache.stats.misses == cache.stats.stores == 0


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

def test_builtin_strategies_listed():
    assert {"adaptive", "sa"} <= set(ual.list_strategies())
    assert "hycube" in ual.list_fabrics()
    assert {"interp", "sim", "pallas"} <= set(ual.list_backends())


def test_unknown_strategy_raises_with_known_set():
    program = ual.Program.from_kernel("gemm")
    with pytest.raises(KeyError, match="unknown strategy 'ilp'.*adaptive"):
        ual.compile(program, ual.Target(hycube(4, 4), strategy="ilp"))


def test_duplicate_strategy_registration_raises():
    ual.register_strategy("dup_test_strategy", AdaptiveStrategy())
    try:
        with pytest.raises(ValueError, match="already registered"):
            ual.register_strategy("dup_test_strategy", AdaptiveStrategy())
        ual.register_strategy("dup_test_strategy", AdaptiveStrategy(),
                              overwrite=True)
        assert "dup_test_strategy" in ual.list_strategies()
    finally:
        from repro.core.mapper import MAPPER_STRATEGIES
        MAPPER_STRATEGIES.pop("dup_test_strategy", None)


def test_strategy_must_subclass_mapper_strategy():
    with pytest.raises(TypeError, match="must be a core.mapper"):
        ual.register_strategy("broken", lambda m: True)


def test_custom_strategy_end_to_end(tmp_path):
    """A registered strategy is addressable from Target.strategy, runs the
    mapping, tags the MapResult, and keys the cache under its own name."""
    calls = {"n": 0}

    class CountingStrategy(ual.MapperStrategy):
        name = "counting_test"

        def attempt(self, m):
            calls["n"] += 1
            return m.place_all() and not m.occ.overused()

    ual.register_strategy("counting_test", CountingStrategy())
    try:
        cache = ual.MappingCache(disk_dir=tmp_path / "ual")
        program = ual.Program.from_kernel("gemm")
        base = ual.Target(hycube(4, 4))
        custom = ual.Target(hycube(4, 4), strategy="counting_test")
        assert base.digest != custom.digest        # strategy is mapper state
        exe = ual.compile(program, custom, cache=cache)
        assert exe.success and calls["n"] >= 1
        assert exe.map_result.strategy == "counting_test"
        assert ual.compile(program, custom, cache=cache).compile_info.cache_hit
    finally:
        from repro.core.mapper import MAPPER_STRATEGIES
        MAPPER_STRATEGIES.pop("counting_test", None)


# ---------------------------------------------------------------------------
# failure caching (memory_only semantics)
# ---------------------------------------------------------------------------

def test_failure_cached_in_memory_never_on_disk(tmp_path):
    """``put(memory_only=True)`` is the failure path: served in-process,
    invisible to the disk layer, retried after clear_memory()."""
    from repro.core.mapper import MapResult
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    fail = MapResult(False, -1, 3, restarts=7)
    cache.put(("p", "t"), fail, memory_only=True)
    assert cache.contains(("p", "t"))
    assert cache.get(("p", "t")).restarts == 7
    assert not list((tmp_path / "ual").glob("*.pkl"))
    cache.clear_memory()
    assert not cache.contains(("p", "t"))          # a new process must retry
    assert cache.get(("p", "t")) is None

    ok = MapResult(True, 4, 4)
    cache.put(("p2", "t2"), ok, memory_only=False)
    assert list((tmp_path / "ual").glob("*.pkl"))  # successes do persist
    cache.clear_memory()
    assert cache.contains(("p2", "t2"))


def test_compile_many_failure_stays_off_disk(tmp_path):
    """A grid containing an unmappable point: the pool maps it once, the
    failure is memoized in-process only, and the executable reports it."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    good = ual.Target.from_name("hycube", rows=4, cols=4)
    bad = ual.Target(hycube(2, 2), ii_max=1, max_restarts=1)  # can't fit
    exes = ual.compile_many([(program, good), (program, bad),
                             (program, bad)], workers=2, cache=cache)
    assert exes[0].success
    assert not exes[1].success and not exes[2].success
    assert exes[2].compile_info.cache_hit          # dedup'd, not re-mapped
    pkls = list((tmp_path / "ual").glob("*.pkl"))
    # only the success persisted: its mapping entry (+ at most its lowered
    # artifact) — the failure never reaches disk
    assert len([p for p in pkls if not p.name.endswith("_low.pkl")]) == 1
    assert len([p for p in pkls if p.name.endswith("_low.pkl")]) <= 1


# ---------------------------------------------------------------------------
# compile_many / explore
# ---------------------------------------------------------------------------

def test_compile_many_dedups_and_orders(tmp_path):
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    t_hyc = ual.Target.from_name("hycube", rows=4, cols=4)
    t_n2n = ual.Target.from_name("n2n", rows=4, cols=4)
    pairs = [(program, t_hyc), (program, t_n2n),
             (program, t_hyc.with_backend("pallas")),   # same digest as [0]
             (program, t_hyc)]                          # exact duplicate
    exes = ual.compile_many(pairs, workers=2, cache=cache)
    assert [e.success for e in exes] == [True] * 4
    # two unique digests -> exactly two mappings paid, two warm hits
    assert cache.stats.stores == 2
    assert [e.compile_info.cache_hit for e in exes] == \
        [False, False, True, True]
    assert exes[0].compile_info.mapper_restarts >= 1
    assert exes[0].II == exes[2].II == exes[3].II
    # pool-mapped executables carry true mapping cost in their pass record
    stats = {p.name: p.stats for p in exes[0].compile_info.passes}
    assert stats["mapping"]["cache"] == "pool"
    # results identical to an in-process compile of the same pair
    mem = program.random_inputs(np.random.default_rng(0))
    out_pool = exes[0].run(mem)
    out_seq = ual.compile(program, t_hyc, use_cache=False).run(mem)
    for name in program.outputs:
        np.testing.assert_array_equal(out_pool[name], out_seq[name])


def test_compile_many_mixed_grid_serial_paths(tmp_path):
    """Spatial fabrics and mapping-free backends can't fan out — they
    compile serially through the same pipeline, in input order."""
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    pairs = [(program, ual.Target.from_name("spatial", backend="interp")),
             (program, ual.Target(hycube(4, 4), backend="interp")),
             (program, ual.Target.from_name("hycube", rows=4, cols=4))]
    exes = ual.compile_many(pairs, workers=2, cache=cache)
    assert exes[0].spatial_subgraphs >= 1
    assert exes[1].map_result is None
    assert exes[2].map_result.config is not None
    assert cache.stats.stores == 1                 # only the temporal mapping


def test_explore_report_pareto_and_zero_redundancy(tmp_path):
    cache = ual.MappingCache(disk_dir=tmp_path / "ual")
    program = ual.Program.from_kernel("gemm")
    space = {"fabric": [("hycube", dict(rows=4, cols=4)),
                        ("n2n", dict(rows=4, cols=4))],
             "strategy": ["adaptive", "sa"]}
    report = ual.explore(program, space, workers=2, cache=cache)
    assert len(report.points) == 4
    assert all(p.success for p in report.points)
    for p in report.points:
        assert p.II >= 1 and p.gops_w > 0
        assert set(p.pass_times) == set(PASS_NAMES)
    assert report.n_mapped == 4 == cache.stats.stores
    assert report.pareto and set(report.pareto) <= set(report.points)
    # no point on the frontier is dominated by another point
    for p in report.pareto:
        for q in report.points:
            assert not (q.II <= p.II and q.mapper_wall_s <= p.mapper_wall_s
                        and q.gops_w >= p.gops_w
                        and (q.II, q.mapper_wall_s, q.gops_w)
                        != (p.II, p.mapper_wall_s, p.gops_w))
    rendered = report.render()
    assert "hycube_4x4" in rendered and "Pareto" in rendered
    assert report.to_json()["points"][0]["II"] == report.points[0].II

    # warm re-sweep over the same cache: zero mappings paid
    again = ual.explore(program, space, workers=2, cache=cache)
    assert again.n_mapped == 0 and again.n_warm == len(again.points)
    assert [p.II for p in again.points] == [p.II for p in report.points]


def test_explore_rejects_bad_space():
    program = ual.Program.from_kernel("gemm")
    with pytest.raises(ValueError, match="'fabric' axis"):
        ual.explore(program, {"strategy": ["adaptive"]})
    with pytest.raises(ValueError, match="unknown space axes"):
        ual.explore(program, {"fabric": ["hycube"], "rows": [4]})
    with pytest.raises(KeyError, match="unknown fabric 'fpga'"):
        ual.explore(program, {"fabric": ["fpga"]})
    with pytest.raises(ValueError, match="design space is empty"):
        ual.explore(program, {"fabric": ["hycube"], "strategy": []})


def test_explore_accepts_bare_string_axes(tmp_path):
    """A scalar string for strategy/backend means one value, not its chars."""
    from repro.ual.explore import space_targets
    targets = space_targets({"fabric": ["hycube"], "strategy": "sa",
                             "backend": "interp"})
    assert [(t.strategy, t.backend) for t, _ in targets] == [("sa", "interp")]

"""The sharded serving cluster: replicas, routing, stealing, processes.

Contract under test:

  * ``Router`` routes flush-ready micro-batches to the least-loaded
    replica slot (class-affinity tiebreak), an idle slot steals the
    oldest batch from the most-loaded sibling, and ``stop()`` drains
    queues before workers exit,
  * ``Coalescer.steal_oldest`` honors the minimum bucket age (idle
    capacity never flushes a brand-new bucket) and pops earliest-due,
  * ``Service(replicas=N)`` keeps oracle parity through the replicated
    path, reports the router in ``stats()``, and flushes partial buckets
    early when replicas idle,
  * the sharded engine path (``pallas_sharded``) is bit-exact vs the
    interpreter oracle, including a ragged final chunk, both in-process
    and in a fresh process with 2 forced host devices,
  * a cold class compiled by several *processes* against one shared
    disk cache pays exactly ONE mapping cluster-wide (the cross-process
    per-key lock),
  * ``MappingCache`` disk writes are atomic and tolerate a concurrent
    writer winning the ``os.replace`` race,
  * ``ClusterService`` resolves parent-side futures bit-exact through
    worker processes and merges their stats into one cluster view,
  * self-healing: a worker killed mid-batch (deterministic
    ``FaultPlan``) strands no future — orphaned requests retry
    transparently on live workers with bit-exact results, the dead
    worker respawns under the ``RestartPolicy`` and rejoins warm off
    the shared disk cache; with the retry/restart budgets at zero the
    caller gets a ``worker-died`` verdict instead; shutdown racing a
    respawn leaks no process,
  * a short soak keeps queue depth bounded and p99 finite.
"""
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro import ual
from repro.core.dfg import interpret
from repro.launch.mesh import forced_device_env
from repro.ual.cluster.replica import Router
from repro.ual.service.coalescer import Coalescer
from repro.ual.service.queue import Request

REPO = Path(__file__).resolve().parents[1]


def _program(kname="gemm"):
    return ual.Program.from_kernel(kname)


def _target(**knobs):
    return ual.Target.from_name("hycube", rows=4, cols=4, **knobs)


def _oracle(program, mem):
    return interpret(program.dfg, mem, program.n_iters)


# ---------------------------------------------------------------------------
# Router units
# ---------------------------------------------------------------------------

def test_router_routes_least_loaded_under_skew():
    r = Router(3)
    r.slots[0].in_flight = 2     # busy
    r.slots[1].in_flight = 1
    idx = r.route("k", ["b0"])
    assert idx == 2              # the empty slot
    # slot 2 now has 1 queued == slot 1's in-flight; next goes to 1 or 2,
    # never to the most-loaded slot 0
    assert r.route("k", ["b1"]) != 0
    assert r.stats()["decisions"]["least_loaded"] == 2


def test_router_affinity_breaks_ties_toward_warm_slot():
    r = Router(3)
    r.slots[2].warm.add("classA")
    assert r.route("classA", ["b"]) == 2
    assert r.stats()["decisions"]["affinity"] == 1
    # a colder class at equal load ignores warmth it doesn't have
    assert r.route("classB", ["b"]) != 2


def test_router_idle_pull_steals_oldest_from_most_loaded():
    r = Router(2)
    r.route("k", ["old"])        # both land on slot 0: it is least-loaded
    r.route("k", ["new"])        # only until its queue grows — but route
    # load counts queued batches, so the second goes to slot 1; force the
    # skew the scheduler would see under a burst instead:
    r.slots[0].queue.extend(r.slots[1].queue)
    r.slots[1].queue.clear()
    key, batch, stolen = r.pull(1, timeout=0.1)
    assert stolen and batch == ["old"]     # FIFO across the pool
    assert r.slots[1].steals == 1 and r.stats()["steals"] == 1
    r.done(1, 1, 0.01)
    assert r.slots[1].samples == 1


def test_router_stop_drains_queues_before_none():
    r = Router(1)
    r.route("k", ["pending"])
    r.stop()
    item = r.pull(0, timeout=1.0)
    assert item is not None and item[1] == ["pending"]
    r.done(0, 1, 0.0)
    assert r.pull(0, timeout=1.0) is None


def test_router_validates_inputs():
    with pytest.raises(ValueError):
        Router(0)
    with pytest.raises(ValueError):
        Router(3, devices=[None, None])


# ---------------------------------------------------------------------------
# Coalescer stealing
# ---------------------------------------------------------------------------

def test_coalescer_steal_oldest_honors_min_age():
    c = Coalescer(max_batch=8, max_wait_s=1.0)
    program, target = _program(), _target()
    r1 = Request(tenant="a", program=program, target=target, mem={},
                 n_iters=4, t_submit=100.0)
    r2 = Request(tenant="b", program=program, target=target, mem={},
                 n_iters=8, t_submit=100.5)       # different class
    c.offer(r1)
    c.offer(r2)
    assert c.steal_oldest(100.05, min_age_s=0.1) is None   # too young
    got = c.steal_oldest(100.2, min_age_s=0.1)             # r1 aged enough
    assert got == [r1]                                     # earliest-due
    assert c.pending() == 1
    assert c.steal_oldest(100.55, min_age_s=0.1) is None   # r2 still young
    assert c.steal_oldest(100.7, min_age_s=0.1) == [r2]


# ---------------------------------------------------------------------------
# Service in replicated mode (sim backend)
# ---------------------------------------------------------------------------

def test_replicated_service_parity_and_router_stats():
    program, target = _program(), _target()
    rng = np.random.default_rng(1)
    mems = [program.random_inputs(rng) for _ in range(24)]
    with ual.Service(max_batch=8, max_wait_ms=30, replicas=2) as svc:
        resps = [svc.submit(program, target, m) for m in mems]
        outs = [r.result(timeout=300) for r in resps]
        stats = svc.stats()
    for mem, out in zip(mems, outs):
        expect = _oracle(program, mem)
        for name in program.outputs:
            np.testing.assert_array_equal(out[name], expect[name])
    router = stats["router"]
    assert router["replicas"] == 2
    assert len(router["slots"]) == 2
    assert sum(s["samples"] for s in router["slots"]) == 24
    assert sum(router["decisions"].values()) == \
        sum(s["batches"] for s in router["slots"])
    for slot in router["slots"]:
        for k in ("batches", "samples", "busy_s", "samples_per_s",
                  "steals", "warm_classes"):
            assert k in slot


def test_replicated_service_early_flush_when_replicas_idle():
    """With a long age limit and idle replicas, partial buckets flush
    early (coalescer-side stealing) instead of waiting out the clock."""
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(2))
    with ual.Service(max_batch=64, max_wait_ms=2000, replicas=2) as svc:
        t0 = time.perf_counter()
        resp = svc.submit(program, target, mem)
        resp.result(timeout=300)
        waited = time.perf_counter() - t0
        stats = svc.stats()
    assert waited < 1.5, "early flush should beat the 2s age limit"
    assert stats["router"]["early_flushes"] >= 1


# ---------------------------------------------------------------------------
# sharded engine path
# ---------------------------------------------------------------------------

def test_sharded_backend_parity_including_ragged_batch():
    """pallas_sharded == interp oracle on whatever mesh this host has
    (1 device in-process), including a batch that is ragged vs the
    device count and bucket ladder."""
    program, target = _program(), _target(backend="pallas")
    exe = ual.compile(program, target)
    rng = np.random.default_rng(3)
    mems = [program.random_inputs(rng) for _ in range(5)]
    outs = exe.run_batch(mems, backend="pallas_sharded")
    for mem, out in zip(mems, outs):
        expect = _oracle(program, mem)
        for name in program.outputs:
            np.testing.assert_array_equal(out[name], expect[name])
    assert exe.last_info["engine"] == "pallas-jit-sharded"
    assert exe.last_info["n_devices"] >= 1


def test_sharded_parity_under_forced_two_devices():
    """A fresh process with 2 forced host devices runs the sharded path
    bit-exact, with the batch axis genuinely split over both."""
    code = (
        "from repro.launch.mesh import forced_host_devices\n"
        "forced_host_devices(2)\n"
        "import numpy as np\n"
        "from repro import ual\n"
        "from repro.core.dfg import interpret\n"
        "import jax\n"
        "assert len(jax.devices()) == 2\n"
        "program = ual.Program.from_kernel('gemm')\n"
        "target = ual.Target.from_name('hycube', rows=4, cols=4,\n"
        "                              backend='pallas')\n"
        "exe = ual.compile(program, target)\n"
        "rng = np.random.default_rng(0)\n"
        "mems = [program.random_inputs(rng) for _ in range(5)]\n"
        "outs = exe.run_batch(mems, backend='pallas_sharded')\n"
        "ok = all(np.array_equal(\n"
        "    o[n], interpret(program.dfg, m, program.n_iters)[n])\n"
        "    for m, o in zip(mems, outs) for n in program.outputs)\n"
        "print('DEVICES', exe.last_info['n_devices'], 'PARITY', ok)\n"
    )
    env = forced_device_env(2)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=str(REPO), timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DEVICES 2 PARITY True" in out.stdout


# ---------------------------------------------------------------------------
# cross-process compile-once through the shared disk cache
# ---------------------------------------------------------------------------

def test_cold_compile_happens_once_across_processes(tmp_path):
    """Three processes race one cold class against a shared disk cache:
    the cross-process per-key lock makes exactly one pay the mapping;
    the others block briefly and load the artifact."""
    code = (
        "import sys\n"
        "from repro import ual\n"
        "cache = ual.MappingCache(disk_dir=sys.argv[1])\n"
        "program = ual.Program.from_kernel('gemm')\n"
        "target = ual.Target.from_name('hycube', rows=4, cols=4)\n"
        "exe = ual.compile(program, target, cache=cache)\n"
        "rec = {p.name: p.stats for p in exe.compile_info.passes}\n"
        "print('MAPPING', rec['mapping'].get('cache'))\n"
    )
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    procs = [subprocess.Popen([sys.executable, "-c", code, str(tmp_path)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True,
                              env=env, cwd=str(REPO))
             for _ in range(3)]
    outs = [p.communicate(timeout=560) for p in procs]
    for p, (stdout, stderr) in zip(procs, outs):
        assert p.returncode == 0, stderr[-2000:]
    verdicts = [stdout.strip().split()[-1] for stdout, _ in outs]
    assert verdicts.count("miss") == 1, verdicts
    assert verdicts.count("hit") == 2, verdicts
    mapping_pkls = [f for f in tmp_path.glob("*.pkl")
                    if not f.name.endswith("_low.pkl")]
    assert len(mapping_pkls) == 1


def test_write_atomic_tolerates_concurrent_winner(tmp_path, monkeypatch):
    """If ``os.replace`` fails but another writer already installed the
    entry, the write is a success (the artifact is there); if nobody
    installed it, the failure surfaces."""
    cache = ual.MappingCache(disk_dir=tmp_path)
    path = tmp_path / "entry.pkl"

    real_replace = os.replace

    def losing_replace(src, dst):
        real_replace(src, dst)      # "the other writer" wins first...
        raise OSError("simulated lost rename race")

    monkeypatch.setattr(os, "replace", losing_replace)
    cache._write_atomic(path, {"payload": 1})       # tolerated
    assert path.exists()
    assert not list(tmp_path.glob("*.tmp.*")), "tmp files must be cleaned"

    def failing_replace(src, dst):
        raise OSError("disk detached")

    gone = tmp_path / "never.pkl"
    monkeypatch.setattr(os, "replace", failing_replace)
    with pytest.raises(OSError):
        cache._write_atomic(gone, {"payload": 2})
    assert not gone.exists()
    assert not list(tmp_path.glob("*.tmp.*"))


def test_process_lock_key_is_reentrant_across_instances(tmp_path):
    """Two cache instances over one directory serialize on the same
    per-key lock file (the in-process analogue of the subprocess race)."""
    a = ual.MappingCache(disk_dir=tmp_path)
    b = ual.MappingCache(disk_dir=tmp_path)
    key = ("p" * 24, "t" * 24)
    la = a.process_lock_key(key)
    lb = b.process_lock_key(key)
    assert la is not None and lb is not None
    assert Path(la._path) == Path(lb._path)
    with la:
        assert Path(la._path).exists()
    with lb:
        pass
    assert ual.MappingCache(disk_dir=None).process_lock_key(key) is None


# ---------------------------------------------------------------------------
# ClusterService end-to-end (worker processes, sim backend)
# ---------------------------------------------------------------------------

def test_cluster_service_parity_and_merged_stats(tmp_path):
    program, target = _program(), _target()
    rng = np.random.default_rng(4)
    mems = [program.random_inputs(rng) for _ in range(16)]
    with ual.ClusterService(workers=2, max_batch=8, max_wait_ms=10,
                            cache_dir=str(tmp_path)) as cs:
        resps = [cs.submit(program, target, m) for m in mems]
        outs = [r.result(timeout=300) for r in resps]
        stats = cs.stats()
    for mem, out in zip(mems, outs):
        expect = _oracle(program, mem)
        for name in program.outputs:
            np.testing.assert_array_equal(out[name], expect[name])
    # every response knows which worker ran it
    assert all(r.info.get("worker") in (0, 1) for r in resps)
    # merged cluster schema
    assert stats["cluster"] is True and stats["workers"] == 2
    assert stats["completed"] == 16 and stats["rejected"] == 0
    assert stats["samples_per_s"] > 0 and stats["p99_ms"] is not None
    assert set(stats["routing"]["decisions"]) == {"affinity",
                                                  "least_loaded", "retry"}
    assert stats["routing"]["decisions"]["retry"] == 0  # no deaths here
    assert sum(stats["routing"]["decisions"].values()) == 16
    assert sorted(stats["per_worker"]) == [0, 1]
    for snap in stats["per_worker"].values():
        for k in ("completed", "p50_ms", "p99_ms", "samples_per_s",
                  "cache", "engine"):
            assert k in snap


def test_cluster_service_rejects_after_shutdown(tmp_path):
    program, target = _program(), _target()
    cs = ual.ClusterService(workers=1, max_batch=4, max_wait_ms=5,
                            cache_dir=str(tmp_path))
    cs.shutdown()
    resp = cs.submit(program, target,
                     program.random_inputs(np.random.default_rng(5)))
    assert resp.rejected and resp.reason == "shutdown"


# ---------------------------------------------------------------------------
# self-healing: kill/retry/respawn/warm-rejoin (deterministic fault plans)
# ---------------------------------------------------------------------------

def _wait_respawn(cs, widx, timeout=60.0):
    """Poll supervision until worker ``widx`` is alive again post-restart;
    returns its final supervision snapshot."""
    deadline = time.time() + timeout
    snap = None
    while time.time() < deadline:
        snap = cs.stats(timeout=30)["supervision"]["workers"][widx]
        if snap["restarts"] >= 1 and snap["alive"]:
            return snap
        time.sleep(0.2)
    raise AssertionError(f"worker {widx} never respawned: {snap}")


def test_cluster_kill_midbatch_transparent_retry(tmp_path):
    """Worker 0 is killed (hard exit, no goodbye) with requests in
    flight: every future still resolves bit-exact — orphans ride retry
    hops to worker 1 — and worker 0 respawns under the policy."""
    program, target = _program(), _target()
    rng = np.random.default_rng(7)
    mems = [program.random_inputs(rng) for _ in range(24)]
    plan = ual.FaultPlan([ual.FaultSpec("kill_worker", worker=0, after=3)])
    with ual.ClusterService(
            workers=2, max_batch=8, max_wait_ms=2, cache_dir=str(tmp_path),
            worker_env=plan.to_env(),
            restart_policy=ual.RestartPolicy(max_restarts=2,
                                             backoff_base_s=0.1)) as cs:
        resps = [cs.submit(program, target, m) for m in mems]
        outs = [r.result(timeout=300) for r in resps]    # nothing lost
        for mem, out in zip(mems, outs):
            expect = _oracle(program, mem)
            for name in program.outputs:
                np.testing.assert_array_equal(out[name], expect[name])
        assert any(r.info.get("retries", 0) >= 1 for r in resps), \
            "the kill must strand (and retry) at least one request"
        assert all(r.info.get("retries", 0) <= cs.max_retries
                   for r in resps)
        snap = _wait_respawn(cs, 0)
        stats = cs.stats(timeout=30)
    assert snap["deaths"] == 1 and snap["restarts"] == 1
    assert snap["last_recovery_s"] is not None
    sup = stats["supervision"]
    assert sup["restarts_total"] == 1 and sup["deaths_total"] == 1
    assert sup["retries_total"] == stats["routing"]["decisions"]["retry"] >= 1
    assert sup["policy"]["max_restarts"] == 2


def test_cluster_retry_exhaustion_yields_worker_died_verdict(tmp_path):
    """Budgets at zero: the stranded request resolves with a
    ``worker-died`` verdict (never hangs), and with no live worker left
    later submits are rejected up front."""
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(8))
    plan = ual.FaultPlan([ual.FaultSpec("kill_worker", worker=0)])
    with ual.ClusterService(
            workers=1, max_batch=4, max_wait_ms=2, cache_dir=str(tmp_path),
            worker_env=plan.to_env(), max_retries=0,
            restart_policy=ual.RestartPolicy(max_restarts=0)) as cs:
        resp = cs.submit(program, target, mem)   # its arrival is the kill
        with pytest.raises(ual.ServiceRejected) as err:
            resp.result(timeout=120)
        assert err.value.reason == "worker-died"
        assert resp.info.get("retries") == 0
        deadline = time.time() + 60
        while cs.stats(timeout=10)["supervision"]["workers"][0]["alive"]:
            assert time.time() < deadline, "death never detected"
            time.sleep(0.1)
        late = cs.submit(program, target, mem)
        assert late.rejected and late.reason == "worker-died"
        sup = cs.stats(timeout=10)["supervision"]
    assert sup["workers"][0]["exhausted"] is True
    assert sup["restarts_total"] == 0


def test_cluster_respawned_worker_rejoins_warm(tmp_path):
    """A respawned worker re-registers its classes and re-loads
    artifacts from the shared disk cache: it serves again with ZERO
    fresh mapping stores (disk hits only)."""
    program, target = _program(), _target()
    rng = np.random.default_rng(9)
    mems = [program.random_inputs(rng) for _ in range(8)]
    plan = ual.FaultPlan([ual.FaultSpec("kill_worker", worker=0, after=2)])
    with ual.ClusterService(
            workers=2, max_batch=4, max_wait_ms=2, cache_dir=str(tmp_path),
            worker_env=plan.to_env(),
            restart_policy=ual.RestartPolicy(max_restarts=1,
                                             backoff_base_s=0.1)) as cs:
        for r in [cs.submit(program, target, m) for m in mems]:
            r.result(timeout=300)
        _wait_respawn(cs, 0)
        # sequential requests route to the warm-affine least-loaded
        # worker 0; stay under the re-armed kill threshold (after=2)
        outs = []
        for mem in mems[:2]:
            outs.append(cs.submit(program, target, mem).result(timeout=300))
        for mem, out in zip(mems[:2], outs):
            expect = _oracle(program, mem)
            for name in program.outputs:
                np.testing.assert_array_equal(out[name], expect[name])
        stats = cs.stats(timeout=30)
    w0 = stats["per_worker"].get(0)
    assert w0 is not None, "respawned worker must answer stats"
    mapping = w0["cache"]["mapping"]
    assert mapping["stores"] == 0, "warm rejoin must not re-map"
    assert mapping["disk_hits"] >= 1, "artifacts must come off shared disk"


def test_cluster_shutdown_during_respawn_leaks_nothing(tmp_path):
    """Shutdown racing the respawn window: the watchdog either installs
    the replacement (then it is stopped like any worker) or reaps it —
    no leaked process, no wedged watchdog thread."""
    program, target = _program(), _target()
    mem = program.random_inputs(np.random.default_rng(10))
    plan = ual.FaultPlan([ual.FaultSpec("kill_worker", worker=0)])
    cs = ual.ClusterService(
        workers=1, max_batch=4, max_wait_ms=2, cache_dir=str(tmp_path),
        worker_env=plan.to_env(),
        restart_policy=ual.RestartPolicy(max_restarts=3,
                                         backoff_base_s=0.05))
    resp = cs.submit(program, target, mem)       # kills the only worker
    deadline = time.time() + 60
    while cs.stats(timeout=10)["supervision"]["workers"][0]["deaths"] < 1:
        assert time.time() < deadline, "death never detected"
        time.sleep(0.05)
    cs.shutdown()                                # races the respawn
    assert all(not p.is_alive() for p in cs._procs), "leaked worker"
    assert all(not t.is_alive() for t in cs._threads), "wedged thread"
    with pytest.raises(ual.ServiceRejected):     # resolved, not stuck
        resp.result(timeout=5)


# ---------------------------------------------------------------------------
# soak: bounded depth, finite tail
# ---------------------------------------------------------------------------

def test_replicated_soak_bounded_queue_and_finite_p99():
    """A short steady load through the replicated service: queue depth
    stays bounded (admission control works) and p99 is finite."""
    program, target = _program(), _target()
    rng = np.random.default_rng(6)
    mems = [program.random_inputs(rng) for _ in range(8)]
    depths = []
    with ual.Service(max_batch=8, max_wait_ms=5, max_queue=64,
                     replicas=2) as svc:
        resps = []
        t_end = time.perf_counter() + 2.0
        while time.perf_counter() < t_end:
            resps.append(svc.submit(program, target, mems[len(resps) % 8]))
            depths.append(svc.stats()["queue_depth"])
            time.sleep(0.01)
        completed = 0
        for r in resps:
            try:
                r.result(timeout=300)
                completed += 1
            except ual.ServiceRejected:
                pass            # bounded-queue rejection is the contract
        stats = svc.stats()
    assert max(depths) <= 64, "queue depth must stay bounded"
    assert stats["p99_ms"] is not None and np.isfinite(stats["p99_ms"])
    assert stats["completed"] == completed > 0
